"""L2 — the JAX GQA transformer (fwd/bwd) and the Lexico decode path.

This is the paper's "model" layer: a decoder-only transformer with grouped-
query attention, RoPE, RMSNorm and SwiGLU — the architecture family of every
model the paper evaluates (Llama-3.x / Mistral / Qwen2.5). Three sizes (S/M/L,
DESIGN.md §1) are trained from scratch by ``aot.py`` at build time; weights
are exported to ``artifacts/model_{size}.bin`` and all inference graphs are
lowered to HLO text for the Rust/PJRT runtime. Python never runs at serving
time.

The Lexico decode step (``lexico_decode_step``) composes the L1 Pallas
kernels (``kernels.omp``, ``kernels.sparse_attn``) into the full Eq. 7
computation so they lower into the same HLO artifact.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.sparse_attn import lexico_decode_attn

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters. ``name`` keys the artifact files."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    max_seq: int
    rope_base: float = 10000.0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self, params=None) -> int:
        if params is None:
            params = init_params(jax.random.PRNGKey(0), self)
        return sum(int(np.prod(v.shape)) for v in params.values())


# The three model scales (Fig. 1's 1B/3B/8B ladder substitute). head_dim m=32
# throughout, so the paper's (3s+2)/(2m) memory accounting applies unchanged.
CONFIGS = {
    "S": ModelConfig("S", n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                     head_dim=32, d_ff=128, vocab=57, max_seq=640),
    "M": ModelConfig("M", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=256, vocab=57, max_seq=640),
    "L": ModelConfig("L", n_layers=8, d_model=128, n_heads=4, n_kv_heads=2,
                     head_dim=32, d_ff=256, vocab=57, max_seq=640),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Flat name → shape map; the single source of truth for the .bin format."""
    shapes: dict[str, tuple[int, ...]] = {"embed": (cfg.vocab, cfg.d_model)}
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes[p + "ln1"] = (cfg.d_model,)
        shapes[p + "wq"] = (cfg.d_model, cfg.q_dim)
        shapes[p + "wk"] = (cfg.d_model, cfg.kv_dim)
        shapes[p + "wv"] = (cfg.d_model, cfg.kv_dim)
        shapes[p + "wo"] = (cfg.q_dim, cfg.d_model)
        shapes[p + "ln2"] = (cfg.d_model,)
        shapes[p + "w1"] = (cfg.d_model, cfg.d_ff)
        shapes[p + "w3"] = (cfg.d_model, cfg.d_ff)
        shapes[p + "w2"] = (cfg.d_ff, cfg.d_model)
    shapes["lnf"] = (cfg.d_model,)
    return shapes


def init_params(key, cfg: ModelConfig) -> dict[str, jax.Array]:
    """Scaled-normal init; norms start at 1."""
    shapes = param_shapes(cfg)
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "lnf")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps=1e-5):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope_angles(cfg: ModelConfig, positions):
    """cos/sin tables for given positions [..., d/2] (split-half convention)."""
    half = cfg.head_dim // 2
    inv = cfg.rope_base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., head_dim]; cos/sin broadcastable to [..., head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(params, i, x):
    p = f"layer{i}."
    q = x @ params[p + "wq"]
    k = x @ params[p + "wk"]
    v = x @ params[p + "wv"]
    return q, k, v


# ---------------------------------------------------------------------------
# Full forward (training / prefill)
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, tokens):
    """Causal LM forward. tokens [B,T] int32 → logits [B,T,V].

    Also returns the per-layer K/V states (post-RoPE keys) for cache export:
    (logits, k_states [L,B,KV,T,m], v_states).
    """
    b, t = tokens.shape
    x = params["embed"][tokens]  # [B,T,d]
    pos = jnp.arange(t)
    cos, sin = rope_angles(cfg, pos)  # [T, m/2]
    causal = jnp.tril(jnp.ones((t, t), jnp.bool_))
    ks, vs = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q, k, v = _qkv(params, i, h)
        q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :, None], sin[None, :, None])
        k = apply_rope(k, cos[None, :, None], sin[None, :, None])
        ks.append(k.transpose(0, 2, 1, 3))  # [B,KV,T,m]
        vs.append(v.transpose(0, 2, 1, 3))
        group = cfg.n_heads // cfg.n_kv_heads
        kr = jnp.repeat(k, group, axis=2)
        vr = jnp.repeat(v, group, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, kr) / math.sqrt(cfg.head_dim)
        scores = jnp.where(causal[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", w, vr).reshape(b, t, cfg.q_dim)
        x = x + attn @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2"])
        gate = jax.nn.silu(h @ params[p + "w1"]) * (h @ params[p + "w3"])
        x = x + gate @ params[p + "w2"]
    x = rmsnorm(x, params["lnf"])
    logits = x @ params["embed"].T  # tied unembedding
    return logits, jnp.stack(ks), jnp.stack(vs)


def loss_fn(params, cfg: ModelConfig, x, y, w=None):
    """Weighted next-token cross-entropy (w=None ⇒ uniform)."""
    logits, _, _ = forward(params, cfg, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    if w is None:
        return nll.mean()
    return jnp.sum(nll * w) / jnp.sum(w)


# ---------------------------------------------------------------------------
# Adam (hand-rolled — this image has no optax)
# ---------------------------------------------------------------------------


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mh = {k: m[k] / (1 - b1 ** t.astype(jnp.float32)) for k in params}
    vh = {k: v[k] / (1 - b2 ** t.astype(jnp.float32)) for k in params}
    new = {k: params[k] - lr * mh[k] / (jnp.sqrt(vh[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def make_train_step(cfg: ModelConfig, peak_lr: float, total_steps: int):
    """Jitted fwd/bwd + Adam with cosine decay (the paper's dict-training
    recipe applied to the model itself)."""

    warmup = max(1, total_steps // 20)

    def step(params, opt, x, y, w):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, x, y, w)
        t = opt["t"].astype(jnp.float32)
        frac = jnp.clip((t - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        lr = peak_lr * jnp.minimum(t / warmup, 1.0) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    return jax.jit(step)


# ---------------------------------------------------------------------------
# Decode graphs (AOT-exported; executed from Rust via PJRT)
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ModelConfig, token, pos, k_cache, v_cache):
    """One autoregressive step with a dense (full-precision) KV cache.

    token [B] int32; pos [B] int32 (0-based index of this token);
    k_cache/v_cache [L,B,KV,Tmax,m]. Returns (logits [B,V], k_cache, v_cache)
    with the new K/V written at position ``pos``.
    """
    b = token.shape[0]
    t_max = k_cache.shape[3]
    x = params["embed"][token]  # [B,d]
    cos, sin = rope_angles(cfg, pos)  # [B, m/2]
    valid = jnp.arange(t_max)[None, :] <= pos[:, None]  # [B,Tmax]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q, k, v = _qkv(params, i, h)
        q = apply_rope(q.reshape(b, cfg.n_heads, cfg.head_dim), cos[:, None], sin[:, None])
        k = apply_rope(k.reshape(b, cfg.n_kv_heads, cfg.head_dim), cos[:, None], sin[:, None])
        v = v.reshape(b, cfg.n_kv_heads, cfg.head_dim)
        # scatter the new K/V at position pos
        onehot = (jnp.arange(t_max)[None, :] == pos[:, None]).astype(k.dtype)  # [B,T]
        k_cache = k_cache.at[i].add(onehot[:, None, :, None] * k[:, :, None, :])
        v_cache = v_cache.at[i].add(onehot[:, None, :, None] * v[:, :, None, :])
        group = cfg.n_heads // cfg.n_kv_heads
        kr = jnp.repeat(k_cache[i], group, axis=1)  # [B,H,T,m]
        vr = jnp.repeat(v_cache[i], group, axis=1)
        scores = jnp.einsum("bhd,bhtd->bht", q, kr) / math.sqrt(cfg.head_dim)
        scores = jnp.where(valid[:, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bht,bhtd->bhd", w, vr).reshape(b, cfg.q_dim)
        x = x + attn @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2"])
        gate = jax.nn.silu(h @ params[p + "w1"]) * (h @ params[p + "w3"])
        x = x + gate @ params[p + "w2"]
    x = rmsnorm(x, params["lnf"])
    return x @ params["embed"].T, k_cache, v_cache


def prefill(params, cfg: ModelConfig, tokens, n_valid):
    """Prefill graph: tokens [B,Tmax] (PAD beyond n_valid). Returns
    (logits at the last valid position [B,V], k_states, v_states
    [L,B,KV,Tmax,m]). Padding keys are left in the cache but masked by
    position bounds at decode time."""
    logits, ks, vs = forward(params, cfg, tokens)
    b = tokens.shape[0]
    last = jnp.take_along_axis(
        logits, (n_valid - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]
    del b
    return last, ks, vs


def lexico_decode_step(
    params, cfg: ModelConfig, d_k, d_v,
    token, pos,
    k_idx, k_val, v_idx, v_val, n_csr,
    k_buf, v_buf, n_buf,
):
    """One autoregressive step over the Lexico compressed cache (Eq. 7).

    d_k/d_v          [L, m, N]      per-layer dictionaries
    token, pos       [1]            (single-sequence graph)
    k_idx/k_val/...  [L, KV, Tc, s] CSR-as-dense compressed prefix
    n_csr            []             number of valid compressed tokens
    k_buf/v_buf      [L, KV, Tb, m] recency buffer (full precision)
    n_buf            []             number of valid buffer tokens *excluding*
                                    the new token (its slot is n_buf)

    Returns (logits [V], k_t [L,KV,m], v_t [L,KV,m]): the coordinator owns
    buffer append / OMP compression (Alg. 2), keeping this graph pure.

    Invalid CSR slots must carry value 0 (they then contribute exp(0)-free
    scores — we mask them to -inf here via n_csr); invalid buffer slots are
    masked likewise.
    """
    tc = k_idx.shape[2]
    tb = k_buf.shape[2]
    x = params["embed"][token][0]  # [d]
    cos, sin = rope_angles(cfg, pos)  # [1, m/2]
    k_out, v_out = [], []
    mask_c = jnp.arange(tc) < n_csr          # [Tc]
    mask_b = jnp.arange(tb) <= n_buf          # [Tb] (includes the new token)
    neg = jnp.float32(-1e30)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = rmsnorm(x, params[p + "ln1"])
        q = (h @ params[p + "wq"]).reshape(cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(cfg.n_kv_heads, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        k_out.append(k)
        v_out.append(v)
        # place the new token's K/V into its buffer slot
        slot = (jnp.arange(tb) == n_buf).astype(k.dtype)  # [Tb]
        kb = k_buf[i] * (1.0 - slot)[None, :, None] + slot[None, :, None] * k[:, None, :]
        vb = v_buf[i] * (1.0 - slot)[None, :, None] + slot[None, :, None] * v[:, None, :]
        # split attention via the L1 Pallas kernel; validity masking enters
        # as additive score biases (0 for valid slots, -1e30 otherwise).
        bias_c = jnp.where(mask_c, 0.0, neg)
        bias_b = jnp.where(mask_b, 0.0, neg)
        attn = lexico_decode_attn(
            q, k_idx[i], k_val[i], v_idx[i], v_val[i], d_k[i], d_v[i],
            kb, vb, bias_c, bias_b,
        ).reshape(cfg.q_dim)
        x = x + attn @ params[p + "wo"]
        h = rmsnorm(x, params[p + "ln2"])
        gate = jax.nn.silu(h @ params[p + "w1"]) * (h @ params[p + "w3"])
        x = x + gate @ params[p + "w2"]
    x = rmsnorm(x, params["lnf"])
    return x @ params["embed"].T, jnp.stack(k_out), jnp.stack(v_out)


# ---------------------------------------------------------------------------
# Greedy generation (python-side sanity evals only)
# ---------------------------------------------------------------------------


def generate_greedy(params, cfg: ModelConfig, prompt_ids, max_new: int, stop_id=None):
    """Slow reference generation used by build-time sanity checks."""
    ids = list(prompt_ids)
    fwd = jax.jit(lambda p, t: forward(p, cfg, t)[0])
    for _ in range(max_new):
        t = jnp.asarray([ids], jnp.int32)
        logits = fwd(params, t)
        nxt = int(jnp.argmax(logits[0, len(ids) - 1]))
        ids.append(nxt)
        if stop_id is not None and nxt == stop_id:
            break
    return ids[len(prompt_ids):]
