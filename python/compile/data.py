"""Synthetic task suite + tokenizer — the data contract shared with Rust.

This module is the *single source of truth* for the byte-level vocabulary
(exported to ``artifacts/vocab.txt`` by ``aot.py`` and asserted equal by the
Rust test suite) and the Python-side generators of the synthetic workloads
that substitute for the paper's corpora (see DESIGN.md §1):

  * ``lm``          — Markov-chain "prose" (WikiText-103 substitute; the
                      dictionary-training and LM-perplexity corpus)
  * ``arith``       — multi-step arithmetic chains (GSM8K substitute)
  * ``arith_hard``  — deeper chains (MMLU-Pro Engineering substitute)
  * ``needle``      — key/value recall over long distractor context
                      (TREC/TriviaQA-style retrieval substitute)
  * ``copy``        — long-range verbatim completion (LCC/RepoBench substitute)
  * ``sort``        — digit sorting (MMLU-Pro Law substitute)

Generators are seeded with SplitMix64 so the corpus is reproducible; the
Rust evaluation harness uses *different* seeds/streams, so evaluation data
is automatically held out from training data.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary
# ---------------------------------------------------------------------------

PAD, BOS, EOS = 0, 1, 2
SPECIALS = 3
# Order matters: id(ch) = SPECIALS + VOCAB_CHARS.index(ch).
VOCAB_CHARS = "\n abcdefghijklmnopqrstuvwxyz0123456789=+-*;:,.?#()<>[]"
VOCAB_SIZE = SPECIALS + len(VOCAB_CHARS)  # 57

_CH2ID = {c: SPECIALS + i for i, c in enumerate(VOCAB_CHARS)}
_ID2CH = {SPECIALS + i: c for i, c in enumerate(VOCAB_CHARS)}


def encode(text: str) -> list[int]:
    """Map text to token ids. Raises on out-of-vocabulary characters."""
    return [_CH2ID[c] for c in text]


def decode(ids) -> str:
    """Inverse of :func:`encode`; specials render as empty."""
    return "".join(_ID2CH.get(int(i), "") for i in ids)


# ---------------------------------------------------------------------------
# SplitMix64 — tiny, portable PRNG (same algorithm as rust/src/util/rng.rs)
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1


class SplitMix64:
    """Deterministic 64-bit PRNG used by every generator in this repo."""

    def __init__(self, seed: int):
        self.state = seed & _M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Uniform integer in [0, n)."""
        return self.next_u64() % n

    def choice(self, seq):
        return seq[self.below(len(seq))]

    def uniform(self) -> float:
        return self.next_u64() / float(1 << 64)


# ---------------------------------------------------------------------------
# lm task — Markov "prose"
# ---------------------------------------------------------------------------

# A compact word list; the Markov transition structure below gives the text
# non-trivial bigram statistics for the language-modeling proxy task.
_WORDS = (
    "the a one this that red blue green small large old new dark cold "
    "fox dog cat bird fish tree river stone house door city road cloud "
    "runs jumps sleeps sings falls rises moves turns stands waits "
    "over under near beside into from with without through around "
    "quickly slowly quietly loudly gently always never often soon "
    "and but then while because"
).split()

_KINDS = {}  # word -> syntactic class index
for _w in _WORDS[:14]:
    _KINDS[_w] = 0  # determiners/adjectives
for _w in _WORDS[14:28]:
    _KINDS[_w] = 1  # nouns
for _w in _WORDS[28:38]:
    _KINDS[_w] = 2  # verbs
for _w in _WORDS[38:48]:
    _KINDS[_w] = 3  # prepositions
for _w in _WORDS[48:58]:
    _KINDS[_w] = 4  # adverbs
for _w in _WORDS[58:]:
    _KINDS[_w] = 5  # conjunctions

_BY_KIND = [[w for w in _WORDS if _KINDS[w] == k] for k in range(6)]
# kind -> plausible successor kinds (weighted by repetition)
_NEXT = {
    0: [0, 1, 1, 1],
    1: [2, 2, 2, 3],
    2: [3, 3, 4, 5],
    3: [0, 0, 1, 1],
    4: [5, 0, 2, 3],
    5: [0, 0, 1, 4],
}


def gen_lm_text(rng: SplitMix64, n_chars: int) -> str:
    """Markov-chain prose of roughly ``n_chars`` characters."""
    out: list[str] = []
    total = 0
    while total < n_chars:
        kind = 0
        sent_len = 5 + rng.below(9)
        words = []
        for _ in range(sent_len):
            words.append(rng.choice(_BY_KIND[kind]))
            kind = rng.choice(_NEXT[kind])
        s = " ".join(words) + ". "
        out.append(s)
        total += len(s)
    return "".join(out)[:n_chars]


# ---------------------------------------------------------------------------
# arith task — multi-step arithmetic chains (values mod 100)
# ---------------------------------------------------------------------------

_VARS = "abcdefghij"


def gen_arith_example(rng: SplitMix64, n_steps: int) -> tuple[str, str]:
    """One chain. Returns (prompt_without_answer, answer_string).

    Format: ``a=3;b=a+4;c=b*2;c?`` → answer ``14``. All values mod 100.
    """
    vals: dict[str, int] = {}
    parts = []
    for i in range(n_steps):
        var = _VARS[i]
        if i == 0:
            v = 1 + rng.below(9)
            parts.append(f"{var}={v}")
        else:
            src = _VARS[rng.below(i)]
            op = rng.choice("+-*")
            operand = 1 + rng.below(9)
            if op == "+":
                v = (vals[src] + operand) % 100
            elif op == "-":
                v = (vals[src] - operand) % 100
            else:
                v = (vals[src] * operand) % 100
            parts.append(f"{var}={src}{op}{operand}")
        vals[var] = v
    q = _VARS[n_steps - 1]
    return ";".join(parts) + f";{q}?", str(vals[q])


def gen_arith_prompt(
    rng: SplitMix64, n_steps: int, n_shots: int
) -> tuple[str, str]:
    """Few-shot prompt: k solved chains, then an unsolved one."""
    shots = []
    for _ in range(n_shots):
        p, a = gen_arith_example(rng, n_steps)
        shots.append(p + a)
    query, answer = gen_arith_example(rng, n_steps)
    return "\n".join(shots + [query]), answer


# ---------------------------------------------------------------------------
# needle task — key/value recall
# ---------------------------------------------------------------------------


def gen_needle_example(rng: SplitMix64, n_pairs: int) -> tuple[str, str]:
    """``k17=v42;k83=v07;...;k17?`` → ``v42``. Keys are distinct 2-digit."""
    keys = list(range(100))
    # Fisher–Yates shuffle with our PRNG.
    for i in range(99, 0, -1):
        j = rng.below(i + 1)
        keys[i], keys[j] = keys[j], keys[i]
    keys = keys[:n_pairs]
    pairs = [(k, rng.below(100)) for k in keys]
    ctx = ";".join(f"k{k:02d}=v{v:02d}" for k, v in pairs)
    qk, qv = pairs[rng.below(n_pairs)]
    return f"{ctx};k{qk:02d}?", f"v{qv:02d}"


# ---------------------------------------------------------------------------
# copy task — verbatim long-range completion
# ---------------------------------------------------------------------------


def gen_copy_example(rng: SplitMix64, n_chars: int) -> tuple[str, str]:
    """``<random letters>#`` → the same letters again."""
    s = "".join(
        VOCAB_CHARS[2 + rng.below(26)] for _ in range(n_chars)
    )  # letters a..z
    return s + "#", s


# ---------------------------------------------------------------------------
# sort task
# ---------------------------------------------------------------------------


def gen_sort_example(rng: SplitMix64, n_digits: int) -> tuple[str, str]:
    """``7,3,9,1>`` → ``1,3,7,9``."""
    ds = [rng.below(10) for _ in range(n_digits)]
    return ",".join(map(str, ds)) + ">", ",".join(map(str, sorted(ds)))


# ---------------------------------------------------------------------------
# Mixed training corpus
# ---------------------------------------------------------------------------

TASK_NAMES = ("lm", "arith", "arith_hard", "needle", "copy", "sort")


def gen_training_document(rng: SplitMix64) -> str:
    """One training document: a solved task instance (or prose).

    Mixture is retrieval-heavy: induction-style skills (needle/copy) need
    the most gradient signal at these model scales."""
    r = rng.below(10)
    if r < 2:
        return gen_lm_text(rng, 120 + rng.below(140))
    if r < 4:
        # half the time, a few-shot style document (solved chains separated
        # by newlines) so the eval-time few-shot format is in-distribution
        if rng.below(2) == 0:
            p, a = gen_arith_example(rng, 2 + rng.below(4))
            return p + a
        chains = [
            "".join(gen_arith_example(rng, 3 + rng.below(2)))
            for _ in range(2 + rng.below(3))
        ]
        return "\n".join(chains)
    if r == 4:
        p, a = gen_arith_example(rng, 5 + rng.below(4))  # hard variant
        return p + a
    if r < 8:
        p, a = gen_needle_example(rng, 4 + rng.below(28))
        return p + a
    if r == 8:
        p, a = gen_copy_example(rng, 8 + rng.below(32))
        return p + a
    p, a = gen_sort_example(rng, 3 + rng.below(6))
    return p + a


def token_stream(seed: int, n_tokens: int) -> np.ndarray:
    """Concatenate BOS-separated training documents into a token stream."""
    rng = SplitMix64(seed)
    toks: list[int] = []
    while len(toks) < n_tokens:
        toks.append(BOS)
        toks.extend(encode(gen_training_document(rng)))
        toks.append(_CH2ID["\n"])
    return np.asarray(toks[:n_tokens], dtype=np.int32)


#: loss weight for answer spans (tokens after a query marker ?/>/# up to
#: the newline). Answers are the only positions where task *competence*
#: (rather than format) shows up in the loss; upweighting them sharpens the
#: learning signal for retrieval/induction enormously at our tiny scale.
ANSWER_WEIGHT = 8.0
_QUERY_MARKS = {_CH2ID[c] for c in "?>#"}
_NL = _CH2ID["\n"]


def answer_weights(stream: np.ndarray) -> np.ndarray:
    """Per-position loss weights for a token stream (weight of predicting
    ``stream[i]`` given the prefix): ANSWER_WEIGHT inside answer spans."""
    w = np.ones(len(stream), dtype=np.float32)
    in_ans = False
    for i, t in enumerate(stream):
        if in_ans:
            w[i] = ANSWER_WEIGHT
        if t in _QUERY_MARKS:
            in_ans = True
        elif t == _NL or t == BOS:
            in_ans = False
    return w


def training_batches(seed: int, n_tokens: int, batch: int, seq: int):
    """Yield (x, y, w) next-token batches carved from the token stream."""
    stream = token_stream(seed, n_tokens)
    weights = answer_weights(stream)
    per = batch * seq
    n = (len(stream) - 1) // per
    for i in range(n):
        chunk = stream[i * per : i * per + per + 1]
        x = chunk[:-1].reshape(batch, seq)
        y = chunk[1:].reshape(batch, seq)
        w = weights[i * per + 1 : i * per + per + 1].reshape(batch, seq)
        yield x, y, w


# Disjoint corpora for the Table 1 reconstruction-error protocol. Each is a
# different *distribution* (WikiText / CNN-DailyMail / IMDB / TweetEval
# substitutes): prose, arithmetic, retrieval, mixed-short.
TABLE1_CORPORA = {
    "prose": lambda rng: gen_lm_text(rng, 200),
    "arith": lambda rng: "\n".join(
        p + a for p, a in (gen_arith_example(rng, 3 + rng.below(4)) for _ in range(6))
    ),
    "retrieval": lambda rng: ";".join(
        p + a for p, a in (gen_needle_example(rng, 10 + rng.below(20)) for _ in range(2))
    ),
    "mixed": lambda rng: "\n".join(
        [
            gen_sort_example(rng, 4 + rng.below(5))[0],
            gen_copy_example(rng, 10 + rng.below(20))[0],
            gen_lm_text(rng, 80),
        ]
    ),
}


def corpus_tokens(name: str, seed: int, n_tokens: int) -> np.ndarray:
    """Token stream drawn from one of the Table-1 corpora."""
    gen = TABLE1_CORPORA[name]
    rng = SplitMix64(seed)
    toks: list[int] = []
    while len(toks) < n_tokens:
        toks.append(BOS)
        toks.extend(encode(gen(rng)))
    return np.asarray(toks[:n_tokens], dtype=np.int32)
