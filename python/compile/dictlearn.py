"""Dictionary learning for Lexico (paper §3.3, Fig. 4) + Table 1 baselines.

Trains per-layer key/value dictionaries by direct gradient-based
optimization: each step OMP-encodes a batch of KV vectors against the
current dictionary, then takes an Adam step on the ℓ2 reconstruction loss
with gradient components parallel to each atom removed (the paper's
unit-norm enforcement), followed by re-normalization.

Also implements the Table 1 baselines:
  * sparse autoencoder (two-layer perceptron, hard top-k activation);
  * random unit-norm dictionaries.

The OMP encoder here (``omp_jnp``) is the same inverse-Gram algorithm as the
L1 Pallas kernel, written as plain jnp so the training loop jits tightly on
CPU; equivalence of the two (and of both against the textbook oracle in
``kernels/ref.py``) is asserted by the test suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod


# ---------------------------------------------------------------------------
# Batched OMP in plain jnp (jit-friendly; same math as kernels/omp.py)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2,), static_argnames=("delta",))
def omp_jnp(D, X, s: int, delta: float = 0.0):
    """OMP over rows of X [B,m] w.r.t. D [m,N] (unit-norm columns).

    Returns (idx [B,s] i32, val [B,s] f32, nnz [B] i32)."""
    b, m = X.shape
    n = D.shape[1]
    f = X.dtype
    norm_x = jnp.sqrt(jnp.sum(X * X, axis=1))

    def body(i, carry):
        sel, sel_d, g_inv, y, r, mask, nnz = carry
        r_norm = jnp.sqrt(jnp.sum(r * r, axis=1))
        active = r_norm > jnp.maximum(delta * norm_x, 1e-12)
        c = jnp.abs(r @ D)
        c = jnp.where(mask, -jnp.inf, c)
        j = jnp.argmax(c, axis=1)
        dj = jnp.take(D.T, j, axis=0)
        e_i = jax.nn.one_hot(i, s, dtype=f)
        bb = jnp.einsum("tsm,tm->ts", sel_d, dj)
        u = jnp.einsum("tsk,tk->ts", g_inv, bb)
        beta = jnp.maximum(1.0 - jnp.sum(bb * u, axis=1), 1e-8)[:, None, None]
        upd = (
            u[:, :, None] * u[:, None, :]
            - u[:, :, None] * e_i[None, None, :]
            - e_i[None, :, None] * u[:, None, :]
            + e_i[None, :, None] * e_i[None, None, :]
        ) / beta
        g_inv_n = g_inv + upd
        sel_d_n = sel_d + e_i[None, :, None] * dj[:, None, :]
        sel_n = sel + e_i.astype(jnp.int32)[None, :] * j[:, None].astype(jnp.int32)
        alpha = jnp.einsum("tsm,tm->ts", sel_d_n, X)
        y_n = jnp.einsum("tsk,tk->ts", g_inv_n, alpha)
        r_n = X - jnp.einsum("ts,tsm->tm", y_n, sel_d_n)
        mask_n = mask | jax.nn.one_hot(j, n, dtype=jnp.bool_)
        a1, a2 = active[:, None], active[:, None, None]
        return (
            jnp.where(a1, sel_n, sel),
            jnp.where(a2, sel_d_n, sel_d),
            jnp.where(a2, g_inv_n, g_inv),
            jnp.where(a1, y_n, y),
            jnp.where(a1, r_n, r),
            jnp.where(a1, mask_n, mask),
            nnz + active.astype(jnp.int32),
        )

    init = (
        jnp.zeros((b, s), jnp.int32),
        jnp.zeros((b, s, m), f),
        jnp.zeros((b, s, s), f),
        jnp.zeros((b, s), f),
        X,
        jnp.zeros((b, n), jnp.bool_),
        jnp.zeros((b,), jnp.int32),
    )
    sel, _, _, y, _, _, nnz = jax.lax.fori_loop(0, s, body, init)
    return sel, y, nnz


def reconstruct_jnp(D, idx, val):
    """X̂ [B,m] from sparse codes."""
    return jnp.einsum("bs,bsm->bm", val, jnp.take(D.T, idx, axis=0))


def rel_error_jnp(D, X, idx, val):
    err = jnp.linalg.norm(X - reconstruct_jnp(D, idx, val), axis=-1)
    return err / jnp.maximum(jnp.linalg.norm(X, axis=-1), 1e-12)


# ---------------------------------------------------------------------------
# KV-vector collection (training data for the dictionaries)
# ---------------------------------------------------------------------------


def collect_kv(params, cfg, seed: int, n_tokens: int, seq: int = 256):
    """Run the model over the synthetic corpus and gather per-layer K/V states.

    Returns (K [L, n_vecs, m], V [L, n_vecs, m]) — kv-heads flattened into
    the vector axis (the paper's dictionaries are per-layer, shared across
    heads)."""
    fwd = jax.jit(lambda p, t: model_mod.forward(p, cfg, t)[1:])
    ks, vs = [], []
    stream = data_mod.token_stream(seed, n_tokens)
    n_chunks = len(stream) // seq
    for c in range(n_chunks):
        toks = jnp.asarray(stream[c * seq : (c + 1) * seq][None], jnp.int32)
        k, v = fwd(params, toks)  # [L,1,KV,T,m]
        ks.append(np.asarray(k[:, 0]))  # [L,KV,T,m]
        vs.append(np.asarray(v[:, 0]))
    k = np.concatenate(ks, axis=2)  # [L,KV,T_total,m]
    v = np.concatenate(vs, axis=2)
    ll, kv, tt, m = k.shape
    return k.reshape(ll, kv * tt, m), v.reshape(ll, kv * tt, m)


# ---------------------------------------------------------------------------
# Lexico dictionary training (OMP encoder + projected Adam)
# ---------------------------------------------------------------------------


def init_dictionary(key, m: int, n: int):
    """Uniform init (PyTorch linear-layer default), unit-norm columns."""
    lim = 1.0 / np.sqrt(m)
    d = jax.random.uniform(key, (m, n), jnp.float32, -lim, lim)
    return d / jnp.linalg.norm(d, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnums=(4,))
def _dict_step(D, opt, X, lr, s: int):
    """One training step: OMP encode (stop-grad), ℓ2 loss, projected Adam."""
    idx, val, _ = omp_jnp(D, X, s)

    def loss(d):
        return jnp.mean(jnp.sum((X - reconstruct_jnp(d, idx, val)) ** 2, axis=1))

    l, g = jax.value_and_grad(loss)(D)
    # remove gradient components parallel to each atom (unit-norm tangent)
    par = jnp.sum(g * D, axis=0, keepdims=True)
    g = g - par * D
    new_d, opt = model_mod.adam_update({"d": D}, {"d": g}, opt, lr)
    d = new_d["d"]
    d = d / jnp.maximum(jnp.linalg.norm(d, axis=0, keepdims=True), 1e-8)
    return d, opt, l


def train_dictionary(
    vectors: np.ndarray,
    n_atoms: int,
    s: int,
    epochs: int = 12,
    batch: int = 256,
    lr: float = 1e-4,
    seed: int = 0,
    log=None,
):
    """Train one dictionary on ``vectors`` [n,m]. Paper recipe: Adam with
    cosine decay over the epochs, lr 1e-4."""
    n_vec, m = vectors.shape
    key = jax.random.PRNGKey(seed)
    d = init_dictionary(key, m, n_atoms)
    opt = model_mod.adam_init({"d": d})
    n_batches = max(1, n_vec // batch)
    total = epochs * n_batches
    step_i = 0
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        perm = rng.permutation(n_vec)
        ep_loss = 0.0
        for bi in range(n_batches):
            xb = jnp.asarray(vectors[perm[bi * batch : (bi + 1) * batch]])
            cur_lr = lr * 0.5 * (1.0 + np.cos(np.pi * step_i / total))
            d, opt, l = _dict_step(d, opt, xb, cur_lr, s)
            ep_loss += float(l)
            step_i += 1
        if log:
            log(f"  dict epoch {ep+1}/{epochs} loss {ep_loss / n_batches:.5f}")
    return np.asarray(d)


# ---------------------------------------------------------------------------
# Table 1 baselines
# ---------------------------------------------------------------------------


def random_dictionary(m: int, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((m, n)).astype(np.float32)
    return d / np.linalg.norm(d, axis=0, keepdims=True)


def _topk_hard(z, k: int):
    """K-sparse autoencoder activation: keep top-k by |activation|."""
    vals = jax.lax.top_k(jnp.abs(z), k)[0]
    thresh = vals[..., -1][..., None]
    return jnp.where(jnp.abs(z) >= thresh, z, 0.0)


@functools.partial(jax.jit, static_argnums=(4,))
def _sae_step(enc, dec, opt, X, s: int, lr=1e-3):  # noqa: D401
    def loss(params):
        e, d = params["enc"], params["dec"]
        y = _topk_hard(X @ e, s)
        return jnp.mean(jnp.sum((X - y @ d.T) ** 2, axis=1))

    l, g = jax.value_and_grad(loss)({"enc": enc, "dec": dec})
    new, opt = model_mod.adam_update({"enc": enc, "dec": dec}, g, opt, lr)
    d = new["dec"]
    d = d / jnp.maximum(jnp.linalg.norm(d, axis=0, keepdims=True), 1e-8)
    return new["enc"], d, opt, l


def train_sae(vectors: np.ndarray, n_atoms: int, s: int, epochs: int = 12,
              batch: int = 256, seed: int = 0, lr: float = 3e-3):
    """Two-layer perceptron with hard top-k activation (Table 1 baseline).

    Returns (encoder [m,N], decoder [m,N]); reconstruction uses
    ``topk(x·enc) @ decᵀ``."""
    n_vec, m = vectors.shape
    key = jax.random.PRNGKey(seed)
    enc = jnp.asarray(np.asarray(init_dictionary(key, m, n_atoms)))
    dec = init_dictionary(jax.random.PRNGKey(seed + 1), m, n_atoms)
    opt = model_mod.adam_init({"enc": enc, "dec": dec})
    rng = np.random.default_rng(seed)
    n_batches = max(1, n_vec // batch)
    for _ in range(epochs):
        perm = rng.permutation(n_vec)
        for bi in range(n_batches):
            xb = jnp.asarray(vectors[perm[bi * batch : (bi + 1) * batch]])
            enc, dec, opt, _ = _sae_step(enc, dec, opt, xb, s, lr)
    return np.asarray(enc), np.asarray(dec)


def sae_rel_error(enc, dec, X, s: int) -> np.ndarray:
    y = _topk_hard(jnp.asarray(X) @ jnp.asarray(enc), s)
    recon = y @ jnp.asarray(dec).T
    err = jnp.linalg.norm(jnp.asarray(X) - recon, axis=-1)
    return np.asarray(err / jnp.maximum(jnp.linalg.norm(jnp.asarray(X), axis=-1), 1e-12))
