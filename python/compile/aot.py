"""AOT build pipeline: train models + dictionaries, export weights and HLO.

Runs ONCE at build time (``make artifacts``); the Rust serving binary is
self-contained afterwards. Produces, under ``artifacts/``:

  vocab.txt                    tokenizer contract (asserted by Rust tests)
  model_{S,M,L}.bin            trained transformer weights (LXMW format)
  dict_{size}_N{n}.bin         per-layer K/V Lexico dictionaries (LXDC)
  sae_M_N{n}.bin               sparse-autoencoder baseline (LXSA, Table 1)
  model.hlo.txt                M-model single-token decode graph (dense cache)
  prefill_M.hlo.txt            M-model prefill graph
  omp_M.hlo.txt                L1 Pallas OMP kernel, lowered standalone
  lexico_decode_M.hlo.txt      full Lexico decode step (Eq. 7, calls L1 kernel)
  grads_M.hlo.txt              loss+grad graph (the L2 bwd, for completeness)
  manifest.json                input/output orderings + static dims per graph

HLO is exported as *text*: jax>=0.5 serialized protos carry 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Env knobs: LEXICO_SIZES=S,M,L  LEXICO_STEPS_<SIZE>  LEXICO_DICT_EPOCHS
           LEXICO_FORCE=1 (retrain even if .bin exists)
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import dictlearn
from . import model as model_mod
from .kernels.omp import omp_pallas_call

# ---------------------------------------------------------------------------
# Binary formats (readers live in rust/src/model/weights.rs, rust/src/dict/)
# ---------------------------------------------------------------------------


def _write_tensor(f, name: str, arr: np.ndarray):
    arr = np.ascontiguousarray(arr, dtype=np.float32)
    nb = name.encode()
    f.write(struct.pack("<I", len(nb)))
    f.write(nb)
    f.write(struct.pack("<I", arr.ndim))
    f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
    f.write(arr.tobytes())


def save_model_bin(path: str, cfg: model_mod.ModelConfig, params: dict):
    with open(path, "wb") as f:
        f.write(b"LXMW")
        f.write(
            struct.pack(
                "<9I", 1, cfg.n_layers, cfg.d_model, cfg.n_heads,
                cfg.n_kv_heads, cfg.head_dim, cfg.d_ff, cfg.vocab, cfg.max_seq,
            )
        )
        names = sorted(params)
        f.write(struct.pack("<I", len(names)))
        for name in names:
            _write_tensor(f, name, np.asarray(params[name]))


def load_model_bin(path: str):
    """Python-side reader (used by tests and incremental builds)."""
    with open(path, "rb") as f:
        assert f.read(4) == b"LXMW"
        ver, nl, dm, nh, nkv, hd, ff, vocab, ms = struct.unpack("<9I", f.read(36))
        assert ver == 1
        cfg = model_mod.ModelConfig("?", nl, dm, nh, nkv, hd, ff, vocab, ms)
        (n_tensors,) = struct.unpack("<I", f.read(4))
        params = {}
        for _ in range(n_tensors):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (rank,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{rank}I", f.read(4 * rank))
            n = int(np.prod(shape))
            params[name] = np.frombuffer(f.read(4 * n), np.float32).reshape(shape)
        return cfg, params


def save_dict_bin(path: str, d_k: np.ndarray, d_v: np.ndarray):
    """d_k/d_v: [L, m, N] float32, unit-norm columns."""
    ll, m, n = d_k.shape
    with open(path, "wb") as f:
        f.write(b"LXDC")
        f.write(struct.pack("<4I", 1, ll, m, n))
        f.write(np.ascontiguousarray(d_k, np.float32).tobytes())
        f.write(np.ascontiguousarray(d_v, np.float32).tobytes())


def load_dict_bin(path: str):
    with open(path, "rb") as f:
        assert f.read(4) == b"LXDC"
        ver, ll, m, n = struct.unpack("<4I", f.read(16))
        assert ver == 1
        sz = ll * m * n
        d_k = np.frombuffer(f.read(4 * sz), np.float32).reshape(ll, m, n)
        d_v = np.frombuffer(f.read(4 * sz), np.float32).reshape(ll, m, n)
        return d_k, d_v


def save_sae_bin(path: str, enc_k, dec_k, enc_v, dec_v):
    m, n = enc_k.shape
    with open(path, "wb") as f:
        f.write(b"LXSA")
        f.write(struct.pack("<3I", 1, m, n))
        for a in (enc_k, dec_k, enc_v, dec_v):
            f.write(np.ascontiguousarray(a, np.float32).tobytes())


# ---------------------------------------------------------------------------
# HLO lowering helper (text interchange — see module docstring)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x):
    return jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype if not hasattr(x, "dtype") else x.dtype)


# ---------------------------------------------------------------------------
# Model training
# ---------------------------------------------------------------------------

DEFAULT_STEPS = {"S": 900, "M": 3200, "L": 1300}
TRAIN_BATCH, TRAIN_SEQ = 4, 256
TRAIN_SEED = 42


def sanity_eval(params, cfg, seed=7, n=12) -> dict:
    """Quick greedy-decoding accuracy on arith + needle (build-time log)."""
    rng = data_mod.SplitMix64(seed)
    nl = data_mod.encode("\n")[0]
    correct_a = correct_n = 0
    for _ in range(n):
        p, a = data_mod.gen_arith_prompt(rng, 3, 2)
        out = model_mod.generate_greedy(
            params, cfg, [data_mod.BOS] + data_mod.encode(p), 6, stop_id=nl)
        if data_mod.decode(out).rstrip("\n") == a:
            correct_a += 1
        p, a = data_mod.gen_needle_example(rng, 10)
        out = model_mod.generate_greedy(
            params, cfg, [data_mod.BOS] + data_mod.encode(p), 6, stop_id=nl)
        if data_mod.decode(out).rstrip("\n") == a:
            correct_n += 1
    return {"arith": correct_a / n, "needle": correct_n / n}


def train_model(size: str, steps: int, log) -> tuple:
    cfg = model_mod.CONFIGS[size]
    params = model_mod.init_params(jax.random.PRNGKey(hash(size) % 2**31), cfg)
    log(f"[{size}] {cfg.param_count(params)} params, {steps} steps")
    step = model_mod.make_train_step(cfg, 1.5e-3, steps)
    opt = model_mod.adam_init(params)
    n_tokens = steps * TRAIN_BATCH * TRAIN_SEQ + 1
    t0 = time.time()
    for i, (x, y, w) in enumerate(
        data_mod.training_batches(TRAIN_SEED, n_tokens, TRAIN_BATCH, TRAIN_SEQ)
    ):
        if i >= steps:
            break
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y),
                                 jnp.asarray(w))
        if i % 200 == 0 or i == steps - 1:
            log(f"[{size}] step {i} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    acc = sanity_eval(params, cfg)
    log(f"[{size}] sanity: arith {acc['arith']:.2f} needle {acc['needle']:.2f}")
    return cfg, {k: np.asarray(v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# Dictionary training per model
# ---------------------------------------------------------------------------

DICT_SPARSITY = 8          # paper: s = m/4 during dictionary training
DICT_TOKENS = 4096         # corpus size for KV collection
DICT_SEED = 1234           # distinct from TRAIN_SEED → held-out-ish corpus


def build_dicts(size: str, cfg, params, n_atoms: int, epochs: int, log, art: str) -> str:
    path = f"{art}/dict_{size}_N{n_atoms}.bin"
    if os.path.exists(path) and not os.environ.get("LEXICO_FORCE"):
        log(f"[{size}] {path} exists, skip")
        return path
    kvecs, vvecs = dictlearn.collect_kv(params, cfg, DICT_SEED, DICT_TOKENS)
    # The paper trains with lr 1e-4 at (m=128, N≤4096, WikiText scale); at
    # our smaller scale that underfits badly — 3e-3 with cosine decay
    # reaches much lower reconstruction error in the same epochs.
    lr = float(os.environ.get("LEXICO_DICT_LR", "3e-3"))
    d_ks, d_vs = [], []
    for layer in range(cfg.n_layers):
        for vecs, acc in ((kvecs[layer], d_ks), (vvecs[layer], d_vs)):
            d = dictlearn.train_dictionary(
                vecs, n_atoms, DICT_SPARSITY, epochs=epochs, lr=lr,
                seed=layer, log=None)
            acc.append(d)
        log(f"[{size}] N={n_atoms} layer {layer} dicts done")
    save_dict_bin(path, np.stack(d_ks), np.stack(d_vs))
    return path


# ---------------------------------------------------------------------------
# HLO graph exports (M model)
# ---------------------------------------------------------------------------

HLO_TC, HLO_TB, HLO_S, HLO_N = 512, 64, 8, 1024
OMP_BATCH = 64


def export_hlo(cfg, params, out_main: str, log) -> dict:
    manifest: dict = {"graphs": {}}
    names = sorted(params)
    wspecs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    art = os.path.dirname(out_main) or "."
    ll, kv, m = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    t_max = cfg.max_seq

    def lower(fn, specs):
        return to_hlo_text(jax.jit(fn).lower(*specs))

    def record(fname, text, inputs, outputs, const=None):
        with open(os.path.join(art, fname), "w") as f:
            f.write(text)
        manifest["graphs"][fname] = {
            "inputs": inputs, "outputs": outputs, "const": const or {},
        }
        log(f"wrote {fname} ({len(text)} chars)")

    i32, f32 = jnp.int32, jnp.float32
    winfo = [{"name": n, "shape": list(params[n].shape), "dtype": "f32"} for n in names]

    # ---- dense-cache decode step (the Makefile sentinel) -----------------
    def dec(*args):
        ws = dict(zip(names, args[: len(names)]))
        token, pos, kc, vc = args[len(names):]
        return model_mod.decode_step(ws, cfg, token, pos, kc, vc)

    cache_spec = jax.ShapeDtypeStruct((ll, 1, kv, t_max, m), f32)
    text = lower(dec, wspecs + [
        jax.ShapeDtypeStruct((1,), i32), jax.ShapeDtypeStruct((1,), i32),
        cache_spec, cache_spec,
    ])
    record(os.path.basename(out_main), text,
           winfo + [
               {"name": "token", "shape": [1], "dtype": "i32"},
               {"name": "pos", "shape": [1], "dtype": "i32"},
               {"name": "k_cache", "shape": [ll, 1, kv, t_max, m], "dtype": "f32"},
               {"name": "v_cache", "shape": [ll, 1, kv, t_max, m], "dtype": "f32"},
           ],
           [{"name": "logits", "shape": [1, cfg.vocab], "dtype": "f32"},
            {"name": "k_cache", "shape": [ll, 1, kv, t_max, m], "dtype": "f32"},
            {"name": "v_cache", "shape": [ll, 1, kv, t_max, m], "dtype": "f32"}],
           {"t_max": t_max})

    # ---- prefill ----------------------------------------------------------
    def pre(*args):
        ws = dict(zip(names, args[: len(names)]))
        tokens, n_valid = args[len(names):]
        return model_mod.prefill(ws, cfg, tokens, n_valid)

    text = lower(pre, wspecs + [
        jax.ShapeDtypeStruct((1, t_max), i32), jax.ShapeDtypeStruct((1,), i32)])
    record("prefill_M.hlo.txt", text,
           winfo + [
               {"name": "tokens", "shape": [1, t_max], "dtype": "i32"},
               {"name": "n_valid", "shape": [1], "dtype": "i32"},
           ],
           [{"name": "last_logits", "shape": [1, cfg.vocab], "dtype": "f32"},
            {"name": "k_states", "shape": [ll, 1, kv, t_max, m], "dtype": "f32"},
            {"name": "v_states", "shape": [ll, 1, kv, t_max, m], "dtype": "f32"}],
           {"t_max": t_max})

    # ---- standalone Pallas OMP kernel -------------------------------------
    call = omp_pallas_call(m, HLO_N, OMP_BATCH, HLO_S, 0.0, tile=OMP_BATCH)
    text = to_hlo_text(jax.jit(call).lower(
        jax.ShapeDtypeStruct((m, HLO_N), f32),
        jax.ShapeDtypeStruct((OMP_BATCH, m), f32)))
    record("omp_M.hlo.txt", text,
           [{"name": "dict", "shape": [m, HLO_N], "dtype": "f32"},
            {"name": "x", "shape": [OMP_BATCH, m], "dtype": "f32"}],
           [{"name": "idx", "shape": [OMP_BATCH, HLO_S], "dtype": "i32"},
            {"name": "val", "shape": [OMP_BATCH, HLO_S], "dtype": "f32"},
            {"name": "nnz", "shape": [OMP_BATCH], "dtype": "i32"}],
           {"s": HLO_S, "n_atoms": HLO_N, "batch": OMP_BATCH})

    # ---- full Lexico decode step (Eq. 7; calls the L1 attention kernel) ---
    def lexdec(*args):
        ws = dict(zip(names, args[: len(names)]))
        (d_k, d_v, token, pos, k_idx, k_val, v_idx, v_val, n_csr,
         k_buf, v_buf, n_buf) = args[len(names):]
        return model_mod.lexico_decode_step(
            ws, cfg, d_k, d_v, token, pos,
            k_idx, k_val, v_idx, v_val, n_csr, k_buf, v_buf, n_buf)

    dk_spec = jax.ShapeDtypeStruct((ll, m, HLO_N), f32)
    idx_spec = jax.ShapeDtypeStruct((ll, kv, HLO_TC, HLO_S), i32)
    val_spec = jax.ShapeDtypeStruct((ll, kv, HLO_TC, HLO_S), f32)
    buf_spec = jax.ShapeDtypeStruct((ll, kv, HLO_TB, m), f32)
    text = lower(lexdec, wspecs + [
        dk_spec, dk_spec,
        jax.ShapeDtypeStruct((1,), i32), jax.ShapeDtypeStruct((1,), i32),
        idx_spec, val_spec, idx_spec, val_spec,
        jax.ShapeDtypeStruct((), i32),
        buf_spec, buf_spec, jax.ShapeDtypeStruct((), i32),
    ])
    record("lexico_decode_M.hlo.txt", text,
           winfo + [
               {"name": "d_k", "shape": [ll, m, HLO_N], "dtype": "f32"},
               {"name": "d_v", "shape": [ll, m, HLO_N], "dtype": "f32"},
               {"name": "token", "shape": [1], "dtype": "i32"},
               {"name": "pos", "shape": [1], "dtype": "i32"},
               {"name": "k_idx", "shape": [ll, kv, HLO_TC, HLO_S], "dtype": "i32"},
               {"name": "k_val", "shape": [ll, kv, HLO_TC, HLO_S], "dtype": "f32"},
               {"name": "v_idx", "shape": [ll, kv, HLO_TC, HLO_S], "dtype": "i32"},
               {"name": "v_val", "shape": [ll, kv, HLO_TC, HLO_S], "dtype": "f32"},
               {"name": "n_csr", "shape": [], "dtype": "i32"},
               {"name": "k_buf", "shape": [ll, kv, HLO_TB, m], "dtype": "f32"},
               {"name": "v_buf", "shape": [ll, kv, HLO_TB, m], "dtype": "f32"},
               {"name": "n_buf", "shape": [], "dtype": "i32"},
           ],
           [{"name": "logits", "shape": [cfg.vocab], "dtype": "f32"},
            {"name": "k_t", "shape": [ll, kv, m], "dtype": "f32"},
            {"name": "v_t", "shape": [ll, kv, m], "dtype": "f32"}],
           {"tc": HLO_TC, "tb": HLO_TB, "s": HLO_S, "n_atoms": HLO_N})

    # ---- loss + grads (the L2 backward pass, exported for completeness) ---
    def grads(*args):
        ws = dict(zip(names, args[: len(names)]))
        x, y = args[len(names):]
        loss, g = jax.value_and_grad(model_mod.loss_fn)(ws, cfg, x, y)
        return (loss, *[g[n] for n in names])

    text = lower(grads, wspecs + [
        jax.ShapeDtypeStruct((2, 128), i32), jax.ShapeDtypeStruct((2, 128), i32)])
    record("grads_M.hlo.txt", text,
           winfo + [{"name": "x", "shape": [2, 128], "dtype": "i32"},
                    {"name": "y", "shape": [2, 128], "dtype": "i32"}],
           [{"name": "loss", "shape": [], "dtype": "f32"}] + winfo,
           {"batch": 2, "seq": 128})

    manifest["weight_order"] = names
    manifest["config"] = {
        "n_layers": ll, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
        "n_kv_heads": kv, "head_dim": m, "d_ff": cfg.d_ff,
        "vocab": cfg.vocab, "max_seq": t_max,
    }
    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log("wrote manifest.json")
    return manifest


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    args = ap.parse_args()
    art = os.path.dirname(args.out) or "."
    os.makedirs(art, exist_ok=True)

    def log(msg):
        print(f"[aot] {msg}", flush=True)

    t_start = time.time()
    with open(os.path.join(art, "vocab.txt"), "w") as f:
        f.write(data_mod.VOCAB_CHARS)

    sizes = os.environ.get("LEXICO_SIZES", "S,M,L").split(",")
    epochs = int(os.environ.get("LEXICO_DICT_EPOCHS", "12"))
    models = {}
    for size in sizes:
        path = f"{art}/model_{size}.bin"
        if os.path.exists(path) and not os.environ.get("LEXICO_FORCE"):
            log(f"{path} exists, loading")
            cfg, params = load_model_bin(path)
            cfg = model_mod.CONFIGS[size]
        else:
            steps = int(os.environ.get(f"LEXICO_STEPS_{size}", DEFAULT_STEPS[size]))
            cfg, params = train_model(size, steps, log)
            save_model_bin(path, cfg, params)
            log(f"saved {path}")
        models[size] = (cfg, params)

    for size in sizes:
        cfg, params = models[size]
        n_list = (1024, 256) if size == "M" else (1024,)
        for n_atoms in n_list:
            build_dicts(size, cfg, params, n_atoms, epochs, log, art)

    # SAE baseline (Table 1): middle-layer K/V of the M model.
    if "M" in models:
        sae_path = f"{art}/sae_M_N1024.bin"
        if not (os.path.exists(sae_path) and not os.environ.get("LEXICO_FORCE")):
            cfg, params = models["M"]
            kvecs, vvecs = dictlearn.collect_kv(params, cfg, DICT_SEED, DICT_TOKENS)
            mid = cfg.n_layers // 2
            enc_k, dec_k = dictlearn.train_sae(kvecs[mid], 1024, DICT_SPARSITY, epochs=epochs)
            enc_v, dec_v = dictlearn.train_sae(vvecs[mid], 1024, DICT_SPARSITY, epochs=epochs)
            save_sae_bin(sae_path, enc_k, dec_k, enc_v, dec_v)
            log(f"saved {sae_path}")

        cfg, params = models["M"]
        export_hlo(cfg, {k: jnp.asarray(v) for k, v in params.items()}, args.out, log)

    log(f"done in {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
