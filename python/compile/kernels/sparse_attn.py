"""Lexico split decode-attention as a Pallas kernel (paper Eq. 7 / Fig 2b).

For one newly generated token the pre-softmax scores over the *compressed*
prefix are computed without materializing K̂: first ``q·D_k`` (a [G,m]×[m,N]
MXU matmul, shared across the whole kv-head group), then the sparse
contraction with ``K_csr`` — a gather of ``s`` scalars per token followed by
a fused multiply-accumulate on the VPU. Buffer tokens take the standard
dense path, and the two score blocks share one softmax.

The value side reconstructs ``V̂`` rows from ``D_v`` with a gather +
weighted-sum (for tiny ``s`` this is the one-hot-matmul pattern the MXU
prefers; in interpret mode it executes as a gather).

Grid: one program per kv head; each program serves its whole GQA group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lexico_decode_attn"]


def _kernel(q_ref, kidx_ref, kval_ref, vidx_ref, vval_ref, dk_ref, dv_ref,
            kbuf_ref, vbuf_ref, biasc_ref, biasb_ref, o_ref):
    q = q_ref[...][0]        # [G, m]   query heads of this kv group
    k_idx = kidx_ref[...][0]  # [Tc, s]
    k_val = kval_ref[...][0]
    v_idx = vidx_ref[...][0]
    v_val = vval_ref[...][0]
    d_k = dk_ref[...]        # [m, N]
    d_v = dv_ref[...]
    k_buf = kbuf_ref[...][0]  # [Tb, m]
    v_buf = vbuf_ref[...][0]
    bias_c = biasc_ref[...]  # [Tc]   additive score bias (0 or -inf mask)
    bias_b = biasb_ref[...]  # [Tb]
    m = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(m, q.dtype))

    # --- compressed scores: (q·D_k) then sparse contraction with K_csr ----
    qd = q @ d_k                                   # [G, N]  (MXU)
    gathered = jnp.take(qd, k_idx, axis=1)         # [G, Tc, s]
    sc_c = jnp.sum(gathered * k_val[None], axis=2) * scale + bias_c[None]

    # --- buffer scores: standard dense path -------------------------------
    sc_b = (q @ k_buf.T) * scale + bias_b[None]    # [G, Tb]

    # --- joint softmax -----------------------------------------------------
    scores = jnp.concatenate([sc_c, sc_b], axis=1)  # [G, Tc+Tb]
    scores = scores - jnp.max(scores, axis=1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    tc = sc_c.shape[1]
    w_c, w_b = w[:, :tc], w[:, tc:]

    # --- value side: V̂ rows via gather + weighted sum ---------------------
    atoms = jnp.take(d_v.T, v_idx, axis=0)          # [Tc, s, m]
    v_hat = jnp.einsum("ts,tsm->tm", v_val, atoms)  # [Tc, m]
    out = w_c @ v_hat + w_b @ v_buf                 # [G, m]
    o_ref[...] = out[None]


def lexico_decode_attn(q, k_idx, k_val, v_idx, v_val, d_k, d_v, k_buf, v_buf,
                       bias_c=None, bias_b=None):
    """Split attention for one token. Shapes as in ``ref.lexico_decode_attn_ref``:

    q [H,m]; k_idx/k_val/v_idx/v_val [KV,Tc,s]; d_k/d_v [m,N];
    k_buf/v_buf [KV,Tb,m] (buffer already includes the new token's k/v);
    optional additive score biases bias_c [Tc] / bias_b [Tb] (use -1e30 to
    mask invalid slots). Returns the attention output [H, m].
    """
    h, m = q.shape
    kv, tc, s = k_idx.shape
    tb = k_buf.shape[1]
    n_atoms = d_k.shape[1]
    g = h // kv
    assert g * kv == h, (h, kv)
    if bias_c is None:
        bias_c = jnp.zeros((tc,), q.dtype)
    if bias_b is None:
        bias_b = jnp.zeros((tb,), q.dtype)
    qg = q.reshape(kv, g, m)
    out = pl.pallas_call(
        functools.partial(_kernel),
        grid=(kv,),
        in_specs=[
            pl.BlockSpec((1, g, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tc, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tc, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tc, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tc, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, n_atoms), lambda i: (0, 0)),
            pl.BlockSpec((m, n_atoms), lambda i: (0, 0)),
            pl.BlockSpec((1, tb, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tb, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((tc,), lambda i: (0,)),
            pl.BlockSpec((tb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, g, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kv, g, m), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qg, k_idx, k_val, v_idx, v_val, d_k, d_v, k_buf, v_buf, bias_c, bias_b)
    return out.reshape(h, m)
