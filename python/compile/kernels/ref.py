"""Pure-jnp/numpy oracles for the Pallas kernels.

These implementations favour clarity over speed; every Pallas kernel in this
package is validated against them by ``python/tests/``. The OMP oracle uses
an explicit least-squares solve per iteration (textbook OMP, Algorithm 1 of
the paper); the decode-attention oracle materializes the dense
reconstruction ``K̂ = K_csr D_kᵀ`` (Eq. 4/5) and runs standard attention.
"""

from __future__ import annotations

import numpy as np


def omp_ref(
    D: np.ndarray, X: np.ndarray, s: int, delta: float | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Textbook OMP. ``D`` is [m, N] with unit-norm columns, ``X`` is [B, m].

    Returns (indices [B, s] int32, values [B, s] f32, nnz [B] int32).
    If ``delta`` is given, iteration stops early once
    ``||x - Dy||_2 <= delta * ||x||_2`` (paper §4.2.1); unused slots have
    index 0 and value 0 and are excluded from nnz.
    """
    m, N = D.shape
    B = X.shape[0]
    idxs = np.zeros((B, s), dtype=np.int32)
    vals = np.zeros((B, s), dtype=np.float32)
    nnz = np.zeros((B,), dtype=np.int32)
    for b in range(B):
        x = X[b].astype(np.float64)
        norm_x = np.linalg.norm(x)
        support: list[int] = []
        y = np.zeros(0)
        for i in range(s):
            r = x - (D[:, support].astype(np.float64) @ y if support else 0.0)
            if delta is not None and np.linalg.norm(r) <= delta * norm_x:
                break
            c = D.astype(np.float64).T @ r
            c[support] = 0.0  # residual already ⊥ span(support)
            j = int(np.argmax(np.abs(c)))
            support.append(j)
            sub = D[:, support].astype(np.float64)
            y, *_ = np.linalg.lstsq(sub, x, rcond=None)
        k = len(support)
        idxs[b, :k] = support
        vals[b, :k] = y.astype(np.float32)
        nnz[b] = k
    return idxs, vals, nnz


def reconstruct(D: np.ndarray, idxs: np.ndarray, vals: np.ndarray) -> np.ndarray:
    """Dense reconstruction ``X̂[b] = Σ_j vals[b,j] · D[:, idxs[b,j]]``."""
    atoms = D.T[idxs]  # [B, s, m]
    return np.einsum(
        "bs,bsm->bm", vals.astype(np.float64), atoms.astype(np.float64)
    ).astype(np.float32)


def rel_error(
    D: np.ndarray, X: np.ndarray, idxs: np.ndarray, vals: np.ndarray
) -> np.ndarray:
    """Per-vector relative ℓ2 reconstruction error (Table 1 metric)."""
    err = np.linalg.norm(X - reconstruct(D, idxs, vals), axis=-1)
    return err / np.maximum(np.linalg.norm(X, axis=-1), 1e-12)


def lexico_decode_attn_ref(
    q: np.ndarray,          # [H, m]           query heads (single new token)
    k_idx: np.ndarray,      # [KV, Tc, s]      compressed key indices
    k_val: np.ndarray,      # [KV, Tc, s]      compressed key coefficients
    v_idx: np.ndarray,      # [KV, Tc, s]
    v_val: np.ndarray,      # [KV, Tc, s]
    d_k: np.ndarray,        # [m, N]
    d_v: np.ndarray,        # [m, N]
    k_buf: np.ndarray,      # [KV, Tb, m]      full-precision buffer (incl. k_t)
    v_buf: np.ndarray,      # [KV, Tb, m]
) -> np.ndarray:
    """Reference for Eq. (7): split attention over compressed + buffer cache.

    Grouped-query attention: query head h uses kv head h // (H // KV).
    Returns the attention output [H, m].
    """
    H, m = q.shape
    KV = k_idx.shape[0]
    group = H // KV
    out = np.zeros((H, m), dtype=np.float32)
    for h in range(H):
        g = h // group
        k_hat = reconstruct(d_k, k_idx[g], k_val[g])  # [Tc, m]
        v_hat = reconstruct(d_v, v_idx[g], v_val[g])  # [Tc, m]
        keys = np.concatenate([k_hat, k_buf[g]], axis=0)  # [Tc+Tb, m]
        values = np.concatenate([v_hat, v_buf[g]], axis=0)
        scores = keys @ q[h] / np.sqrt(m)
        scores -= scores.max()
        w = np.exp(scores)
        w /= w.sum()
        out[h] = (w[:, None] * values).sum(axis=0)
    return out


def attn_ref(q: np.ndarray, K: np.ndarray, V: np.ndarray) -> np.ndarray:
    """Plain single-token attention oracle. q [H,m], K/V [KV,T,m] → [H,m]."""
    H, m = q.shape
    KV = K.shape[0]
    group = H // KV
    out = np.zeros((H, m), dtype=np.float32)
    for h in range(H):
        g = h // group
        scores = K[g] @ q[h] / np.sqrt(m)
        scores -= scores.max()
        w = np.exp(scores)
        w /= w.sum()
        out[h] = (w[:, None] * V[g]).sum(axis=0)
    return out
