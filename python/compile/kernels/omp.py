"""Batched Orthogonal Matching Pursuit as a Pallas kernel.

TPU rethink of the CUDA batched-OMP kernel the paper builds on (Lubonja et
al. 2024) — see DESIGN.md §5 (Hardware adaptation):

  * the dictionary ``D`` (m×N) is small enough to sit **whole in VMEM**
    (32×4096 f32 = 512 KB), so the BlockSpec pins it for every grid step and
    tiles the *batch of vectors* instead of staging dictionary tiles through
    shared memory as the CUDA kernel does;
  * the correlation step ``c = r Dᵀ`` is expressed as a [TB,m]×[m,N] matmul
    — exactly the MXU systolic-array shape — replacing warp-per-atom dot
    products; atom selection is a vectorized argmax on the VPU;
  * the least-squares state is kept as an explicit **inverse-Gram** updated
    with the block-matrix inversion identity (the ``v0``/inverse-Cholesky
    family of Zhu et al. 2020). For unit-norm atoms the update needs only
    small matmuls and outer products, so the whole iteration stays on the
    MXU/VPU with no triangular solves.

The kernel supports the paper's two operating modes:

  * fixed sparsity ``s`` (``delta=0``): exactly ``s`` OMP iterations;
  * error-thresholded (``delta>0``, §4.2.1): a lane freezes once
    ``‖x − Dy‖₂ ≤ δ·‖x‖₂``; because OMP is greedy, the frozen prefix equals
    what fixed-``s`` OMP would have produced.

``interpret=True`` is mandatory on this box: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["omp", "omp_pallas_call"]


def _omp_kernel(d_ref, x_ref, idx_ref, val_ref, nnz_ref, *, s: int, delta: float):
    D = d_ref[...]  # [m, N] — resident in VMEM across the whole grid
    X = x_ref[...]  # [TB, m]
    tb, m = X.shape
    n_atoms = D.shape[1]
    f = X.dtype
    norm_x = jnp.sqrt(jnp.sum(X * X, axis=1))  # [TB]

    def body(i, carry):
        sel, sel_d, g_inv, y, r, mask, nnz = carry
        # Early-termination test (no-op when delta == 0: ‖r‖ > 0 ≥ δ‖x‖ is
        # false only for exactly-reconstructed lanes, which must freeze
        # anyway to keep the Gram update non-singular).
        r_norm = jnp.sqrt(jnp.sum(r * r, axis=1))
        active = r_norm > jnp.maximum(delta * norm_x, 1e-12)  # [TB]

        # Correlation + selection: one MXU matmul, one VPU argmax.
        c = jnp.abs(r @ D)  # [TB, N]
        c = jnp.where(mask, -jnp.inf, c)
        j = jnp.argmax(c, axis=1)  # [TB]
        dj = jnp.take(D.T, j, axis=0)  # [TB, m]

        # Inverse-Gram block update. With unit-norm atoms the new Gram row
        # is (b, 1); u = G⁻¹b lives in the first i coordinates only.
        e_i = jax.nn.one_hot(i, s, dtype=f)  # [s]
        b = jnp.einsum("tsm,tm->ts", sel_d, dj)
        u = jnp.einsum("tsk,tk->ts", g_inv, b)
        beta = jnp.maximum(1.0 - jnp.sum(b * u, axis=1), 1e-8)[:, None, None]
        upd = (
            u[:, :, None] * u[:, None, :]
            - u[:, :, None] * e_i[None, None, :]
            - e_i[None, :, None] * u[:, None, :]
            + e_i[None, :, None] * e_i[None, None, :]
        ) / beta
        g_inv_n = g_inv + upd
        sel_d_n = sel_d + e_i[None, :, None] * dj[:, None, :]
        sel_n = sel + e_i.astype(jnp.int32)[None, :] * j[:, None].astype(jnp.int32)

        # Re-solve on the enlarged support and refresh the residual.
        alpha = jnp.einsum("tsm,tm->ts", sel_d_n, X)
        y_n = jnp.einsum("tsk,tk->ts", g_inv_n, alpha)
        r_n = X - jnp.einsum("ts,tsm->tm", y_n, sel_d_n)
        mask_n = mask | (jax.nn.one_hot(j, n_atoms, dtype=jnp.bool_))

        # Frozen lanes keep their previous state.
        a1 = active[:, None]
        a2 = active[:, None, None]
        return (
            jnp.where(a1, sel_n, sel),
            jnp.where(a2, sel_d_n, sel_d),
            jnp.where(a2, g_inv_n, g_inv),
            jnp.where(a1, y_n, y),
            jnp.where(a1, r_n, r),
            jnp.where(a1, mask_n, mask),
            nnz + active.astype(jnp.int32),
        )

    init = (
        jnp.zeros((tb, s), jnp.int32),
        jnp.zeros((tb, s, m), f),
        jnp.zeros((tb, s, s), f),
        jnp.zeros((tb, s), f),
        X,
        jnp.zeros((tb, n_atoms), jnp.bool_),
        jnp.zeros((tb,), jnp.int32),
    )
    sel, _, _, y, _, _, nnz = jax.lax.fori_loop(0, s, body, init)
    idx_ref[...] = sel
    val_ref[...] = y
    nnz_ref[...] = nnz


def omp_pallas_call(m: int, n_atoms: int, batch: int, s: int, delta: float = 0.0,
                    tile: int = 64, dtype=jnp.float32):
    """Build the pallas_call for given static shapes. batch % tile == 0."""
    assert batch % tile == 0, (batch, tile)
    kernel = functools.partial(_omp_kernel, s=s, delta=float(delta))
    return pl.pallas_call(
        kernel,
        grid=(batch // tile,),
        in_specs=[
            pl.BlockSpec((m, n_atoms), lambda i: (0, 0)),  # D pinned in VMEM
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, s), jnp.int32),
            jax.ShapeDtypeStruct((batch, s), dtype),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )


def omp(D: jax.Array, X: jax.Array, s: int, delta: float = 0.0, tile: int = 64):
    """Sparse-code the rows of ``X`` [B,m] over dictionary ``D`` [m,N].

    Returns ``(indices [B,s] i32, values [B,s], nnz [B] i32)``. Rows of the
    output beyond ``nnz[b]`` are zero-filled (index 0, coefficient 0).
    Batch is padded up to a multiple of ``tile`` internally.
    """
    m, n_atoms = D.shape
    b = X.shape[0]
    tile = min(tile, max(1, b))
    pad = (-b) % tile
    if pad:
        X = jnp.concatenate([X, jnp.zeros((pad, m), X.dtype)], axis=0)
    call = omp_pallas_call(m, n_atoms, b + pad, s, delta, tile, X.dtype)
    idx, val, nnz = call(D, X)
    return idx[:b], val[:b], nnz[:b]
