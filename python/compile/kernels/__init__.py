"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""
from . import ref  # noqa: F401
from .omp import omp  # noqa: F401
from .sparse_attn import lexico_decode_attn  # noqa: F401
