"""L1 Pallas split decode-attention kernel vs oracle (Eq. 7)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import lexico_decode_attn, ref


def make_case(rng, h, kv, m, n, tc, tb, s):
    q = rng.standard_normal((h, m)).astype(np.float32)
    d_k = rng.standard_normal((m, n)).astype(np.float32)
    d_k /= np.linalg.norm(d_k, axis=0)
    d_v = rng.standard_normal((m, n)).astype(np.float32)
    d_v /= np.linalg.norm(d_v, axis=0)
    k_idx = rng.integers(0, n, (kv, tc, s)).astype(np.int32)
    v_idx = rng.integers(0, n, (kv, tc, s)).astype(np.int32)
    k_val = rng.standard_normal((kv, tc, s)).astype(np.float32)
    v_val = rng.standard_normal((kv, tc, s)).astype(np.float32)
    k_buf = rng.standard_normal((kv, tb, m)).astype(np.float32)
    v_buf = rng.standard_normal((kv, tb, m)).astype(np.float32)
    return q, k_idx, k_val, v_idx, v_val, d_k, d_v, k_buf, v_buf


def test_matches_oracle():
    rng = np.random.default_rng(0)
    case = make_case(rng, 4, 2, 32, 256, 40, 8, 6)
    out = np.asarray(lexico_decode_attn(*map(jnp.asarray, case)))
    expect = ref.lexico_decode_attn_ref(*case)
    np.testing.assert_allclose(out, expect, atol=1e-4)


def test_bias_masks_invalid_slots():
    """-inf biases must exactly remove masked tokens from the softmax."""
    rng = np.random.default_rng(1)
    h, kv, m, n, tc, tb, s = 2, 1, 16, 64, 10, 4, 3
    case = make_case(rng, h, kv, m, n, tc, tb, s)
    # mask the last 4 compressed and last 2 buffer slots
    bias_c = np.zeros(tc, np.float32)
    bias_c[6:] = -1e30
    bias_b = np.zeros(tb, np.float32)
    bias_b[2:] = -1e30
    out = np.asarray(lexico_decode_attn(
        *map(jnp.asarray, case), jnp.asarray(bias_c), jnp.asarray(bias_b)))
    # oracle on the truncated inputs
    q, k_idx, k_val, v_idx, v_val, d_k, d_v, k_buf, v_buf = case
    expect = ref.lexico_decode_attn_ref(
        q, k_idx[:, :6], k_val[:, :6], v_idx[:, :6], v_val[:, :6],
        d_k, d_v, k_buf[:, :2], v_buf[:, :2])
    np.testing.assert_allclose(out, expect, atol=1e-4)


def test_equivalent_to_dense_attention_when_exact():
    """With K̂/V̂ materialized, the split path == plain attention."""
    rng = np.random.default_rng(2)
    kv, m, n, tc, tb, s = 2, 16, 64, 12, 4, 4
    case = make_case(rng, 4, kv, m, n, tc, tb, s)
    q, k_idx, k_val, v_idx, v_val, d_k, d_v, k_buf, v_buf = case
    out = np.asarray(lexico_decode_attn(*map(jnp.asarray, case)))
    k_hat = np.stack([ref.reconstruct(d_k, k_idx[g], k_val[g]) for g in range(kv)])
    v_hat = np.stack([ref.reconstruct(d_v, v_idx[g], v_val[g]) for g in range(kv)])
    keys = np.concatenate([k_hat, k_buf], axis=1)
    values = np.concatenate([v_hat, v_buf], axis=1)
    expect = ref.attn_ref(q, keys, values)
    np.testing.assert_allclose(out, expect, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    kv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    m=st.sampled_from([8, 16, 32]),
    tc=st.integers(2, 24),
    tb=st.integers(1, 8),
    s=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_oracle_agreement_hypothesis(kv, group, m, tc, tb, s, seed):
    rng = np.random.default_rng(seed)
    case = make_case(rng, kv * group, kv, m, 4 * m, tc, tb, s)
    out = np.asarray(lexico_decode_attn(*map(jnp.asarray, case)))
    expect = ref.lexico_decode_attn_ref(*case)
    np.testing.assert_allclose(out, expect, atol=2e-4)
