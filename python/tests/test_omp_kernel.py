"""L1 Pallas OMP kernel vs the textbook oracle (kernels/ref.py)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import omp, ref
from compile.dictlearn import omp_jnp


def unit_dict(rng, m, n):
    d = rng.standard_normal((m, n)).astype(np.float32)
    return d / np.linalg.norm(d, axis=0, keepdims=True)


def test_kernel_matches_oracle_exactly():
    rng = np.random.default_rng(0)
    m, n, b, s = 32, 256, 16, 6
    d = unit_dict(rng, m, n)
    x = rng.standard_normal((b, m)).astype(np.float32)
    idx, val, nnz = omp(jnp.asarray(d), jnp.asarray(x), s, tile=8)
    ridx, rval, rnnz = ref.omp_ref(d, x, s)
    assert (np.asarray(nnz) == rnnz).all()
    assert (np.sort(np.asarray(idx), 1) == np.sort(ridx, 1)).all()
    err_k = ref.rel_error(d, x, np.asarray(idx), np.asarray(val))
    err_r = ref.rel_error(d, x, ridx, rval)
    np.testing.assert_allclose(err_k, err_r, atol=1e-4)


def test_residual_monotone_in_sparsity():
    rng = np.random.default_rng(1)
    m, n = 32, 128
    d = unit_dict(rng, m, n)
    x = rng.standard_normal((4, m)).astype(np.float32)
    prev = np.full(4, np.inf)
    for s in (1, 2, 4, 8):
        idx, val, _ = omp(jnp.asarray(d), jnp.asarray(x), s, tile=4)
        err = ref.rel_error(d, x, np.asarray(idx), np.asarray(val))
        assert (err <= prev + 1e-4).all(), (s, err, prev)
        prev = err


def test_threshold_mode_is_greedy_prefix():
    rng = np.random.default_rng(2)
    m, n, b = 32, 128, 8
    d = unit_dict(rng, m, n)
    x = rng.standard_normal((b, m)).astype(np.float32)
    full_idx, _, _ = omp(jnp.asarray(d), jnp.asarray(x), 12, tile=8)
    thr_idx, thr_val, nnz = omp(jnp.asarray(d), jnp.asarray(x), 12, delta=0.5, tile=8)
    nnz = np.asarray(nnz)
    thr_idx = np.asarray(thr_idx)
    full_idx = np.asarray(full_idx)
    for bi in range(b):
        k = nnz[bi]
        assert (thr_idx[bi, :k] == full_idx[bi, :k]).all()
        if k < 12:
            err = ref.rel_error(d, x[bi:bi + 1], thr_idx[bi:bi + 1], np.asarray(thr_val)[bi:bi + 1])
            assert err[0] <= 0.5 + 1e-3


def test_exact_recovery_of_sparse_signal():
    rng = np.random.default_rng(3)
    m, n, k = 32, 256, 3
    d = unit_dict(rng, m, n)
    support = rng.choice(n, size=k, replace=False)
    coefs = rng.uniform(0.5, 2.0, size=k).astype(np.float32)
    x = (d[:, support] @ coefs)[None].astype(np.float32)
    idx, val, _ = omp(jnp.asarray(d), jnp.asarray(x), k, tile=1)
    err = ref.rel_error(d, x, np.asarray(idx), np.asarray(val))
    assert err[0] < 1e-3
    assert set(np.asarray(idx)[0]) == set(support)


def test_zero_vector_freezes():
    rng = np.random.default_rng(4)
    d = unit_dict(rng, 16, 64)
    x = np.zeros((4, 16), np.float32)
    idx, val, nnz = omp(jnp.asarray(d), jnp.asarray(x), 4, tile=4)
    assert (np.asarray(nnz) == 0).all()
    assert np.asarray(val).sum() == 0


def test_jnp_variant_matches_kernel():
    """The jit-friendly trainer encoder == the Pallas kernel."""
    rng = np.random.default_rng(5)
    m, n, b, s = 32, 256, 12, 5
    d = unit_dict(rng, m, n)
    x = rng.standard_normal((b, m)).astype(np.float32)
    ki, kv, kn = omp(jnp.asarray(d), jnp.asarray(x), s, tile=4)
    ji, jv, jn = omp_jnp(jnp.asarray(d), jnp.asarray(x), s)
    assert (np.asarray(ki) == np.asarray(ji)).all()
    np.testing.assert_allclose(np.asarray(kv), np.asarray(jv), atol=1e-5)
    assert (np.asarray(kn) == np.asarray(jn)).all()


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    overcomplete=st.sampled_from([2, 4, 8]),
    b=st.integers(1, 9),
    s=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_kernel_vs_oracle_hypothesis(m, overcomplete, b, s, seed):
    """Shape/param sweep: kernel reconstruction ≤ oracle's (within fp32 ties)."""
    rng = np.random.default_rng(seed)
    n = m * overcomplete
    s = min(s, m)
    d = unit_dict(rng, m, n)
    x = rng.standard_normal((b, m)).astype(np.float32)
    idx, val, nnz = omp(jnp.asarray(d), jnp.asarray(x), s, tile=min(8, b))
    assert np.asarray(idx).shape == (b, s)
    assert (np.asarray(nnz) == s).all()
    err_k = ref.rel_error(d, x, np.asarray(idx), np.asarray(val))
    err_r = ref.rel_error(d, x, *ref.omp_ref(d, x, s)[:2])
    # f32 vs f64 argmax ties can flip a selection; allow a small margin
    assert (err_k <= err_r + 0.05).all(), (err_k, err_r)
