"""Dictionary learning: convergence, constraints, baselines (Table 1 logic)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import dictlearn


def subspace_data(rng, n_vec, m, n_sub=4, dim=3):
    """Union-of-subspaces data — the structure Fig. 3 observes in keys."""
    bases = [rng.standard_normal((dim, m)).astype(np.float32) for _ in range(n_sub)]
    out = np.zeros((n_vec, m), np.float32)
    for v in range(n_vec):
        b = bases[rng.integers(n_sub)]
        out[v] = rng.standard_normal(dim).astype(np.float32) @ b
    return out


@pytest.fixture(scope="module")
def trained():
    rng = np.random.default_rng(0)
    vecs = subspace_data(rng, 512, 16)
    d = dictlearn.train_dictionary(vecs, n_atoms=64, s=4, epochs=15, batch=64,
                                   lr=3e-2, seed=1)
    return vecs, d


def test_atoms_unit_norm(trained):
    _, d = trained
    norms = np.linalg.norm(d, axis=0)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_trained_beats_random(trained):
    vecs, d = trained
    rand = dictlearn.random_dictionary(16, 64, seed=9)
    idx, val, _ = dictlearn.omp_jnp(jnp.asarray(d), jnp.asarray(vecs[:200]), 4)
    e_t = np.asarray(dictlearn.rel_error_jnp(jnp.asarray(d), jnp.asarray(vecs[:200]), idx, val))
    idx, val, _ = dictlearn.omp_jnp(jnp.asarray(rand), jnp.asarray(vecs[:200]), 4)
    e_r = np.asarray(dictlearn.rel_error_jnp(jnp.asarray(rand), jnp.asarray(vecs[:200]), idx, val))
    assert e_t.mean() < 0.8 * e_r.mean(), (e_t.mean(), e_r.mean())


def test_sae_baseline_trains_and_reconstructs():
    rng = np.random.default_rng(2)
    vecs = subspace_data(rng, 256, 16)
    enc, dec = dictlearn.train_sae(vecs, n_atoms=64, s=4, epochs=25, batch=64, seed=3, lr=1e-2)
    errs = dictlearn.sae_rel_error(enc, dec, vecs[:100], 4)
    assert np.isfinite(errs).all()
    assert errs.mean() < 1.0
    norms = np.linalg.norm(dec, axis=0)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_collect_kv_shapes():
    import jax
    from compile import model
    cfg = model.ModelConfig("T", 2, 32, 2, 1, 16, 64, 57, 96)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    k, v = dictlearn.collect_kv(params, cfg, seed=5, n_tokens=128, seq=64)
    assert k.shape == (2, 128, 16)
    assert v.shape == (2, 128, 16)
