"""Data contract: tokenizer, generators, PRNG parity with the Rust side."""

import numpy as np
import pytest

from compile import data


def test_vocab_roundtrip():
    s = "a=3;b=a+4;b?7\nk01=v02;k01?"
    assert data.decode(data.encode(s)) == s
    assert data.VOCAB_SIZE == 57


def test_splitmix64_known_vectors():
    # Same algorithm as rust/src/util/rng.rs — spot-check determinism and
    # 64-bit wrapping behaviour.
    r = data.SplitMix64(1234)
    v = [r.next_u64() for _ in range(3)]
    r2 = data.SplitMix64(1234)
    assert v == [r2.next_u64() for _ in range(3)]
    assert all(0 <= x < 2**64 for x in v)
    # golden value (computed once; also asserted in the Rust tests via the
    # shared artifact if regenerated)
    r3 = data.SplitMix64(0)
    assert r3.next_u64() == 16294208416658607535


def test_arith_examples_solve():
    rng = data.SplitMix64(1)
    for _ in range(100):
        p, a = data.gen_arith_example(rng, 4)
        env = {}
        chain, q = p.rsplit(";", 1)
        for stmt in chain.split(";"):
            var, expr = stmt.split("=")
            for op in "+-*":
                if op in expr:
                    src, operand = expr.split(op)
                    val = {"+": env[src] + int(operand),
                           "-": env[src] - int(operand),
                           "*": env[src] * int(operand)}[op] % 100
                    break
            else:
                val = int(expr)
            env[var] = val
        assert str(env[q[:-1]]) == a, p


def test_needle_consistency():
    rng = data.SplitMix64(2)
    for _ in range(30):
        p, a = data.gen_needle_example(rng, 15)
        q = p.rsplit(";", 1)[1][:-1]
        assert f"{q}={a}" in p


def test_training_stream_shapes():
    stream = data.token_stream(seed=3, n_tokens=1000)
    assert stream.shape == (1000,)
    assert stream.min() >= 0 and stream.max() < data.VOCAB_SIZE
    assert (stream == data.BOS).sum() > 0
    batches = list(data.training_batches(3, 4 * 2 * 32 + 1, 2, 32))
    assert len(batches) == 4
    x, y, w = batches[0]
    assert x.shape == (2, 32) and w.shape == (2, 32)
    # next-token alignment
    np.testing.assert_array_equal(x.reshape(-1)[1:], y.reshape(-1)[:-1])


def test_answer_weights_mark_spans():
    # "k01=v02;k01?v02\n" → the answer chars (v02) and the newline carry
    # ANSWER_WEIGHT; everything else weight 1.
    toks = np.asarray([data.BOS] + data.encode("k01?v02\nab"), np.int32)
    w = data.answer_weights(toks)
    text = "k01?v02\nab"
    expect = [1.0] * (1 + len(text))
    q = 1 + text.index("?")
    for i in range(q + 1, 1 + text.index("\n") + 1):
        expect[i] = data.ANSWER_WEIGHT
    np.testing.assert_array_equal(w, expect)


def test_table1_corpora_disjoint_formats():
    toks = {name: data.corpus_tokens(name, 9, 400) for name in data.TABLE1_CORPORA}
    texts = {name: data.decode(t) for name, t in toks.items()}
    assert "k" in texts["retrieval"] and "=" in texts["retrieval"]
    assert any(w in texts["prose"] for w in ("the", "fox", "river"))
    assert ";" in texts["arith"]
    for t in toks.values():
        assert t.shape == (400,)


def test_vocab_file_matches_rust_constant():
    """artifacts/vocab.txt (when built) must equal VOCAB_CHARS."""
    import os
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/vocab.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        assert f.read() == data.VOCAB_CHARS
