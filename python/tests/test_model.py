"""L2 JAX model: shapes, causality, training, and the Lexico decode path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data, model


TINY = model.ModelConfig("T", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                         head_dim=16, d_ff=64, vocab=57, max_seq=96)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), TINY)


def test_forward_shapes(params):
    toks = jnp.zeros((2, 10), jnp.int32)
    logits, ks, vs = model.forward(params, TINY, toks)
    assert logits.shape == (2, 10, 57)
    assert ks.shape == (2, 2, 1, 10, 16)
    assert vs.shape == (2, 2, 1, 10, 16)


def test_causality(params):
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(0)
    a = rng.integers(3, 57, (1, 12)).astype(np.int32)
    b = a.copy()
    b[0, -1] = (b[0, -1] - 3 + 1) % 54 + 3
    la, _, _ = model.forward(params, TINY, jnp.asarray(a))
    lb, _, _ = model.forward(params, TINY, jnp.asarray(b))
    np.testing.assert_allclose(la[0, :-1], lb[0, :-1], atol=1e-5)
    assert not np.allclose(la[0, -1], lb[0, -1])


def test_decode_step_matches_forward(params):
    """Autoregressive decode with the dense cache == full forward."""
    rng = np.random.default_rng(1)
    toks = rng.integers(3, 57, (1, 8)).astype(np.int32)
    logits_full, ks, vs = model.forward(params, TINY, jnp.asarray(toks))
    t_max = 16
    kc = jnp.zeros((2, 1, 1, t_max, 16))
    vc = jnp.zeros((2, 1, 1, t_max, 16))
    kc = kc.at[:, :, :, :8].set(ks)
    vc = vc.at[:, :, :, :8].set(vs)
    nxt = jnp.asarray([5], jnp.int32)
    logits_dec, _, _ = model.decode_step(
        params, TINY, nxt, jnp.asarray([8], jnp.int32), kc, vc)
    toks9 = np.concatenate([toks, [[5]]], axis=1)
    logits_full9, _, _ = model.forward(params, TINY, jnp.asarray(toks9))
    np.testing.assert_allclose(
        np.asarray(logits_dec[0]), np.asarray(logits_full9[0, -1]), atol=1e-4)


def test_lexico_decode_with_exact_dictionary(params):
    """Identity dictionary + s=m ⇒ lexico decode == dense decode."""
    m, n = 16, 16
    eye = jnp.eye(m)[None].repeat(2, 0)  # [L, m, N]
    rng = np.random.default_rng(2)
    toks = rng.integers(3, 57, (1, 6)).astype(np.int32)
    _, ks, vs = model.forward(params, TINY, jnp.asarray(toks))
    # compress tokens 0..3 "exactly": idx=coordinates, val=components
    tc, tb, s = 8, 6, m
    k_idx = jnp.zeros((2, 1, tc, s), jnp.int32)
    k_val = jnp.zeros((2, 1, tc, s))
    v_idx = jnp.zeros((2, 1, tc, s), jnp.int32)
    v_val = jnp.zeros((2, 1, tc, s))
    # identity dictionary ⇒ indices are coordinates, coefficients are the
    # vector components themselves. ks is [L,B,KV,T,m].
    coords = jnp.arange(m)[None, None, None]
    k_idx = k_idx.at[:, :, :4].set(coords.repeat(4, 2))
    v_idx = v_idx.at[:, :, :4].set(coords.repeat(4, 2))
    k_val = k_val.at[:, :, :4].set(ks[:, 0][:, :, :4, :])
    v_val = v_val.at[:, :, :4].set(vs[:, 0][:, :, :4, :])
    # buffer holds tokens 4,5 at slots 0,1
    k_buf = jnp.zeros((2, 1, tb, m)).at[:, :, 0:2].set(ks[:, 0][:, :, 4:6, :])
    v_buf = jnp.zeros((2, 1, tb, m)).at[:, :, 0:2].set(vs[:, 0][:, :, 4:6, :])
    logits_lex, k_t, v_t = model.lexico_decode_step(
        params, TINY, eye, eye,
        jnp.asarray([7], jnp.int32), jnp.asarray([6], jnp.int32),
        k_idx, k_val, v_idx, v_val, jnp.asarray(4, jnp.int32),
        k_buf, v_buf, jnp.asarray(2, jnp.int32))
    # reference: dense forward over the 7 tokens
    toks7 = np.concatenate([toks, [[7]]], axis=1)
    logits_ref, ks7, _ = model.forward(params, TINY, jnp.asarray(toks7))
    np.testing.assert_allclose(
        np.asarray(logits_lex), np.asarray(logits_ref[0, -1]), atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(k_t), np.asarray(ks7[:, 0][:, :, -1, :]), atol=1e-5)


def test_training_reduces_loss():
    cfg = TINY
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    step = model.make_train_step(cfg, 3e-3, 30)
    opt = model.adam_init(params)
    losses = []
    for x, y, w in data.training_batches(7, 30 * 2 * 64 + 1, 2, 64):
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y), jnp.asarray(w))
        losses.append(float(loss))
        if len(losses) >= 30:
            break
    assert losses[-1] < losses[0] * 0.8, losses[::6]


def test_param_shapes_contract():
    shapes = model.param_shapes(TINY)
    assert shapes["embed"] == (57, 32)
    assert shapes["layer0.wk"] == (32, 16)
    assert shapes["layer1.w2"] == (64, 32)
    assert len([k for k in shapes if k.startswith("layer0.")]) == 9
