//! Open-loop, fault-injecting load generator for the serving stack: an
//! in-process server (tiny random weights, lexico cache method) is driven
//! over real TCP by thousands of simulated clients with Poisson arrivals,
//! heavy-tailed prompt lengths, a shared-prefix mix and three priority
//! tiers — at an offered load deliberately ~2× the measured capacity, so
//! the SLO-aware admission path has to shed. A seeded fault schedule rides
//! along: mid-stream disconnects, slow readers, garbage frames, torn
//! frames and a deadline storm. The run asserts the overload contract
//! (low-priority prefills shed with a `retry_after_ms` hint, high-priority
//! TTFT bounded, `{"cmd":"metrics"}` still answering afterwards) and emits
//! `BENCH_loadgen.json` — its `gate` object feeds `benches/compare.rs`
//! against `benches/baseline_loadgen.json` in CI.
//!
//!   cargo bench --bench loadgen [-- --smoke]
//!
//! `--smoke` reduces the arrival count (the CI shape). The arrival
//! schedule, prompt mix and fault schedule are all derived from one
//! SplitMix64 seed, so two runs offer the identical request sequence —
//! only the wall-clock timings differ.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lexico::dict::{Dictionary, DictionarySet};
use lexico::model::testutil::tiny_weights;
use lexico::model::Engine;
use lexico::server::batcher::{self, BatcherConfig};
use lexico::server::http::{serve_opts, ServeOpts};
use lexico::server::metrics::Metrics;
use lexico::server::sched::{SloTargets, TenantQuotas};
use lexico::util::json::Json;
use lexico::util::rng::Rng;
use lexico::util::stats::summarize;

/// Everything decided about a request before the run starts — the seeded,
/// deterministic part of the workload.
#[derive(Clone)]
struct Spec {
    at_ms: f64,
    tenant: &'static str,
    priority: i64,
    deadline_ms: u64,
    prompt: String,
    max_new: usize,
    fault: Fault,
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum Fault {
    None,
    /// read one reply line, then vanish mid-stream
    Disconnect,
    /// sleep between reply lines so the bounded stream channel backs up
    SlowReader,
    /// send a line that is not JSON at all
    Garbage,
    /// send half a request and close without a newline
    Torn,
}

#[derive(Clone, Copy, PartialEq, Debug)]
enum Outcome {
    Ok,
    Shed,
    /// an `overloaded` reply that did NOT carry a retry hint (contract bug)
    ShedNoHint,
    Expired,
    Busy,
    Error,
    /// the request was itself a fault injection; no reply contract applies
    Fault,
}

struct Record {
    tenant: &'static str,
    outcome: Outcome,
    /// nominal-arrival → first token (open-loop convention: loadgen queue
    /// wait counts against the server, not the client)
    ttft_ms: f64,
    tpot_ms: f64,
}

const TENANTS: [(&str, i64); 3] = [("pro", 8), ("std", 4), ("free", 0)];

/// kv-pair prompt: one of `n_prefixes` shared prefixes (exercising the
/// prefix cache under churn) + a bounded-Pareto random suffix + a query.
/// Lengths are capped so prompt + max_new fits the tiny model's 128-token
/// window.
fn gen_prompt(rng: &mut Rng, n_prefixes: usize) -> String {
    let p = rng.below(n_prefixes);
    let mut s = String::new();
    for j in 0..5 {
        s.push_str(&format!("k{p}{j}=v{p}{j};"));
    }
    // heavy-tailed suffix length: Pareto-ish via inverse-power transform
    let u = rng.uniform().max(1e-9);
    let extra_pairs = ((1.0 / u.powf(0.6)) as usize).clamp(1, 7);
    for _ in 0..extra_pairs {
        let (a, b) = (rng.below(10), rng.below(10));
        s.push_str(&format!("k{a}{b}=v{b}{a};"));
    }
    s.push_str(&format!("k{p}0?"));
    s
}

/// Build the whole arrival schedule up front (Poisson arrivals at
/// `rate_per_s`, tenant mix, deadline storm window, fault mix).
fn build_specs(seed: u64, n: usize, rate_per_s: f64) -> Vec<Spec> {
    let mut rng = Rng::new(seed);
    let mut t_ms = 0.0f64;
    let storm = (n * 2 / 5)..(n * 2 / 5 + n / 20).max(n * 2 / 5 + 1);
    (0..n)
        .map(|i| {
            let u = rng.uniform().max(1e-12);
            t_ms += -u.ln() / rate_per_s * 1e3;
            let (tenant, priority) = {
                let r = rng.uniform();
                if r < 0.25 {
                    TENANTS[0]
                } else if r < 0.60 {
                    TENANTS[1]
                } else {
                    TENANTS[2]
                }
            };
            // deadline storm: a burst of already-hopeless deadlines that the
            // round-top expiry has to clear without starving live traffic
            let deadline_ms = if storm.contains(&i) {
                1
            } else if rng.uniform() < 0.10 {
                2000
            } else {
                0
            };
            let fault = match rng.uniform() {
                r if r < 0.03 => Fault::Disconnect,
                r if r < 0.06 => Fault::SlowReader,
                r if r < 0.08 => Fault::Garbage,
                r if r < 0.10 => Fault::Torn,
                _ => Fault::None,
            };
            Spec {
                at_ms: t_ms,
                tenant,
                priority,
                deadline_ms,
                prompt: gen_prompt(&mut rng, 4),
                max_new: 6 + rng.below(7),
                fault,
            }
        })
        .collect()
}

fn request_line(spec: &Spec) -> String {
    let mut s = format!(
        "{{\"prompt\": \"{}\", \"max_new\": {}, \"tenant\": \"{}\", \"priority\": {}, \
         \"stream\": true",
        spec.prompt, spec.max_new, spec.tenant, spec.priority
    );
    if spec.deadline_ms > 0 {
        s.push_str(&format!(", \"deadline_ms\": {}", spec.deadline_ms));
    }
    s.push('}');
    s
}

/// Run one client request against the server; returns what happened.
fn run_client(addr: std::net::SocketAddr, spec: &Spec, t0: Instant) -> Record {
    let rec =
        |outcome, ttft_ms, tpot_ms| Record { tenant: spec.tenant, outcome, ttft_ms, tpot_ms };
    let conn = match TcpStream::connect(addr) {
        Ok(c) => c,
        Err(_) => return rec(Outcome::Error, f64::NAN, f64::NAN),
    };
    let _ = conn.set_read_timeout(Some(Duration::from_secs(30)));
    let mut writer = match conn.try_clone() {
        Ok(w) => w,
        Err(_) => return rec(Outcome::Error, f64::NAN, f64::NAN),
    };
    let mut reader = BufReader::new(conn);
    match spec.fault {
        Fault::Garbage => {
            // not JSON at all: the server must answer a structured error on
            // the same connection instead of dying
            let _ = writeln!(writer, "@@@ definitely not json @@@");
            let mut line = String::new();
            let _ = reader.read_line(&mut line);
            return rec(Outcome::Fault, f64::NAN, f64::NAN);
        }
        Fault::Torn => {
            // half a frame, no newline, then gone — the server sees EOF on a
            // partial line and must just close its side
            let _ = writer.write_all(b"{\"prompt\": \"k00=v00;");
            let _ = writer.flush();
            return rec(Outcome::Fault, f64::NAN, f64::NAN);
        }
        _ => {}
    }
    if writeln!(writer, "{}", request_line(spec)).is_err() {
        return rec(Outcome::Error, f64::NAN, f64::NAN);
    }
    let mut first_token_ms = f64::NAN;
    let mut line = String::new();
    loop {
        if spec.fault == Fault::SlowReader {
            std::thread::sleep(Duration::from_millis(25));
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return rec(Outcome::Error, f64::NAN, f64::NAN),
            Ok(_) => {}
            Err(_) => return rec(Outcome::Error, f64::NAN, f64::NAN),
        }
        let v = match Json::parse(&line) {
            Ok(v) => v,
            Err(_) => return rec(Outcome::Error, f64::NAN, f64::NAN),
        };
        if v.get("token").as_str().is_some() {
            if first_token_ms.is_nan() {
                first_token_ms = t0.elapsed().as_secs_f64() * 1e3;
            }
            if spec.fault == Fault::Disconnect {
                // vanish mid-stream: the batcher must cancel the session
                // and return its KV bytes without a goodbye
                return rec(Outcome::Fault, f64::NAN, f64::NAN);
            }
            continue;
        }
        // final reply line
        let done_ms = t0.elapsed().as_secs_f64() * 1e3;
        return match v.get("error").as_str() {
            Some("overloaded") => {
                if v.get("retry_after_ms").as_u64().unwrap_or(0) > 0 {
                    rec(Outcome::Shed, f64::NAN, f64::NAN)
                } else {
                    rec(Outcome::ShedNoHint, f64::NAN, f64::NAN)
                }
            }
            Some("deadline_expired") => rec(Outcome::Expired, f64::NAN, f64::NAN),
            Some("busy") => rec(Outcome::Busy, f64::NAN, f64::NAN),
            Some(_) => rec(Outcome::Error, f64::NAN, f64::NAN),
            None => {
                let n_gen = v.get("n_generated").as_usize().unwrap_or(0);
                let ttft = (if first_token_ms.is_nan() { done_ms } else { first_token_ms }
                    - spec.at_ms)
                    .max(0.0);
                let tpot = if n_gen > 1 && !first_token_ms.is_nan() {
                    (done_ms - first_token_ms).max(0.0) / (n_gen - 1) as f64
                } else {
                    f64::NAN
                };
                rec(Outcome::Ok, ttft, tpot)
            }
        };
    }
}

/// Closed-loop capacity probe: one client, sequential requests, no faults.
/// Returns mean per-request latency in ms — the basis for the 2× overload
/// offered rate and for the (generous) TTFT acceptance bound.
fn probe_capacity(addr: std::net::SocketAddr) -> f64 {
    let mut lat = Vec::new();
    for i in 0..12 {
        let mut conn = TcpStream::connect(addr).expect("probe connect");
        let mut reader = BufReader::new(conn.try_clone().expect("probe clone"));
        let t0 = Instant::now();
        writeln!(
            conn,
            "{{\"prompt\": \"k00=v0{i};k00?\", \"max_new\": 8, \"tenant\": \"pro\", \
             \"priority\": 8}}"
        )
        .expect("probe write");
        let mut line = String::new();
        reader.read_line(&mut line).expect("probe read");
        let v = Json::parse(&line).expect("probe reply parses");
        assert!(v.get("error").as_str().is_none(), "probe failed: {line}");
        lat.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize(&lat).mean
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let n_arrivals = if smoke { 800 } else { 2400 };
    let n_workers = 48usize;
    let seed = 4242u64;
    let max_sessions = 4usize;

    // ---- in-process server over real TCP ------------------------------
    let engine = Arc::new(Engine::new(tiny_weights(17)));
    let shape = engine.shape();
    let dicts = Some(Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, 64, 800 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, 64, 900 + i as u64))
            .collect(),
    }));
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let cfg = BatcherConfig {
        default_method: "lexico:s=2,nb=8".into(),
        max_sessions,
        prefill_chunk: 16,
        max_queue: 12,
        slo: SloTargets { ttft_ms: 250.0, tpot_ms: 2.0 },
        tenant_quotas: TenantQuotas::parse("free=seats:2").expect("quota spec"),
        spill_dir: None,
        ..Default::default()
    };
    let (jtx, jrx) = channel();
    let m2 = metrics.clone();
    let eng2 = engine.clone();
    let batcher_h = std::thread::spawn(move || batcher::run(eng2, dicts, cfg, jrx, m2));
    let (atx, arx) = channel();
    let m3 = metrics.clone();
    let serve_h = std::thread::spawn(move || {
        serve_opts("127.0.0.1:0", ServeOpts { max_conns: 96 }, jtx, m3, move |a| {
            let _ = atx.send(a);
        })
    });
    let addr = arx.recv_timeout(Duration::from_secs(10)).expect("server bind");

    // ---- capacity probe → offered load --------------------------------
    let probe_ms = probe_capacity(addr);
    // single-client closed-loop rate × seat count bounds capacity from
    // above; offering 2× that guarantees sustained overload
    let capacity_per_s = max_sessions as f64 * 1e3 / probe_ms.max(1e-3);
    let offered_per_s = 2.0 * capacity_per_s;
    println!(
        "loadgen: probe {probe_ms:.2} ms/req → capacity ≤ {capacity_per_s:.0} req/s, \
         offering {offered_per_s:.0} req/s × {n_arrivals} arrivals ({n_workers} workers, \
         seed {seed}{})",
        if smoke { ", smoke" } else { "" }
    );
    let specs = build_specs(seed, n_arrivals, offered_per_s);

    // ---- open-loop drive ----------------------------------------------
    let records: Arc<Mutex<Vec<Record>>> = Arc::new(Mutex::new(Vec::with_capacity(n_arrivals)));
    let (wtx, wrx) = channel::<Spec>();
    let wrx = Arc::new(Mutex::new(wrx));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let wrx = wrx.clone();
            let records = records.clone();
            std::thread::spawn(move || loop {
                let spec = match wrx.lock().expect("work queue").recv() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                let r = run_client(addr, &spec, t0);
                records.lock().expect("records").push(r);
            })
        })
        .collect();
    // dispatcher: sleep to each nominal arrival, then hand off. A hard wall
    // bounds the bench even if the server wedges; anything not dispatched
    // is reported, never silently dropped.
    let wall = Duration::from_secs(120);
    let mut dispatched = 0usize;
    for spec in &specs {
        if t0.elapsed() > wall {
            break;
        }
        let target = Duration::from_secs_f64(spec.at_ms / 1e3);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        wtx.send(spec.clone()).expect("workers alive");
        dispatched += 1;
    }
    drop(wtx);
    for w in workers {
        w.join().expect("worker panicked");
    }
    let drive_s = t0.elapsed().as_secs_f64();
    if dispatched < specs.len() {
        println!(
            "WARNING: hit the {}s wall after {dispatched}/{} arrivals — remaining arrivals \
             were not offered",
            wall.as_secs(),
            specs.len()
        );
    }

    // ---- liveness after the full fault schedule -----------------------
    let mut conn = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    writeln!(conn, "{{\"cmd\": \"metrics\"}}")?;
    let mut report = String::new();
    reader.read_line(&mut report)?;
    assert!(
        report.contains("requests="),
        "server stopped answering metrics after the fault schedule: {report}"
    );
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}")?;
    serve_h.join().expect("serve thread").expect("serve error");
    batcher_h.join().expect("batcher thread").expect("batcher error");

    // ---- aggregate ----------------------------------------------------
    let records = Arc::try_unwrap(records)
        .map_err(|_| anyhow::anyhow!("records still shared"))?
        .into_inner()
        .expect("records lock");
    let count = |o: Outcome| records.iter().filter(|r| r.outcome == o).count();
    let (n_ok, n_shed) = (count(Outcome::Ok), count(Outcome::Shed));
    let (n_nohint, n_expired) = (count(Outcome::ShedNoHint), count(Outcome::Expired));
    let (n_busy, n_error, n_fault) =
        (count(Outcome::Busy), count(Outcome::Error), count(Outcome::Fault));
    let completed_per_s = n_ok as f64 / drive_s.max(1e-9);
    println!(
        "\noffered {dispatched} in {drive_s:.1}s: ok={n_ok} shed={n_shed} expired={n_expired} \
         busy={n_busy} error={n_error} faults={n_fault} ({completed_per_s:.1} completed/s)"
    );

    let mut class_entries = Vec::new();
    let mut gate_hi_ttft = f64::NAN;
    let mut gate_hi_tpot = f64::NAN;
    for (tenant, priority) in TENANTS {
        let ttfts: Vec<f64> = records
            .iter()
            .filter(|r| r.tenant == tenant && r.outcome == Outcome::Ok && r.ttft_ms.is_finite())
            .map(|r| r.ttft_ms)
            .collect();
        let tpots: Vec<f64> = records
            .iter()
            .filter(|r| r.tenant == tenant && r.outcome == Outcome::Ok && r.tpot_ms.is_finite())
            .map(|r| r.tpot_ms)
            .collect();
        let shed = records
            .iter()
            .filter(|r| {
                r.tenant == tenant && matches!(r.outcome, Outcome::Shed | Outcome::ShedNoHint)
            })
            .count();
        if ttfts.is_empty() {
            println!("{tenant:<5} pri {priority}: no completions");
            class_entries.push(format!(
                "    {{\"tenant\": \"{tenant}\", \"priority\": {priority}, \"completed\": 0, \
                 \"shed\": {shed}}}"
            ));
            continue;
        }
        let ts = summarize(&ttfts);
        let ps = if tpots.is_empty() { None } else { Some(summarize(&tpots)) };
        if tenant == "pro" {
            gate_hi_ttft = ts.p99;
            gate_hi_tpot = ps.as_ref().map(|p| p.p99).unwrap_or(f64::NAN);
        }
        println!(
            "{tenant:<5} pri {priority}: {} completed, {shed} shed  TTFT p50 {:.1} p99 {:.1} ms  \
             TPOT p99 {:.2} ms",
            ttfts.len(),
            ts.p50,
            ts.p99,
            ps.as_ref().map(|p| p.p99).unwrap_or(f64::NAN),
        );
        class_entries.push(format!(
            "    {{\"tenant\": \"{tenant}\", \"priority\": {priority}, \"completed\": {}, \
             \"shed\": {shed}, \"ttft_p50_ms\": {:.2}, \"ttft_p99_ms\": {:.2}, \
             \"tpot_p99_ms\": {:.3}}}",
            ttfts.len(),
            ts.p50,
            ts.p99,
            ps.as_ref().map(|p| p.p99).unwrap_or(-1.0),
        ));
    }

    // ---- the overload contract, asserted ------------------------------
    assert!(n_shed > 0, "2× overload must shed at least one queued prefill");
    assert_eq!(n_nohint, 0, "every overloaded reply must carry retry_after_ms");
    assert!(
        dispatched as f64 >= 1.2 * n_ok as f64,
        "offered load was meant to exceed capacity (offered {dispatched}, completed {n_ok})"
    );
    assert!(n_ok > 0, "some requests must still complete under overload");
    assert!(
        gate_hi_ttft.is_finite(),
        "high-priority tenants must complete requests under overload"
    );
    // generous bound: graceful overload keeps high-priority TTFT within a
    // small multiple of unloaded latency instead of queue-length-proportional
    let ttft_bound = (25.0 * probe_ms).max(1000.0);
    assert!(
        gate_hi_ttft <= ttft_bound,
        "high-priority p99 TTFT {gate_hi_ttft:.1} ms exceeds {ttft_bound:.1} ms under 2× load"
    );

    // ---- report -------------------------------------------------------
    // short high-priority answers may all be single-token; an absent TPOT
    // sample must not leak NaN into the report JSON
    let gate_hi_tpot = if gate_hi_tpot.is_finite() { gate_hi_tpot } else { 0.0 };
    let json = format!(
        "{{\n  \"bench\": \"loadgen\",\n  \"smoke\": {smoke},\n  \
         \"config\": {{\"arrivals\": {n_arrivals}, \"dispatched\": {dispatched}, \
         \"workers\": {n_workers}, \"seed\": {seed}, \"max_sessions\": {max_sessions}, \
         \"max_queue\": 12, \"offered_per_s\": {offered_per_s:.1}, \
         \"probe_ms\": {probe_ms:.2}}},\n  \
         \"gate\": {{\n    \"hi_ttft_p99_ms\": {gate_hi_ttft:.2},\n    \
         \"hi_tpot_p99_ms\": {gate_hi_tpot:.3},\n    \
         \"completed_per_s\": {completed_per_s:.1}\n  }},\n  \
         \"counts\": {{\"ok\": {n_ok}, \"shed\": {n_shed}, \"expired\": {n_expired}, \
         \"busy\": {n_busy}, \"error\": {n_error}, \"faults\": {n_fault}}},\n  \
         \"classes\": [\n{}\n  ]\n}}\n",
        class_entries.join(",\n")
    );
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_loadgen.json"))
        .unwrap_or_else(|| "BENCH_loadgen.json".into());
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {}", out_path.display());
    Ok(())
}
