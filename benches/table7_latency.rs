//! Table 7 bench: per-token latency decomposition.
//!
//! Mirrors the paper's measurement: (a) the standard forward pass with a
//! dense cache (qKᵀ), (b) the Lexico forward pass over the compressed
//! cache (q·D_k then K_csr), (c) the OMP sparse-approximation step — each
//! per generated token, summed across all layers, at dictionary sizes
//! N=256 and N=1024 (our 8×/32× overcomplete points ↔ the paper's
//! 1024/4096 at m=128).
//!
//!   cargo bench --bench table7_latency

use std::sync::Arc;

use lexico::cache::full::FullCache;
use lexico::cache::lexico::{LexicoCache, LexicoConfig};
use lexico::dict::DictionarySet;
use lexico::model::{Engine, Weights};
use lexico::omp::{omp_encode, OmpWorkspace};
use lexico::tasks;
use lexico::util::rng::Rng;
use lexico::util::stats::{bench_ms, report};

fn main() -> anyhow::Result<()> {
    let art = lexico::artifacts_dir();
    if !art.join("model_M.bin").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    let shape = engine.shape();
    let t_ctx = 500.min(engine.weights.cfg.max_seq - 80);
    let mut rng = Rng::new(3);
    let mut prompt = vec![tasks::BOS];
    prompt.extend(tasks::encode(&tasks::gen_lm_text(&mut rng, t_ctx - 2)));
    prompt.truncate(t_ctx);
    println!("context: {} tokens, model M ({} layers)\n", prompt.len(), shape.n_layers);
    let (warm, iters) = (10, 60);

    // (a) standard forward, dense cache
    let mut full = FullCache::new(shape);
    let _ = engine.prefill(&prompt, &mut full);
    let mut pos = prompt.len();
    let s = bench_ms(warm, iters, || {
        let _ = engine.decode_step(7, pos, &mut full);
        pos += 1;
    });
    report("standard forward pass (qK^T)", &s);

    for n_atoms in [256usize, 1024] {
        let dicts = Arc::new(DictionarySet::load(
            art.join(format!("dict_M_N{n_atoms}.bin")))?);
        // (b) Lexico forward: attend over compressed prefix + buffer.
        // n_approx=0 keeps OMP out of this timing (measured separately, as
        // in the paper where the two run in parallel).
        let cfg = LexicoConfig { sparsity: 6, n_buffer: 32, n_approx: 0, ..Default::default() };
        let mut lex = LexicoCache::new(shape, dicts.clone(), cfg);
        let _ = engine.prefill(&prompt, &mut lex);
        let mut pos = prompt.len();
        let s = bench_ms(warm, iters, || {
            let _ = engine.decode_step(7, pos, &mut lex);
            pos += 1;
        });
        report(&format!("Lexico forward q(K_csr D_k^T)^T  N={n_atoms}"), &s);

        // (c) OMP for one token: K and V vectors of every layer/kv head
        let m = shape.head_dim;
        let mut ws = OmpWorkspace::new(n_atoms, m, 6);
        let xs: Vec<Vec<f32>> = (0..shape.n_layers * shape.n_kv_heads * 2)
            .map(|_| rng.normal_vec(m))
            .collect();
        let s = bench_ms(warm, iters, || {
            for (i, x) in xs.iter().enumerate() {
                let layer = i / (shape.n_kv_heads * 2);
                let d = if i % 2 == 0 { &dicts.keys[layer] } else { &dicts.values[layer] };
                let _ = omp_encode(&d.atoms, d.n, d.m, x, 6, 0.0, &mut ws);
            }
        });
        report(&format!("Lexico OMP per generated token   N={n_atoms}"), &s);
    }
    println!("\npaper shape to check: Lexico fwd ≈ standard fwd (small overhead);");
    println!("OMP grows with N but stays within the same order as the forward.");
    Ok(())
}
