//! Fig 1 bench (PR 10 shape): the headline accuracy-vs-bytes/token
//! tradeoff, now swept **per coefficient mode** — every lexico operating
//! point runs in FP16 (paper ablation), FP8 (default) and the sign tier
//! (±α, one packed bit per atom plus an f16 row scale) against the kivi
//! quantization baseline and the uncompressed cache. Each curve point
//! reports bytes/token, bits/coefficient, task score and fidelity to the
//! full cache (`agree`), plus the harness eval throughput.
//!
//!   cargo bench --bench fig1_tradeoff [-- --smoke]
//!
//! `--smoke` runs artifact-free on a tiny deterministic model with random
//! dictionaries — scores are near zero there, but the byte accounting,
//! the sign tier's ≤2 bits/coef invariant and the thread-determinism
//! check are all exercised for real. With artifacts present (`make
//! artifacts`) the full run sweeps the trained model M instead.
//!
//! The sweep also pins the sign tier's decode determinism: a 1536-token
//! compressed context (past the sharded-score threshold) must attend
//! bitwise identically on 1-, 2- and 4-thread pools.
//!
//! Emits `BENCH_PR10.json`; its `gate` object feeds `benches/compare.rs`
//! against `benches/baseline_pr10.json`.

use std::sync::Arc;
use std::time::Instant;

use lexico::cache::lexico::{LexicoCache, LexicoConfig};
use lexico::cache::CacheShape;
use lexico::dict::{Dictionary, DictionarySet};
use lexico::eval::{evaluate, EvalConfig};
use lexico::exec::ExecPool;
use lexico::model::testutil::tiny_weights;
use lexico::model::{Engine, Weights};
use lexico::runtime::CacheRuntime;
use lexico::sparse::CoefMode;
use lexico::tasks::Task;
use lexico::util::rng::Rng;
use lexico::util::stats::bench_ms;

/// (display label, spec, lexico coef mode + sparsity when applicable)
fn curve_specs() -> Vec<(&'static str, String, Option<(CoefMode, usize)>)> {
    let mut specs: Vec<(&'static str, String, Option<(CoefMode, usize)>)> =
        vec![("full", "full".into(), None)];
    for s in [4usize, 8] {
        for mode in [CoefMode::Fp16, CoefMode::Fp8, CoefMode::Sign] {
            let flag = match mode {
                CoefMode::Fp16 => ",fp16",
                CoefMode::Fp8 => "",
                CoefMode::Sign => ",sign",
            };
            let label = match mode {
                CoefMode::Fp16 => "lexico-fp16",
                CoefMode::Fp8 => "lexico-fp8",
                CoefMode::Sign => "lexico-sign",
            };
            specs.push((label, format!("lexico:s={s},nb=32{flag}"), Some((mode, s))));
        }
    }
    specs.push(("kivi", "kivi:bits=2,g=16,nb=16".into(), None));
    specs.push(("kivi", "kivi:bits=4,g=16,nb=16".into(), None));
    specs
}

/// Sign-tier thread-determinism pin: fill one sign-mode cache past the
/// sharded-score threshold through the real append path, then attend the
/// identical query on 1-, 2- and 4-thread pools — the outputs must be
/// bitwise identical. Returns the single-thread attend ns/token (the
/// PR10 perf-gate metric).
fn sign_thread_determinism(smoke: bool) -> anyhow::Result<f64> {
    let shape = CacheShape { n_layers: 1, n_heads: 8, n_kv_heads: 4, head_dim: 64 };
    let (n_atoms, m) = (256usize, shape.head_dim);
    let t_tokens = 1536usize; // past the sharded-score threshold (1024)
    let (warm, iters) = if smoke { (2, 8) } else { (5, 25) };
    let dicts = Arc::new(DictionarySet {
        keys: vec![Dictionary::random(m, n_atoms, 71)],
        values: vec![Dictionary::random(m, n_atoms, 72)],
    });
    let cfg = LexicoConfig {
        sparsity: 4,
        n_buffer: 32,
        precision: CoefMode::Sign,
        ..Default::default()
    };
    let mut reference: Option<Vec<u32>> = None;
    let mut gate_ns_per_token = f64::NAN;
    for threads in [1usize, 2, 4] {
        let mut cache = LexicoCache::new(shape, dicts.clone(), cfg.clone());
        cache.set_runtime(
            &CacheRuntime::default().with_pool(Arc::new(ExecPool::new(threads))),
        );
        let mut rng = Rng::new(73);
        let kvd = shape.kv_dim();
        let mut done = 0usize;
        while done < t_tokens {
            let chunk = 512.min(t_tokens - done);
            let ks = rng.normal_vec(chunk * kvd);
            let vs = rng.normal_vec(chunk * kvd);
            cache.append_batch(0, &ks, &vs, chunk);
            done += chunk;
        }
        let q = Rng::new(74).normal_vec(shape.q_dim());
        let mut out = vec![0.0f32; shape.q_dim()];
        if threads == 1 {
            let st = bench_ms(warm, iters, || cache.attend(0, &q, &mut out));
            gate_ns_per_token = st.mean * 1e6 / t_tokens as f64;
        }
        cache.attend(0, &q, &mut out);
        let bits: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(want) => anyhow::ensure!(
                *want == bits,
                "sign attend diverged bitwise at T={threads}"
            ),
        }
        println!(
            "sign determinism T={threads}: {} compressed tokens, output bitwise {}",
            t_tokens,
            if threads == 1 { "recorded" } else { "identical" }
        );
    }
    Ok(gate_ns_per_token)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let art = lexico::artifacts_dir();
    let have_artifacts = art.join("model_M.bin").exists();

    let gate_attend_ns = sign_thread_determinism(smoke)?;

    // Model + dictionaries: trained artifacts when present (full run),
    // else the deterministic tiny model with random dictionaries.
    let (engine, dicts, model_name, n) = if !smoke && have_artifacts {
        let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
        let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
        (engine, dicts, "M", 10usize)
    } else {
        let engine = Engine::new(tiny_weights(61));
        let shape = engine.shape();
        let dicts = Arc::new(DictionarySet {
            keys: (0..shape.n_layers)
                .map(|i| Dictionary::random(shape.head_dim, 64, 8100 + i as u64))
                .collect(),
            values: (0..shape.n_layers)
                .map(|i| Dictionary::random(shape.head_dim, 64, 8200 + i as u64))
                .collect(),
        });
        (engine, dicts, "tiny", 3usize)
    };
    let shape = engine.shape();
    // uncompressed FP16 cost: K + V vectors per token per kv head per layer
    let full_bytes_per_token =
        (2 * 2 * shape.n_kv_heads * shape.head_dim * shape.n_layers) as f64;

    println!(
        "\nPR10 fig1 tradeoff (model {model_name}, n={n} samples/method, \
         {full_bytes_per_token:.0} B/token uncompressed):\n"
    );
    let mut entries = Vec::new();
    let mut total_s = 0.0f64;
    let mut total_samples = 0usize;
    for (label, spec, lex) in curve_specs() {
        let t0 = Instant::now();
        let r = evaluate(
            &engine,
            Some(dicts.clone()),
            &spec,
            &EvalConfig::new(Task::Arith, n, 12345),
        )?;
        let dt = t0.elapsed().as_secs_f64();
        total_s += dt;
        total_samples += r.n;
        let bytes_tok = r.kv_ratio * full_bytes_per_token;
        let (mode_name, bits_coef) = match lex {
            Some((mode, s)) => (mode.name(), mode.bits_per_coef(s)),
            None => ("-", f64::NAN),
        };
        if let Some((CoefMode::Sign, s)) = lex {
            // acceptance: the sign tier stores at most 2 bits per coefficient
            anyhow::ensure!(
                bits_coef <= 2.0 + 1e-12,
                "sign rows store {bits_coef} bits/coef at s={s}"
            );
        }
        println!(
            "{label:<12} {spec:<28} {bytes_tok:>8.1} B/tok  score {:>5.1}  agree {:>5.1}  \
             ({dt:>6.2} s)",
            r.score, r.agree
        );
        let bits_json =
            if bits_coef.is_nan() { "null".into() } else { format!("{bits_coef:.3}") };
        entries.push(format!(
            "    {{\"method\": \"{label}\", \"spec\": \"{spec}\", \
             \"coef_mode\": \"{mode_name}\", \"bits_per_coef\": {bits_json}, \
             \"bytes_per_token\": {bytes_tok:.2}, \"kv_ratio_pct\": {:.2}, \
             \"score\": {:.2}, \"agree\": {:.2}}}",
            100.0 * r.kv_ratio,
            r.score,
            r.agree
        ));
    }
    let eval_samples_per_s = total_samples as f64 / total_s.max(1e-9);
    println!(
        "\nsweep cost {total_s:.1} s ({eval_samples_per_s:.2} samples/s); \
         sign attend gate {gate_attend_ns:.0} ns/token"
    );

    let json = format!(
        "{{\n  \"bench\": \"pr10_fig1_tradeoff\",\n  \"smoke\": {smoke},\n  \
         \"model\": \"{model_name}\",\n  \
         \"config\": {{\"n_samples\": {n}, \"full_bytes_per_token\": {full_bytes_per_token:.0}, \
         \"sign_determinism_threads\": [1, 2, 4]}},\n  \
         \"gate\": {{\n    \"sign_attend_ns_per_token\": {gate_attend_ns:.1},\n    \
         \"eval_samples_per_s\": {eval_samples_per_s:.3}\n  }},\n  \
         \"curves\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_PR10.json"))
        .unwrap_or_else(|| "BENCH_PR10.json".into());
    std::fs::write(&out_path, &json)?;
    println!("wrote {}", out_path.display());
    Ok(())
}
