//! Fig 1 bench: wall-clock cost of regenerating the headline tradeoff
//! sweep (method × sparsity on the arith task), plus the per-method eval
//! throughput — the end-to-end harness cost that gates every experiment.
//!
//!   cargo bench --bench fig1_tradeoff

use std::sync::Arc;
use std::time::Instant;

use lexico::dict::DictionarySet;
use lexico::eval::{evaluate, EvalConfig};
use lexico::model::{Engine, Weights};
use lexico::tasks::Task;

fn main() -> anyhow::Result<()> {
    let art = lexico::artifacts_dir();
    if !art.join("model_M.bin").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    let n = 10;
    println!("eval throughput on arith (n={n} samples/method), model M:\n");
    let mut total = 0.0;
    for spec in [
        "full",
        "lexico:s=8,nb=32",
        "lexico:s=4,nb=32",
        "lexico:s=2,nb=32",
        "kivi:bits=2,g=16,nb=16",
        "kivi:bits=4,g=16,nb=16",
        "pertoken:bits=4,g=16,nb=4",
        "zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16",
        "snapkv:cap=48,win=8",
        "pyramidkv:cap=48,win=8",
    ] {
        let t0 = Instant::now();
        let r = evaluate(&engine, Some(dicts.clone()), spec,
                         &EvalConfig::new(Task::Arith, n, 12345))?;
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!(
            "{spec:<40} {:6.2} s  ({:5.2} s/sample, KV {:5.1}%, score {:5.1})",
            dt,
            dt / n as f64,
            100.0 * r.kv_ratio,
            r.score
        );
    }
    println!("\nfull sweep cost at these settings: {total:.1} s");
    Ok(())
}
