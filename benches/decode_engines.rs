//! Engine comparison bench: native decode vs PJRT decode (dense cache),
//! plus native decode across every cache backend at a long context — the
//! end-to-end per-token cost of each compression method.
//!
//!   cargo bench --bench decode_engines

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::dict::DictionarySet;
use lexico::model::{Engine, Weights};
use lexico::tasks;
use lexico::util::rng::Rng;
use lexico::util::stats::{bench_ms, report};

fn main() -> anyhow::Result<()> {
    let art = lexico::artifacts_dir();
    if !art.join("model_M.bin").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    let ctx = CacheContext { shape: engine.shape(), dicts: Some(dicts) };
    let mut rng = Rng::new(5);
    let t_ctx = 400;
    let mut prompt = vec![tasks::BOS];
    prompt.extend(tasks::encode(&tasks::gen_lm_text(&mut rng, t_ctx)));
    prompt.truncate(t_ctx);

    println!("native decode step at context {} per cache backend:\n", prompt.len());
    for spec in [
        "full",
        "lexico:s=8,nb=32",
        "lexico:s=4,nb=32",
        "kivi:bits=2,g=16,nb=16",
        "pertoken:bits=4,g=16,nb=4",
        "zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16",
        "snapkv:cap=64,win=8",
        "pyramidkv:cap=64,win=8",
    ] {
        let mut cache = build_cache(spec, &ctx)?;
        let _ = engine.prefill(&prompt, &mut *cache);
        let mut pos = prompt.len();
        let st = bench_ms(5, 40, || {
            let _ = engine.decode_step(7, pos, &mut *cache);
            pos += 1;
        });
        report(spec, &st);
    }

    // PJRT path (dense cache graph) for the cross-engine comparison
    if art.join("model.hlo.txt").exists() {
        println!("\nPJRT decode (AOT artifacts through the XLA CPU client):\n");
        let pjrt = lexico::runtime::PjrtEngine::load(&art, &art.join("model_M.bin"))?;
        let short: Vec<u32> = prompt.iter().copied().take(120).collect();
        let st = bench_ms(1, 5, || {
            let _ = pjrt.generate(&short, 8, None).unwrap();
        });
        report("pjrt generate (120-tok prefill + 8 decode)", &st);
    }
    Ok(())
}
