//! Engine comparison bench: native decode vs PJRT decode (dense cache),
//! plus native decode across every cache backend at a long context — the
//! end-to-end per-token cost of each compression method — the
//! batched-throughput sweep: B concurrent sessions advanced per round by
//! `Engine::decode_batch` (the batch-first serving pipeline), reporting
//! per-token latency and aggregate tokens/s at B ∈ {1, 4, 16} — and the
//! thread-scaling sweep T ∈ {1, 2, 4, 8} × B ∈ {1, 4, 16} over the exec
//! pool, reporting tokens/s and parallel efficiency.
//!
//!   cargo bench --bench decode_engines [-- --threads N]

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::KvCache;
use lexico::dict::DictionarySet;
use lexico::exec::ExecPool;
use lexico::model::{Engine, Weights};
use lexico::tasks;
use lexico::util::rng::Rng;
use lexico::util::stats::{bench_ms, report};

fn main() -> anyhow::Result<()> {
    // --threads N (or --threads=N) sizes the default pool for the backend
    // comparison sections; the scaling sweep below builds its own pools.
    let argv: Vec<String> = std::env::args().collect();
    if let Some(t) = lexico::exec::threads_from_args(&argv).map_err(anyhow::Error::msg)? {
        if !lexico::exec::configure_default(t) {
            eprintln!("warning: exec pool already initialized; --threads {t} ignored");
        }
    }
    let art = lexico::artifacts_dir();
    if !art.join("model_M.bin").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    println!("default exec pool: {} threads\n", engine.pool().threads());
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    let ctx = CacheContext { shape: engine.shape(), dicts: Some(dicts) };
    let mut rng = Rng::new(5);
    let t_ctx = 400;
    let mut prompt = vec![tasks::BOS];
    prompt.extend(tasks::encode(&tasks::gen_lm_text(&mut rng, t_ctx)));
    prompt.truncate(t_ctx);

    println!("native decode step at context {} per cache backend:\n", prompt.len());
    for spec in [
        "full",
        "lexico:s=8,nb=32",
        "lexico:s=4,nb=32",
        "kivi:bits=2,g=16,nb=16",
        "pertoken:bits=4,g=16,nb=4",
        "zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16",
        "snapkv:cap=64,win=8",
        "pyramidkv:cap=64,win=8",
    ] {
        let mut cache = build_cache(spec, &ctx)?;
        let _ = engine.prefill(&prompt, &mut *cache);
        let mut pos = prompt.len();
        let st = bench_ms(5, 40, || {
            let _ = engine.decode_step(7, pos, &mut *cache);
            pos += 1;
        });
        report(spec, &st);
    }

    // Batched decode throughput: B sessions, each with its own cache on the
    // same prompt, advanced one token per round via decode_batch. Weight
    // matrices stream once per layer per ROUND, so per-token cost should
    // fall markedly with B (acceptance target: ≥2× tokens/s at B=16 vs B=1
    // for lexico:s=8,nb=32).
    println!("\nbatched decode (B concurrent sessions) at context {}:\n", prompt.len());
    for spec in ["full", "lexico:s=8,nb=32", "kivi:bits=2,g=16,nb=16"] {
        let mut base = f64::NAN;
        for bsz in [1usize, 4, 16] {
            let mut caches: Vec<Box<dyn KvCache>> = Vec::with_capacity(bsz);
            for _ in 0..bsz {
                let mut c = build_cache(spec, &ctx)?;
                let _ = engine.prefill(&prompt, &mut *c);
                caches.push(c);
            }
            let toks: Vec<u32> = vec![7; bsz];
            let mut pos = prompt.len();
            let st = bench_ms(3, 25, || {
                let poss: Vec<usize> = vec![pos; bsz];
                let mut refs: Vec<&mut dyn KvCache> =
                    caches.iter_mut().map(|c| &mut **c).collect();
                let _ = engine.decode_batch(&toks, &poss, &mut refs);
                pos += 1;
            });
            let per_tok = st.mean / bsz as f64;
            if bsz == 1 {
                base = per_tok;
            }
            println!(
                "{spec:<28} B={bsz:<3} {per_tok:>9.4} ms/token  {:>8.1} tok/s  speedup ×{:.2}",
                1e3 / per_tok,
                base / per_tok
            );
        }
    }

    // Thread-scaling sweep: T × B over the exec layer. Each T gets its own
    // engine pinned to a T-thread pool; sessions fork one prefilled
    // prototype (cheap, and exactly the serving path). Reported per cell:
    // amortized ms/token, aggregate tokens/s, speedup over T=1 at the same
    // B, and parallel efficiency (speedup / T). Determinism means every
    // cell decodes the identical token stream — only the clock changes.
    println!("\nthread-scaling sweep (decode_batch, lexico:s=8,nb=32) at context {}:\n", prompt.len());
    {
        let spec = "lexico:s=8,nb=32";
        let mut base_tok_s = std::collections::BTreeMap::new(); // B → tok/s at T=1
        for &threads in &[1usize, 2, 4, 8] {
            let pool = Arc::new(ExecPool::new(threads));
            let eng_t = Engine::with_pool(Weights::load(art.join("model_M.bin"))?, pool.clone());
            for &bsz in &[1usize, 4, 16] {
                let mut proto = build_cache(spec, &ctx)?;
                proto.set_pool(pool.clone());
                let _ = eng_t.prefill(&prompt, &mut *proto);
                let mut caches: Vec<Box<dyn KvCache>> =
                    (0..bsz - 1).map(|_| proto.fork()).collect();
                caches.push(proto);
                let toks: Vec<u32> = vec![7; bsz];
                let mut pos = prompt.len();
                let st = bench_ms(3, 20, || {
                    let poss: Vec<usize> = vec![pos; bsz];
                    let mut refs: Vec<&mut dyn KvCache> =
                        caches.iter_mut().map(|c| &mut **c).collect();
                    let _ = eng_t.decode_batch(&toks, &poss, &mut refs);
                    pos += 1;
                });
                let tok_s = bsz as f64 * 1e3 / st.mean;
                let base = *base_tok_s.entry(bsz).or_insert(tok_s);
                let speedup = tok_s / base;
                println!(
                    "T={threads:<2} B={bsz:<3} {:>9.4} ms/token  {:>8.1} tok/s  speedup ×{speedup:<5.2} efficiency {:>5.1}%",
                    st.mean / bsz as f64,
                    tok_s,
                    100.0 * speedup / threads as f64
                );
            }
        }
    }

    // Shared-prefix amortization: serving N sessions that share a prompt
    // prefix. Cold = every session prefills the whole prompt; prefix-hit =
    // the prefix is prefilled (and captured) once, then each session is a
    // fork of the prototype + a suffix-only prefill — the batcher's
    // admission path on a prefix-cache hit. The gap is the serving win;
    // for lexico the fork also shares the compressed prefix pages
    // physically (shared_prefix_bytes reported below).
    let n_sessions = 8;
    let split = prompt.len() - 16;
    println!(
        "\nshared-prefix prefill amortization ({} prefix + {} suffix tokens, {} sessions):\n",
        split,
        prompt.len() - split,
        n_sessions
    );
    for spec in ["full", "lexico:s=8,nb=32"] {
        let st_cold = bench_ms(1, 4, || {
            for _ in 0..n_sessions {
                let mut c = build_cache(spec, &ctx).unwrap();
                let _ = engine.prefill(&prompt, &mut *c);
            }
        });
        let mut proto = build_cache(spec, &ctx)?;
        let (_, state) = engine.prefill_capture(&prompt[..split], &mut *proto);
        let mut shared_bytes = 0.0;
        let st_hit = bench_ms(1, 4, || {
            for _ in 0..n_sessions {
                let mut c = proto.fork();
                let _ = engine.prefill_suffix(&state, &prompt[split..], &mut *c);
                shared_bytes = c.shared_prefix_bytes();
            }
        });
        println!(
            "{spec:<24} cold {:>8.2} ms/session   prefix-hit {:>8.2} ms/session   amortization ×{:.1}   shared {:.1} KiB/fork",
            st_cold.mean / n_sessions as f64,
            st_hit.mean / n_sessions as f64,
            st_cold.mean / st_hit.mean.max(1e-9),
            shared_bytes / 1024.0
        );
    }

    // Multi-query attend_batch against ONE prefilled cache — the fan-out
    // candidate-scoring shape (b independent queries, one stored state):
    // one streaming pass over the dictionaries / K/V serves every query.
    println!("\nmulti-query attend_batch on one prefilled cache:\n");
    for spec in ["full", "lexico:s=8,nb=32", "kivi:bits=2,g=16,nb=16"] {
        let mut cache = build_cache(spec, &ctx)?;
        let _ = engine.prefill(&prompt, &mut *cache);
        let qd = engine.shape().q_dim();
        let n_layers = engine.shape().n_layers;
        let mut base = f64::NAN;
        for bsz in [1usize, 4, 16] {
            let qs = rng.normal_vec(bsz * qd);
            let mut out = vec![0.0; bsz * qd];
            let st = bench_ms(2, 20, || {
                for l in 0..n_layers {
                    cache.attend_batch(l, &qs, &mut out, bsz);
                }
            });
            let per_q = st.mean / bsz as f64;
            if bsz == 1 {
                base = per_q;
            }
            println!(
                "{spec:<28} b={bsz:<3} {per_q:>9.4} ms/query  speedup ×{:.2}",
                base / per_q
            );
        }
    }

    // PJRT path (dense cache graph) for the cross-engine comparison
    if art.join("model.hlo.txt").exists() {
        println!("\nPJRT decode (AOT artifacts through the XLA CPU client):\n");
        let pjrt = lexico::runtime::PjrtEngine::load(&art, &art.join("model_M.bin"))?;
        let short: Vec<u32> = prompt.iter().copied().take(120).collect();
        let st = bench_ms(1, 5, || {
            let _ = pjrt.generate(&short, 8, None).unwrap();
        });
        report("pjrt generate (120-tok prefill + 8 decode)", &st);
    }
    Ok(())
}
