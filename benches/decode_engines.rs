//! Engine comparison bench: native decode vs PJRT decode (dense cache),
//! plus native decode across every cache backend at a long context — the
//! end-to-end per-token cost of each compression method — the
//! batched-throughput sweep: B concurrent sessions advanced per round by
//! `Engine::decode_batch` (the batch-first serving pipeline), reporting
//! per-token latency and aggregate tokens/s at B ∈ {1, 4, 16} — the
//! thread-scaling sweep T ∈ {1, 2, 4, 8} × B ∈ {1, 4, 16} over the exec
//! pool, reporting tokens/s and parallel efficiency — and the PR 4
//! long-context compressed-attention sweep (flat CSR slabs + SIMD kernels
//! vs the retained row-iterator baseline), which needs no artifacts and
//! emits `BENCH_PR4.json` for the perf trajectory — and the PR 6
//! shared-dictionary round sweep: per-session attend vs the round-level
//! shared-qd protocol (one qᵀD GEMM + one value pass for all sessions)
//! vs the same under the fast-math kernel tier, across session count B
//! and atom count N, emitting `BENCH_PR6.json` — and the PR 7 tiered-
//! residency sweep: spill/fault throughput through the page store, the
//! first-touch attend penalty after a spill (lazy faulting), and resident
//! decode cost while half the fleet is hibernated on disk, emitting
//! `BENCH_PR7.json` — and the PR 8 precomputed-Gram Batch-OMP sweep:
//! canonical residual-space pursuit vs the coefficient-space Gram tier
//! across batch size B × atom count N × sparsity s (one-time Gram build
//! timed separately), plus end-to-end prefill tok/s through a tiny
//! engine on each tier, emitting `BENCH_PR8.json`.
//!
//!   cargo bench --bench decode_engines [-- --threads N] [-- --smoke]
//!
//! `--smoke` runs only the reduced artifact-free sweeps (CI smoke step).
//! `--pr6-child <out>` is internal: the PR 6 sweep re-execs itself with
//! `LEXICO_FAST_MATH=1` to measure the fast tier under its own frozen
//! kernel dispatch (a process-wide `OnceLock`).

use std::sync::Arc;
use std::time::Instant;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::lexico::{LexicoCache, LexicoConfig};
use lexico::cache::{CacheShape, KvCache};
use lexico::dict::{Dictionary, DictionarySet};
use lexico::exec::ExecPool;
use lexico::model::{Engine, Weights};
use lexico::runtime::{CacheRuntime, EncodeTier};
use lexico::sparse::CsrRow;
use lexico::store::SpillStore;
use lexico::tasks;
use lexico::tensor::{axpy, par_matmul_bt, softmax};
use lexico::util::rng::Rng;
use lexico::util::stats::{bench_ms, report};

/// The construction runtime the benches attach resources through — same
/// env-derived defaults the factory uses, so `--gram-omp` / `LEXICO_*`
/// sweeps see their tier here too.
fn bench_rt(pool: Arc<ExecPool>) -> CacheRuntime {
    CacheRuntime::from_env().with_pool(pool)
}

/// The pre-PR scalar `dot`: 8 independent lanes combined by a LINEAR fold
/// plus a sequential tail — the kernel the row-iterator baseline ran on
/// (no SIMD dispatch, lane sums folded left to right).
fn dot_linear(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// The pre-PR scalar `axpy` (8-way unrolled, no SIMD dispatch).
fn axpy_scalar(y: &mut [f32], alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        let yc = &mut y[i..i + 8];
        let xc = &x[i..i + 8];
        for l in 0..8 {
            yc[l] += alpha * xc[l];
        }
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Row-iterator baseline storage: per-token `CsrRow` vectors (two heap
/// `Vec`s per compressed token), exactly the pre-PR layout.
struct RowHead {
    k: Vec<CsrRow>,
    v: Vec<CsrRow>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

/// The pre-PR Lexico attend: row-iterator score/z loops over `Vec<CsrRow>`
/// plus the scalar kernels above. Structure matches the old
/// `LexicoCache::attend` operation for operation.
#[allow(clippy::too_many_arguments)]
fn row_attend(
    shape: &CacheShape,
    heads: &[RowHead],
    k_atoms: &[f32],
    k_n: usize,
    v_atoms: &[f32],
    v_n: usize,
    q: &[f32],
    out: &mut [f32],
    scores: &mut Vec<f32>,
    qd: &mut Vec<f32>,
    z: &mut Vec<f32>,
) {
    let m = shape.head_dim;
    let n_heads = shape.n_heads;
    let scale = 1.0 / (m as f32).sqrt();
    out.fill(0.0);
    qd.resize(n_heads * k_n, 0.0);
    for n in 0..k_n {
        let atom = &k_atoms[n * m..(n + 1) * m];
        for h in 0..n_heads {
            qd[h * k_n + n] = dot_linear(&q[h * m..(h + 1) * m], atom);
        }
    }
    z.resize(v_n, 0.0);
    for h in 0..n_heads {
        let head = &heads[h / shape.group()];
        let (tc, tb) = (head.k.len(), head.buf_len);
        let qh = &q[h * m..(h + 1) * m];
        let qdh = &qd[h * k_n..(h + 1) * k_n];
        scores.resize(tc + tb, 0.0);
        for (ti, row) in head.k.iter().enumerate() {
            let mut sc = 0.0;
            for j in 0..row.nnz() {
                sc += qdh[row.idx[j] as usize] * row.coef(j);
            }
            scores[ti] = sc * scale;
        }
        for ti in 0..tb {
            scores[tc + ti] = dot_linear(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
        }
        softmax(&mut scores[..tc + tb]);
        let oh = &mut out[h * m..(h + 1) * m];
        z[..v_n].fill(0.0);
        for (ti, row) in head.v.iter().enumerate() {
            let w = scores[ti];
            for j in 0..row.nnz() {
                z[row.idx[j] as usize] += w * row.coef(j);
            }
        }
        for (n, &zn) in z[..v_n].iter().enumerate() {
            if zn != 0.0 {
                axpy_scalar(oh, zn, &v_atoms[n * m..(n + 1) * m]);
            }
        }
        for ti in 0..tb {
            axpy_scalar(oh, scores[tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
        }
    }
}

/// Long-context compressed-attention sweep: fill a Lexico cache to T
/// compressed tokens, then time (a) the flat-slab attend single-thread,
/// (b) the same attend with the score sweep sharded on the default pool,
/// and (c) the retained row-iterator baseline — and report the OMP encode
/// throughput observed during the fill. Emits `BENCH_PR4.json` and returns
/// the smallest size's flat-slab attend ns/token (the PR5 perf gate's
/// attend metric).
fn longcontext_attend_sweep(smoke: bool) -> anyhow::Result<f64> {
    // smoke stays past PAR_SCORE_MIN_TOKENS (1024) so the pool-sharded
    // score path is genuinely exercised, not silently skipped
    let sizes: &[usize] = if smoke { &[1536] } else { &[2048, 8192] };
    let (warm, iters) = if smoke { (3, 10) } else { (10, 40) };
    let shape = CacheShape { n_layers: 1, n_heads: 8, n_kv_heads: 4, head_dim: 64 };
    let (n_atoms, m) = (512usize, shape.head_dim);
    let cfg = LexicoConfig { sparsity: 8, n_buffer: 32, ..Default::default() };
    let pool_threads = lexico::exec::default_pool().threads();
    println!(
        "PR4 long-context compressed attention (s={}, N={n_atoms}, m={m}, kv_heads={}) — \
         simd={}, pool T={pool_threads}:\n",
        cfg.sparsity,
        shape.n_kv_heads,
        lexico::tensor::simd::active().name
    );
    let mut entries = Vec::new();
    let mut gate_ns_per_token = f64::NAN;
    for &t_tokens in sizes {
        let dicts = Arc::new(DictionarySet {
            keys: vec![Dictionary::random(m, n_atoms, 11)],
            values: vec![Dictionary::random(m, n_atoms, 12)],
        });
        let mut cache = LexicoCache::new(shape, dicts.clone(), cfg.clone());
        cache.set_runtime(&bench_rt(Arc::new(ExecPool::new(1))));
        let mut rng = Rng::new(7);
        let kvd = shape.kv_dim();
        // fill through the real append path → batched OMP compression
        let fill_t0 = Instant::now();
        let mut done = 0usize;
        while done < t_tokens {
            let chunk = 512.min(t_tokens - done);
            let ks = rng.normal_vec(chunk * kvd);
            let vs = rng.normal_vec(chunk * kvd);
            cache.append_batch(0, &ks, &vs, chunk);
            done += chunk;
        }
        let fill_s = fill_t0.elapsed().as_secs_f64();
        let encoded_vecs = (t_tokens - cfg.n_buffer) * shape.n_kv_heads * 2;
        let encode_vecs_s = encoded_vecs as f64 / fill_s;

        let q = rng.normal_vec(shape.q_dim());
        let mut out = vec![0.0; shape.q_dim()];
        // (a) flat slabs, single-thread
        let st_slab = bench_ms(warm, iters, || cache.attend(0, &q, &mut out));
        // (b) flat slabs, score sweep sharded on the default pool
        cache.set_runtime(&bench_rt(lexico::exec::default_pool()));
        let st_pool = bench_ms(warm, iters, || cache.attend(0, &q, &mut out));
        cache.set_runtime(&bench_rt(Arc::new(ExecPool::new(1))));

        // (c) row-iterator baseline on identical contents
        let heads: Vec<RowHead> = (0..shape.n_kv_heads)
            .map(|g| {
                let (k, v) = cache.csr_rows(0, g);
                let (kb, vb, bl) = cache.buffer(0, g);
                RowHead { k, v, k_buf: kb.to_vec(), v_buf: vb.to_vec(), buf_len: bl }
            })
            .collect();
        let (mut scores, mut qd, mut z) = (Vec::new(), Vec::new(), Vec::new());
        let (dk, dv) = (&dicts.keys[0], &dicts.values[0]);
        let mut out_rows = vec![0.0; shape.q_dim()];
        let st_rows = bench_ms(warm, iters, || {
            row_attend(
                &shape, &heads, &dk.atoms, dk.n, &dv.atoms, dv.n, &q, &mut out_rows,
                &mut scores, &mut qd, &mut z,
            )
        });

        let ns_tok = |mean_ms: f64| mean_ms * 1e6 / t_tokens as f64;
        if gate_ns_per_token.is_nan() {
            gate_ns_per_token = ns_tok(st_slab.mean);
        }
        let speedup = st_rows.mean / st_slab.mean;
        println!(
            "T={t_tokens:<6} slab {:>9.4} ms ({:>7.1} ns/tok)  pool[T={pool_threads}] {:>9.4} ms  \
             row-iter {:>9.4} ms ({:>7.1} ns/tok)  speedup ×{speedup:.2}  \
             encode {encode_vecs_s:>9.0} vecs/s",
            st_slab.mean,
            ns_tok(st_slab.mean),
            st_pool.mean,
            st_rows.mean,
            ns_tok(st_rows.mean),
        );
        entries.push(format!(
            "    {{\"tokens\": {t_tokens}, \"attend_ms\": {:.6}, \"attend_ns_per_token\": {:.2}, \
             \"attend_tokens_per_s\": {:.0}, \"attend_pool_ms\": {:.6}, \"pool_threads\": {pool_threads}, \
             \"row_baseline_ms\": {:.6}, \"row_baseline_ns_per_token\": {:.2}, \
             \"speedup_vs_row_iter\": {:.3}, \"omp_encode_vecs_per_s\": {:.0}}}",
            st_slab.mean,
            ns_tok(st_slab.mean),
            t_tokens as f64 / (st_slab.mean / 1e3),
            st_pool.mean,
            st_rows.mean,
            ns_tok(st_rows.mean),
            speedup,
            encode_vecs_s,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"pr4_longcontext_attend\",\n  \"simd\": \"{}\",\n  \"smoke\": {smoke},\n  \
         \"config\": {{\"sparsity\": {}, \"n_buffer\": {}, \"n_atoms\": {n_atoms}, \"head_dim\": {m}, \
         \"n_kv_heads\": {}}},\n  \"entries\": [\n{}\n  ]\n}}\n",
        lexico::tensor::simd::active().name,
        cfg.sparsity,
        cfg.n_buffer,
        shape.n_kv_heads,
        entries.join(",\n")
    );
    // cargo runs bench binaries with cwd = package root (rust/); anchor the
    // report at the workspace root where the trajectory tooling expects it
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_PR4.json"))
        .unwrap_or_else(|| "BENCH_PR4.json".into());
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {}\n", out_path.display());
    Ok(gate_ns_per_token)
}

/// Serving-round sweep (artifact-free, tiny random weights): 8 sessions
/// decode in steady state, then one 2k-token prompt is admitted mid-stream
/// and prefilled through the batcher's chunked scheduler. Reports decode
/// throughput, round-latency p50, and the admission stall ratio
/// (max round ms during the prefill window ÷ steady p50) per chunk size —
/// chunk 0 (monolithic) shows the TPOT cliff the chunked path removes.
/// Emits `BENCH_PR5.json`; its `gate` object is what
/// `benches/compare.rs` diffs against the committed baseline in CI.
fn serving_round_sweep(smoke: bool, attend_ns_per_token: f64) -> anyhow::Result<()> {
    use lexico::model::testutil::tiny_weights_cfg;
    use lexico::model::ModelConfig;
    use lexico::server::batcher::{Batcher, BatcherConfig};
    use lexico::server::metrics::Metrics;
    use lexico::server::{Job, Request};
    use std::sync::Mutex;

    let n_sessions = 8usize;
    let long_tokens = 2048usize;
    let steady_rounds = if smoke { 15 } else { 40 };
    let cfg_model = ModelConfig {
        n_layers: 2,
        d_model: 32,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 8,
        d_ff: 64,
        vocab: tasks::vocab_size(),
        max_seq: long_tokens + 256,
    };
    let engine = Arc::new(Engine::new(tiny_weights_cfg(33, cfg_model)));
    let dicts = Arc::new(DictionarySet {
        keys: (0..cfg_model.n_layers)
            .map(|i| Dictionary::random(cfg_model.head_dim, 64, 100 + i as u64))
            .collect(),
        values: (0..cfg_model.n_layers)
            .map(|i| Dictionary::random(cfg_model.head_dim, 64, 200 + i as u64))
            .collect(),
    });
    let long_prompt = tasks::gen_lm_text(&mut Rng::new(42), long_tokens - 1);
    let chunks: &[usize] = if smoke { &[256, 0] } else { &[64, 256, 1024, 0] };
    println!(
        "PR5 serving rounds: {n_sessions} decode sessions + one {long_tokens}-token admission \
         (lexico:s=2,nb=8, pool T={}):\n",
        engine.pool().threads()
    );
    let mut gate_decode_tok_s = f64::NAN;
    let mut gate_stall_chunked = f64::NAN;
    let mut stall_monolithic = f64::NAN;
    let mut info = Vec::new();
    for &chunk in chunks {
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=8".into(),
            prefix_entries: 0,
            prefill_chunk: chunk,
            ..Default::default()
        };
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let mut b = Batcher::new(engine.clone(), Some(dicts.clone()), cfg, metrics);
        let mut replies = Vec::new();
        for i in 0..n_sessions {
            let prompt = tasks::gen_lm_text(&mut Rng::new(900 + i as u64), 16);
            let (tx, rx) = std::sync::mpsc::channel();
            b.enqueue(Job::new(Request::greedy(i as u64, prompt, 200, ""), tx));
            replies.push(rx);
        }
        // warm-up: admission + short prefills + first decode rounds
        for _ in 0..3 {
            b.round();
        }
        // steady state: decode rounds only
        let mut round_ms = Vec::with_capacity(steady_rounds);
        let mut steady_tokens = 0u64;
        for _ in 0..steady_rounds {
            let decoders = (b.n_active() - b.n_prefilling()) as u64;
            let t0 = Instant::now();
            b.round();
            round_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            steady_tokens += decoders;
        }
        let steady = lexico::util::stats::summarize(&round_ms);
        let steady_s: f64 = round_ms.iter().sum::<f64>() / 1e3;
        let decode_tok_s = steady_tokens as f64 / steady_s.max(1e-9);

        // the long admission, mid-stream
        let (tx, rl) = std::sync::mpsc::channel();
        b.enqueue(Job::new(Request::greedy(99, long_prompt.clone(), 2, ""), tx));
        let mut max_round_ms = 0.0f64;
        let mut window_rounds = 0usize;
        let window_t0 = Instant::now();
        loop {
            let t0 = Instant::now();
            b.round();
            max_round_ms = max_round_ms.max(t0.elapsed().as_secs_f64() * 1e3);
            window_rounds += 1;
            if b.n_prefilling() == 0 {
                break;
            }
            assert!(window_rounds < 8192, "admission never completed");
        }
        let prefill_tok_s = long_tokens as f64 / window_t0.elapsed().as_secs_f64().max(1e-9);
        let stall = max_round_ms / steady.p50.max(1e-9);
        if chunk == 256 {
            gate_decode_tok_s = decode_tok_s;
            gate_stall_chunked = stall;
        }
        if chunk == 0 {
            stall_monolithic = stall;
        }
        println!(
            "chunk={:<5} decode {decode_tok_s:>8.1} tok/s  round p50 {:>7.4} ms  \
             admission: {window_rounds:>3} rounds, max {max_round_ms:>8.3} ms, stall ×{stall:<8.2} \
             prefill {prefill_tok_s:>8.0} tok/s",
            if chunk == 0 { "mono".into() } else { chunk.to_string() },
            steady.p50,
        );
        info.push(format!(
            "    {{\"prefill_chunk\": {chunk}, \"decode_tokens_per_s\": {decode_tok_s:.1}, \
             \"decode_round_p50_ms\": {:.6}, \"admission_rounds\": {window_rounds}, \
             \"admission_max_round_ms\": {max_round_ms:.6}, \"stall_ratio\": {stall:.3}, \
             \"prefill_tokens_per_s\": {prefill_tok_s:.0}}}",
            steady.p50,
        ));
        // drain so the next config starts clean (and the long reply is real)
        for _ in 0..4096 {
            if !b.has_work() {
                break;
            }
            b.round();
        }
        let long_reply = rl.try_recv().expect("long admission never replied");
        assert!(long_reply.error.is_none(), "{:?}", long_reply.error);
    }
    if stall_monolithic.is_finite() && gate_stall_chunked.is_finite() {
        println!(
            "\nchunked admission cuts the worst round ×{:.1} vs monolithic\n",
            stall_monolithic / gate_stall_chunked.max(1e-9)
        );
    }
    let json = format!(
        "{{\n  \"bench\": \"pr5_serving\",\n  \"smoke\": {smoke},\n  \
         \"config\": {{\"sessions\": {n_sessions}, \"long_prompt_tokens\": {long_tokens}, \
         \"method\": \"lexico:s=2,nb=8\", \"pool_threads\": {}}},\n  \
         \"gate\": {{\n    \"attend_ns_per_token\": {attend_ns_per_token:.2},\n    \
         \"decode_tokens_per_s\": {gate_decode_tok_s:.1}\n  }},\n  \
         \"entries\": [\n{}\n  ]\n}}\n",
        engine.pool().threads(),
        info.join(",\n")
    );
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_PR5.json"))
        .unwrap_or_else(|| "BENCH_PR5.json".into());
    std::fs::write(&out_path, &json)?;
    println!("wrote {}\n", out_path.display());
    Ok(())
}

/// Sweep parameters shared by the parent run and the `--pr6-child`
/// re-exec — both must measure identical shapes for the series to line up.
fn pr6_params(smoke: bool) -> (usize, &'static [usize], &'static [usize], usize, usize) {
    let t_tokens = if smoke { 512 } else { 1024 };
    let atom_counts: &[usize] = if smoke { &[1024, 4096] } else { &[1024, 4096, 16384] };
    let sessions: &[usize] = if smoke { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let (warm, iters) = if smoke { (2, 8) } else { (5, 20) };
    (t_tokens, atom_counts, sessions, warm, iters)
}

const PR6_SHAPE: CacheShape = CacheShape { n_layers: 1, n_heads: 8, n_kv_heads: 4, head_dim: 64 };

fn pr6_dicts(n_atoms: usize) -> Arc<DictionarySet> {
    let m = PR6_SHAPE.head_dim;
    Arc::new(DictionarySet {
        keys: vec![Dictionary::random(m, n_atoms, 21)],
        values: vec![Dictionary::random(m, n_atoms, 22)],
    })
}

/// Fill one prototype through the real append path, then fork it B−1
/// times — sessions share compressed pages physically (the serving
/// shape), so only the per-session scratch and buffers differ.
fn pr6_sessions(
    dicts: &Arc<DictionarySet>,
    t_tokens: usize,
    b: usize,
) -> Vec<Box<dyn KvCache>> {
    let shape = PR6_SHAPE;
    let cfg = LexicoConfig { sparsity: 8, n_buffer: 32, ..Default::default() };
    let mut proto = LexicoCache::new(shape, dicts.clone(), cfg);
    proto.set_runtime(&bench_rt(lexico::exec::default_pool()));
    let mut rng = Rng::new(17);
    let kvd = shape.kv_dim();
    let mut done = 0usize;
    while done < t_tokens {
        let chunk = 512.min(t_tokens - done);
        let ks = rng.normal_vec(chunk * kvd);
        let vs = rng.normal_vec(chunk * kvd);
        proto.append_batch(0, &ks, &vs, chunk);
        done += chunk;
    }
    let mut caches: Vec<Box<dyn KvCache>> = (0..b - 1).map(|_| proto.fork()).collect();
    caches.push(Box::new(proto));
    caches
}

/// One round of the shared-qd protocol over B sessions, exactly as
/// `Engine::decode_batch` drives it per layer: one GEMM of all B·n_heads
/// query rows against D_k, per-session begin (scores + softmax + base
/// z-bins), one ascending-atom value pass over every session's bins,
/// per-session finish (adaptive extras + buffer).
fn pr6_round_attend(
    pool: &ExecPool,
    caches: &mut [Box<dyn KvCache>],
    dicts: &DictionarySet,
    qs: &[f32],
    out: &mut [f32],
    qd_round: &mut Vec<f32>,
    z_round: &mut Vec<f32>,
) {
    let shape = PR6_SHAPE;
    let (m, nh, qd) = (shape.head_dim, shape.n_heads, shape.q_dim());
    let b = caches.len();
    let (dk, dv) = (&dicts.keys[0], &dicts.values[0]);
    qd_round.resize(b * nh * dk.n, 0.0);
    par_matmul_bt(pool, qd_round, qs, &dk.atoms, b * nh, m, dk.n);
    z_round.resize(b * nh * dv.n, 0.0);
    for (bi, c) in caches.iter_mut().enumerate() {
        out[bi * qd..(bi + 1) * qd].fill(0.0);
        c.begin_shared_attend(
            0,
            &qs[bi * qd..(bi + 1) * qd],
            &qd_round[bi * nh * dk.n..(bi + 1) * nh * dk.n],
            &mut z_round[bi * nh * dv.n..(bi + 1) * nh * dv.n],
        );
    }
    for n in 0..dv.n {
        let atom = &dv.atoms[n * m..(n + 1) * m];
        for r in 0..b * nh {
            let zn = z_round[r * dv.n + n];
            if zn != 0.0 {
                let (bi, h) = (r / nh, r % nh);
                axpy(&mut out[bi * qd + h * m..bi * qd + (h + 1) * m], zn, atom);
            }
        }
    }
    for (bi, c) in caches.iter_mut().enumerate() {
        c.finish_shared_attend(0, &mut out[bi * qd..(bi + 1) * qd]);
    }
}

/// `--pr6-child <out>`: round-path timings only, under whatever kernel
/// tier the environment selected. The parent re-execs us with
/// `LEXICO_FAST_MATH=1` because kernel dispatch freezes per process.
fn pr6_child(out_path: &str, smoke: bool) -> anyhow::Result<()> {
    let (t_tokens, atom_counts, sessions, warm, iters) = pr6_params(smoke);
    let pool = lexico::exec::default_pool();
    let qd_dim = PR6_SHAPE.q_dim();
    let mut lines = String::new();
    for &n_atoms in atom_counts {
        let dicts = pr6_dicts(n_atoms);
        for &b in sessions {
            let mut caches = pr6_sessions(&dicts, t_tokens, b);
            let mut rng = Rng::new(99);
            let qs = rng.normal_vec(b * qd_dim);
            let mut out = vec![0.0; b * qd_dim];
            let (mut qd_round, mut z_round) = (Vec::new(), Vec::new());
            let st = bench_ms(warm, iters, || {
                pr6_round_attend(
                    &pool, &mut caches, &dicts, &qs, &mut out, &mut qd_round, &mut z_round,
                );
            });
            lines.push_str(&format!(
                "b={b} n={n_atoms} ns_per_token={:.2}\n",
                st.mean * 1e6 / (b * t_tokens) as f64
            ));
        }
    }
    std::fs::write(out_path, lines)?;
    Ok(())
}

/// Run the fast-math series in a child process (fresh kernel dispatch)
/// and collect its (B, N) → ns/token map. A child failure degrades to an
/// empty map — the fast series is reported as `null`, not a bench abort.
fn pr6_fast_series(
    smoke: bool,
) -> std::collections::BTreeMap<(usize, usize), f64> {
    let mut map = std::collections::BTreeMap::new();
    let exe = match std::env::current_exe() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("warning: current_exe failed ({e}); fast-math series omitted");
            return map;
        }
    };
    let tmp = std::env::temp_dir().join(format!("lexico_pr6_fast_{}.txt", std::process::id()));
    let mut cmd = std::process::Command::new(&exe);
    cmd.arg("--pr6-child")
        .arg(&tmp)
        .arg("--threads")
        .arg(lexico::exec::default_pool().threads().to_string())
        .env("LEXICO_FAST_MATH", "1");
    if smoke {
        cmd.arg("--smoke");
    }
    match cmd.status() {
        Ok(s) if s.success() => {}
        other => {
            eprintln!("warning: fast-math child failed ({other:?}); fast-math series omitted");
            return map;
        }
    }
    let text = std::fs::read_to_string(&tmp).unwrap_or_default();
    let _ = std::fs::remove_file(&tmp);
    for line in text.lines() {
        let (mut b, mut n, mut v) = (None, None, None);
        for part in line.split_whitespace() {
            if let Some(x) = part.strip_prefix("b=") {
                b = x.parse::<usize>().ok();
            } else if let Some(x) = part.strip_prefix("n=") {
                n = x.parse::<usize>().ok();
            } else if let Some(x) = part.strip_prefix("ns_per_token=") {
                v = x.parse::<f64>().ok();
            }
        }
        if let (Some(b), Some(n), Some(v)) = (b, n, v) {
            map.insert((b, n), v);
        }
    }
    map
}

/// PR 6 shared-dictionary round sweep: per-session attend (the old path,
/// every cache projecting q against D_k itself) vs the round-level
/// shared-qd protocol, vs the same protocol under the fast-math tier, at
/// B sessions × N atoms. The round path is asserted bitwise-identical to
/// the per-session path at every cell before timing; the fast series is
/// tolerance-equal only (separate process, separate series). Emits
/// `BENCH_PR6.json`; its `gate` object feeds `benches/compare.rs` against
/// `benches/baseline_pr6.json`.
fn shared_qd_round_sweep(smoke: bool) -> anyhow::Result<()> {
    let (t_tokens, atom_counts, sessions, warm, iters) = pr6_params(smoke);
    let pool = lexico::exec::default_pool();
    let shape = PR6_SHAPE;
    let qd_dim = shape.q_dim();
    println!(
        "PR6 shared-dictionary round attend (s=8, m={}, kv_heads={}, T={t_tokens}) — \
         simd={}, pool T={}:\n",
        shape.head_dim,
        shape.n_kv_heads,
        lexico::tensor::simd::active().name,
        pool.threads()
    );
    let fast = pr6_fast_series(smoke);
    let mut entries = Vec::new();
    let mut gate_old = f64::NAN;
    let mut gate_round = f64::NAN;
    for &n_atoms in atom_counts {
        let dicts = pr6_dicts(n_atoms);
        for &b in sessions {
            let mut caches = pr6_sessions(&dicts, t_tokens, b);
            let mut rng = Rng::new(99);
            let qs = rng.normal_vec(b * qd_dim);
            let mut out_old = vec![0.0; b * qd_dim];
            let mut out_round = vec![0.0; b * qd_dim];
            let (mut qd_round, mut z_round) = (Vec::new(), Vec::new());
            // parity first: the round protocol must be bit-identical to
            // per-session attend on the exact contents it will be timed on
            for (bi, c) in caches.iter_mut().enumerate() {
                c.attend(
                    0,
                    &qs[bi * qd_dim..(bi + 1) * qd_dim],
                    &mut out_old[bi * qd_dim..(bi + 1) * qd_dim],
                );
            }
            pr6_round_attend(
                &pool, &mut caches, &dicts, &qs, &mut out_round, &mut qd_round, &mut z_round,
            );
            assert!(
                out_old.iter().zip(&out_round).all(|(a, b)| a.to_bits() == b.to_bits()),
                "round-level shared-qd attend diverged from per-session attend \
                 (N={n_atoms} B={b})"
            );
            let st_old = bench_ms(warm, iters, || {
                for (bi, c) in caches.iter_mut().enumerate() {
                    c.attend(
                        0,
                        &qs[bi * qd_dim..(bi + 1) * qd_dim],
                        &mut out_old[bi * qd_dim..(bi + 1) * qd_dim],
                    );
                }
            });
            let st_round = bench_ms(warm, iters, || {
                pr6_round_attend(
                    &pool, &mut caches, &dicts, &qs, &mut out_round, &mut qd_round, &mut z_round,
                );
            });
            let ns_tok = |mean_ms: f64| mean_ms * 1e6 / (b * t_tokens) as f64;
            let (old_ns, round_ns) = (ns_tok(st_old.mean), ns_tok(st_round.mean));
            let fast_ns = fast.get(&(b, n_atoms)).copied();
            if n_atoms == atom_counts[0] && b == *sessions.last().unwrap() {
                gate_old = old_ns;
                gate_round = round_ns;
            }
            println!(
                "N={n_atoms:<6} B={b:<3} per-session {old_ns:>8.1} ns/tok  \
                 round-gemm {round_ns:>8.1} ns/tok  speedup ×{:<5.2} fast {}",
                old_ns / round_ns.max(1e-9),
                fast_ns
                    .map(|v| format!("{v:.1} ns/tok"))
                    .unwrap_or_else(|| "n/a".into()),
            );
            entries.push(format!(
                "    {{\"n_atoms\": {n_atoms}, \"sessions\": {b}, \"tokens\": {t_tokens}, \
                 \"old_attend_ns_per_token\": {old_ns:.2}, \
                 \"round_attend_ns_per_token\": {round_ns:.2}, \
                 \"speedup_round_vs_old\": {:.3}, \
                 \"fast_round_attend_ns_per_token\": {}}}",
                old_ns / round_ns.max(1e-9),
                fast_ns.map(|v| format!("{v:.2}")).unwrap_or_else(|| "null".into()),
            ));
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"pr6_shared_qd_round\",\n  \"simd\": \"{}\",\n  \"smoke\": {smoke},\n  \
         \"config\": {{\"sparsity\": 8, \"n_buffer\": 32, \"head_dim\": {}, \"n_heads\": {}, \
         \"n_kv_heads\": {}, \"tokens\": {t_tokens}, \"pool_threads\": {}}},\n  \
         \"gate\": {{\n    \"round_attend_ns_per_token\": {gate_round:.2},\n    \
         \"old_attend_ns_per_token\": {gate_old:.2}\n  }},\n  \"entries\": [\n{}\n  ]\n}}\n",
        lexico::tensor::simd::active().name,
        shape.head_dim,
        shape.n_heads,
        shape.n_kv_heads,
        pool.threads(),
        entries.join(",\n")
    );
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_PR6.json"))
        .unwrap_or_else(|| "BENCH_PR6.json".into());
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {}\n", out_path.display());
    Ok(())
}

/// Fill one Lexico cache to `t_tokens` through the real append path (same
/// shape and config as the PR 4 sweep) and attach it to `store`.
fn pr7_filled_cache(store: &Arc<SpillStore>, t_tokens: usize) -> LexicoCache {
    let shape = PR6_SHAPE;
    let cfg = LexicoConfig { sparsity: 8, n_buffer: 32, ..Default::default() };
    let dicts = pr6_dicts(512);
    let mut cache = LexicoCache::new(shape, dicts, cfg);
    cache.set_runtime(&bench_rt(Arc::new(ExecPool::new(1))).with_spill(store.clone()));
    let mut rng = Rng::new(23);
    let kvd = shape.kv_dim();
    let mut done = 0usize;
    while done < t_tokens {
        let chunk = 512.min(t_tokens - done);
        let ks = rng.normal_vec(chunk * kvd);
        let vs = rng.normal_vec(chunk * kvd);
        cache.append_batch(0, &ks, &vs, chunk);
        done += chunk;
    }
    cache
}

/// PR 7 tiered-residency sweep (artifact-free): sealed pages round-trip
/// through the append-only page store. Measures spill and fault throughput
/// (MB of resident KV state moved per second), per-page fault latency, the
/// first-touch attend penalty after a full spill (pages fault lazily inside
/// attend), and the resident fleet's attend cost while half its sessions
/// are hibernated on disk. Emits `BENCH_PR7.json`; its `gate` object feeds
/// `benches/compare.rs` against `benches/baseline_pr7.json`.
fn spill_residency_sweep(smoke: bool) -> anyhow::Result<()> {
    let sizes: &[usize] = if smoke { &[1536] } else { &[2048, 8192] };
    let rounds = if smoke { 8 } else { 20 };
    let dir = std::env::temp_dir().join(format!("lexico_pr7_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "PR7 tiered KV residency (s=8, N=512, m={}, kv_heads={}):\n",
        PR6_SHAPE.head_dim, PR6_SHAPE.n_kv_heads
    );
    let mut entries = Vec::new();
    let mut gate_spill = f64::NAN;
    let mut gate_fault = f64::NAN;
    for (si, &t_tokens) in sizes.iter().enumerate() {
        let size_dir = dir.join(format!("sz{si}"));
        let store = Arc::new(SpillStore::open(&size_dir).map_err(anyhow::Error::msg)?);
        let mut cache = pr7_filled_cache(&store, t_tokens);
        // spill ⇄ fault round trips: every sealed page through the page
        // file and back, `rounds` times (the file is append-only, so disk
        // usage grows; the ref the cache holds always points at its latest
        // copy)
        let (mut spill_s, mut fault_s) = (0.0f64, 0.0f64);
        let (mut moved, mut pages) = (0.0f64, 0usize);
        for _ in 0..rounds {
            let t0 = Instant::now();
            let (n, bytes) = cache.spill_cold().map_err(anyhow::Error::msg)?;
            spill_s += t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let (nf, _) = cache.fault_resident().map_err(anyhow::Error::msg)?;
            fault_s += t0.elapsed().as_secs_f64();
            assert_eq!(n, nf, "every spilled page must fault back");
            moved += bytes;
            pages += n;
        }
        let spill_mb_s = moved / 1e6 / spill_s.max(1e-9);
        let fault_mb_s = moved / 1e6 / fault_s.max(1e-9);
        let fault_us_page = fault_s * 1e6 / (pages as f64).max(1.0);
        // first-touch attend after a full spill: attend faults the pages it
        // needs lazily, so one call pays the whole wake-up
        let mut rng = Rng::new(31);
        let q = rng.normal_vec(PR6_SHAPE.q_dim());
        let mut out = vec![0.0; PR6_SHAPE.q_dim()];
        let mut cold_s = 0.0f64;
        for _ in 0..rounds {
            let _ = cache.spill_cold().map_err(anyhow::Error::msg)?;
            let t0 = Instant::now();
            cache.attend(0, &q, &mut out);
            cold_s += t0.elapsed().as_secs_f64();
        }
        let cold_ms = cold_s * 1e3 / rounds as f64;
        let warm = bench_ms(3, 4 * rounds, || cache.attend(0, &q, &mut out));
        if gate_spill.is_nan() {
            gate_spill = spill_mb_s;
            gate_fault = fault_mb_s;
        }
        println!(
            "T={t_tokens:<6} spill {spill_mb_s:>8.1} MB/s  fault {fault_mb_s:>8.1} MB/s \
             ({fault_us_page:>6.1} µs/page)  first-touch attend {cold_ms:>8.4} ms  \
             warm {:>8.4} ms",
            warm.mean
        );
        entries.push(format!(
            "    {{\"tokens\": {t_tokens}, \"pages_per_round\": {}, \
             \"spill_mb_per_s\": {spill_mb_s:.1}, \"fault_mb_per_s\": {fault_mb_s:.1}, \
             \"fault_us_per_page\": {fault_us_page:.2}, \
             \"cold_first_attend_ms\": {cold_ms:.6}, \"warm_attend_ms\": {:.6}}}",
            pages / rounds,
            warm.mean
        ));
    }
    // half-hibernated fleet: 8 forked sessions sharing one prefilled
    // prototype; 4 spill to disk, the resident 4 keep decoding. Their
    // attend cost must not move — hibernated neighbours cost disk, not time.
    let fleet_t = sizes[0];
    let store = Arc::new(SpillStore::open(&dir.join("fleet")).map_err(anyhow::Error::msg)?);
    let proto = pr7_filled_cache(&store, fleet_t);
    let mut fleet: Vec<Box<dyn KvCache>> = (0..7).map(|_| proto.fork()).collect();
    fleet.push(Box::new(proto));
    let mut rng = Rng::new(37);
    let q = rng.normal_vec(PR6_SHAPE.q_dim());
    let mut out = vec![0.0; PR6_SHAPE.q_dim()];
    let all_resident = bench_ms(3, 2 * rounds, || {
        for c in fleet.iter_mut().take(4) {
            c.attend(0, &q, &mut out);
        }
    });
    let mut freed = 0.0f64;
    for c in fleet.iter_mut().skip(4) {
        let (_, bytes) = c.spill_cold().map_err(anyhow::Error::msg)?;
        freed += bytes;
    }
    let half_spilled = bench_ms(3, 2 * rounds, || {
        for c in fleet.iter_mut().take(4) {
            c.attend(0, &q, &mut out);
        }
    });
    let ns_tok = |mean_ms: f64| mean_ms * 1e6 / (4 * fleet_t) as f64;
    println!(
        "\nfleet of 8 @ T={fleet_t}: resident-4 attend {:.1} ns/tok all-resident, \
         {:.1} ns/tok with 4 sessions hibernated ({:.1} KiB freed to disk)\n",
        ns_tok(all_resident.mean),
        ns_tok(half_spilled.mean),
        freed / 1024.0
    );
    let json = format!(
        "{{\n  \"bench\": \"pr7_tiered_residency\",\n  \"smoke\": {smoke},\n  \
         \"config\": {{\"sparsity\": 8, \"n_buffer\": 32, \"n_atoms\": 512, \"head_dim\": {}, \
         \"n_kv_heads\": {}, \"rounds\": {rounds}}},\n  \
         \"gate\": {{\n    \"spill_mb_per_s\": {gate_spill:.1},\n    \
         \"fault_mb_per_s\": {gate_fault:.1}\n  }},\n  \
         \"fleet\": {{\"sessions\": 8, \"hibernated\": 4, \"tokens\": {fleet_t}, \
         \"all_resident_attend_ns_per_token\": {:.2}, \
         \"half_hibernated_attend_ns_per_token\": {:.2}, \"freed_bytes\": {freed:.0}}},\n  \
         \"entries\": [\n{}\n  ]\n}}\n",
        PR6_SHAPE.head_dim,
        PR6_SHAPE.n_kv_heads,
        ns_tok(all_resident.mean),
        ns_tok(half_spilled.mean),
        entries.join(",\n")
    );
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_PR7.json"))
        .unwrap_or_else(|| "BENCH_PR7.json".into());
    std::fs::write(&out_path, &json)?;
    println!("wrote {}\n", out_path.display());
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// PR 8 precomputed-Gram Batch-OMP sweep: the canonical residual-space
/// pursuit vs the coefficient-space Gram tier on identical inputs, across
/// batch size B × atom count N × sparsity s at m = 64. The one-time Gram
/// build (`par_syrk` at dictionary load) is timed separately — at serve
/// time it is paid once per process, not per compression. Also measures
/// end-to-end prefill tok/s through a tiny engine with a `LexicoCache`
/// on each tier (the construction runtime's encode tier), the
/// overflow-compression path the
/// server actually runs. Emits `BENCH_PR8.json`; its `gate` object feeds
/// `benches/compare.rs` against `benches/baseline_pr8.json`.
fn gram_encode_sweep(smoke: bool) -> anyhow::Result<()> {
    use lexico::omp::{omp_encode_batch, omp_encode_batch_gram, BatchOmpWorkspace};

    let m = 64usize;
    let delta = 0.0f32;
    let atom_counts: &[usize] = &[1024, 4096];
    let batches: &[usize] = if smoke { &[32, 256] } else { &[32, 256, 1024] };
    let sparsities: &[usize] = if smoke { &[8] } else { &[4, 8, 16] };
    let (warm, iters) = if smoke { (1, 3) } else { (2, 8) };
    let pool = lexico::exec::default_pool();
    println!(
        "PR8 precomputed-Gram Batch-OMP encode (m={m}, delta={delta}) — simd={}, pool T={}:\n",
        lexico::tensor::simd::active().name,
        pool.threads()
    );
    let max_b = *batches.iter().max().unwrap();
    let mut rng = Rng::new(41);
    let xs_all = rng.normal_vec(max_b * m);
    let mut ws_canon = BatchOmpWorkspace::with_pool(pool.clone());
    let mut ws_gram = BatchOmpWorkspace::with_pool(pool.clone());
    let mut entries = Vec::new();
    let mut builds = Vec::new();
    let mut gate_canon = f64::NAN;
    let mut gate_gram = f64::NAN;
    for &n_atoms in atom_counts {
        let dict = Dictionary::random(m, n_atoms, 51);
        let t0 = Instant::now();
        let gram = dict.gram(&pool);
        let build_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "N={n_atoms:<6} gram build {build_ms:>9.3} ms ({:.1} MB, once per dictionary)",
            dict.gram_bytes() as f64 / 1e6
        );
        builds.push(format!(
            "    {{\"n_atoms\": {n_atoms}, \"build_ms\": {build_ms:.4}, \"gram_mb\": {:.2}}}",
            dict.gram_bytes() as f64 / 1e6
        ));
        for &s in sparsities {
            for &b in batches {
                let xs = &xs_all[..b * m];
                let st_canon = bench_ms(warm, iters, || {
                    let _ = omp_encode_batch(
                        &dict.atoms, n_atoms, m, xs, b, s, delta, &mut ws_canon,
                    );
                });
                let st_gram = bench_ms(warm, iters, || {
                    let _ = omp_encode_batch_gram(
                        &dict.atoms, n_atoms, m, &gram, xs, b, s, delta, &mut ws_gram,
                    );
                });
                let vecs_s = |mean_ms: f64| b as f64 / (mean_ms / 1e3).max(1e-12);
                let (canon_v, gram_v) = (vecs_s(st_canon.mean), vecs_s(st_gram.mean));
                let speedup = gram_v / canon_v.max(1e-9);
                if n_atoms == 4096 && s == 8 && b == 256 {
                    gate_canon = canon_v;
                    gate_gram = gram_v;
                }
                println!(
                    "N={n_atoms:<6} s={s:<3} B={b:<5} canonical {canon_v:>10.0} vecs/s  \
                     gram {gram_v:>10.0} vecs/s  speedup ×{speedup:.2}",
                );
                entries.push(format!(
                    "    {{\"n_atoms\": {n_atoms}, \"sparsity\": {s}, \"batch\": {b}, \
                     \"canon_vecs_per_s\": {canon_v:.0}, \"gram_vecs_per_s\": {gram_v:.0}, \
                     \"gram_speedup\": {speedup:.3}}}"
                ));
            }
        }
    }

    // End-to-end prefill on each tier: a tiny engine drives the real
    // overflow-compression path; the Gram matrices are realized before
    // timing so both series measure steady state.
    use lexico::model::testutil::tiny_weights_cfg;
    use lexico::model::ModelConfig;
    let prefill_tokens = if smoke { 320 } else { 640 };
    let cfg_model = ModelConfig {
        n_layers: 2,
        d_model: 128,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 64,
        d_ff: 128,
        vocab: tasks::vocab_size(),
        max_seq: prefill_tokens + 64,
    };
    let engine = Engine::new(tiny_weights_cfg(57, cfg_model));
    let dicts = Arc::new(DictionarySet {
        keys: (0..cfg_model.n_layers)
            .map(|i| Dictionary::random(cfg_model.head_dim, 1024, 300 + i as u64))
            .collect(),
        values: (0..cfg_model.n_layers)
            .map(|i| Dictionary::random(cfg_model.head_dim, 1024, 400 + i as u64))
            .collect(),
    });
    for d in dicts.keys.iter().chain(dicts.values.iter()) {
        let _ = d.gram(&pool);
    }
    let mut ids = vec![tasks::BOS];
    ids.extend(tasks::encode(&tasks::gen_lm_text(&mut Rng::new(43), prefill_tokens)));
    ids.truncate(prefill_tokens);
    let cache_cfg = LexicoConfig { sparsity: 8, n_buffer: 32, ..Default::default() };
    let mut prefill_tok_s = [f64::NAN; 2];
    for (ti, &gram_on) in [false, true].iter().enumerate() {
        let st = bench_ms(warm, iters, || {
            let mut cache = LexicoCache::new(engine.shape(), dicts.clone(), cache_cfg.clone());
            let tier = if gram_on { EncodeTier::Gram } else { EncodeTier::Canonical };
            cache.set_runtime(&bench_rt(pool.clone()).with_encode_tier(tier));
            let _ = engine.prefill(&ids, &mut cache);
        });
        prefill_tok_s[ti] = prefill_tokens as f64 / (st.mean / 1e3).max(1e-12);
    }
    let prefill_speedup = prefill_tok_s[1] / prefill_tok_s[0].max(1e-9);
    println!(
        "\nprefill {prefill_tokens} tokens (2-layer tiny engine, lexico s=8 nb=32 N=1024): \
         canonical {:.0} tok/s  gram {:.0} tok/s  speedup ×{prefill_speedup:.2}\n",
        prefill_tok_s[0], prefill_tok_s[1]
    );

    let json = format!(
        "{{\n  \"bench\": \"pr8_gram_encode\",\n  \"simd\": \"{}\",\n  \"smoke\": {smoke},\n  \
         \"config\": {{\"m\": {m}, \"delta\": {delta}, \"pool_threads\": {}}},\n  \
         \"gate\": {{\n    \"canon_encode_vecs_per_s\": {gate_canon:.0},\n    \
         \"gram_encode_vecs_per_s\": {gate_gram:.0}\n  }},\n  \
         \"gram_build\": [\n{}\n  ],\n  \
         \"prefill\": {{\"tokens\": {prefill_tokens}, \"canon_tokens_per_s\": {:.0}, \
         \"gram_tokens_per_s\": {:.0}, \"gram_speedup\": {prefill_speedup:.3}}},\n  \
         \"entries\": [\n{}\n  ]\n}}\n",
        lexico::tensor::simd::active().name,
        pool.threads(),
        builds.join(",\n"),
        prefill_tok_s[0],
        prefill_tok_s[1],
        entries.join(",\n")
    );
    let out_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_PR8.json"))
        .unwrap_or_else(|| "BENCH_PR8.json".into());
    std::fs::write(&out_path, &json)?;
    println!("wrote {}\n", out_path.display());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // --threads N (or --threads=N) sizes the default pool for the backend
    // comparison sections; the scaling sweep below builds its own pools.
    let argv: Vec<String> = std::env::args().collect();
    if let Some(t) = lexico::exec::threads_from_args(&argv).map_err(anyhow::Error::msg)? {
        if !lexico::exec::configure_default(t) {
            eprintln!("warning: exec pool already initialized; --threads {t} ignored");
        }
    }
    let smoke = argv.iter().any(|a| a == "--smoke");
    // internal re-exec target for the PR 6 fast-math series — must run
    // before anything else touches the kernels so dispatch freezes on the
    // tier LEXICO_FAST_MATH selected
    if let Some(i) = argv.iter().position(|a| a == "--pr6-child") {
        let out = argv
            .get(i + 1)
            .ok_or_else(|| anyhow::anyhow!("--pr6-child needs an output path"))?;
        return pr6_child(out, smoke);
    }
    // The PR 4–8 sweeps are artifact-free: they always run (reduced under
    // --smoke, which then skips the artifact-bound sections — CI's bench
    // smoke + perf-gate steps).
    let attend_ns = longcontext_attend_sweep(smoke)?;
    serving_round_sweep(smoke, attend_ns)?;
    shared_qd_round_sweep(smoke)?;
    spill_residency_sweep(smoke)?;
    gram_encode_sweep(smoke)?;
    if smoke {
        return Ok(());
    }
    let art = lexico::artifacts_dir();
    if !art.join("model_M.bin").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    println!("default exec pool: {} threads\n", engine.pool().threads());
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let mut rng = Rng::new(5);
    let t_ctx = 400;
    let mut prompt = vec![tasks::BOS];
    prompt.extend(tasks::encode(&tasks::gen_lm_text(&mut rng, t_ctx)));
    prompt.truncate(t_ctx);

    println!("native decode step at context {} per cache backend:\n", prompt.len());
    for spec in [
        "full",
        "lexico:s=8,nb=32",
        "lexico:s=4,nb=32",
        "kivi:bits=2,g=16,nb=16",
        "pertoken:bits=4,g=16,nb=4",
        "zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16",
        "snapkv:cap=64,win=8",
        "pyramidkv:cap=64,win=8",
    ] {
        let mut cache = build_cache(spec, &ctx)?;
        let _ = engine.prefill(&prompt, &mut *cache);
        let mut pos = prompt.len();
        let st = bench_ms(5, 40, || {
            let _ = engine.decode_step(7, pos, &mut *cache);
            pos += 1;
        });
        report(spec, &st);
    }

    // Batched decode throughput: B sessions, each with its own cache on the
    // same prompt, advanced one token per round via decode_batch. Weight
    // matrices stream once per layer per ROUND, so per-token cost should
    // fall markedly with B (acceptance target: ≥2× tokens/s at B=16 vs B=1
    // for lexico:s=8,nb=32).
    println!("\nbatched decode (B concurrent sessions) at context {}:\n", prompt.len());
    for spec in ["full", "lexico:s=8,nb=32", "kivi:bits=2,g=16,nb=16"] {
        let mut base = f64::NAN;
        for bsz in [1usize, 4, 16] {
            let mut caches: Vec<Box<dyn KvCache>> = Vec::with_capacity(bsz);
            for _ in 0..bsz {
                let mut c = build_cache(spec, &ctx)?;
                let _ = engine.prefill(&prompt, &mut *c);
                caches.push(c);
            }
            let toks: Vec<u32> = vec![7; bsz];
            let mut pos = prompt.len();
            let st = bench_ms(3, 25, || {
                let poss: Vec<usize> = vec![pos; bsz];
                let mut refs: Vec<&mut dyn KvCache> =
                    caches.iter_mut().map(|c| &mut **c).collect();
                let _ = engine.decode_batch(&toks, &poss, &mut refs);
                pos += 1;
            });
            let per_tok = st.mean / bsz as f64;
            if bsz == 1 {
                base = per_tok;
            }
            println!(
                "{spec:<28} B={bsz:<3} {per_tok:>9.4} ms/token  {:>8.1} tok/s  speedup ×{:.2}",
                1e3 / per_tok,
                base / per_tok
            );
        }
    }

    // Thread-scaling sweep: T × B over the exec layer. Each T gets its own
    // engine pinned to a T-thread pool; sessions fork one prefilled
    // prototype (cheap, and exactly the serving path). Reported per cell:
    // amortized ms/token, aggregate tokens/s, speedup over T=1 at the same
    // B, and parallel efficiency (speedup / T). Determinism means every
    // cell decodes the identical token stream — only the clock changes.
    println!("\nthread-scaling sweep (decode_batch, lexico:s=8,nb=32) at context {}:\n", prompt.len());
    {
        let spec = "lexico:s=8,nb=32";
        let mut base_tok_s = std::collections::BTreeMap::new(); // B → tok/s at T=1
        for &threads in &[1usize, 2, 4, 8] {
            let pool = Arc::new(ExecPool::new(threads));
            let eng_t = Engine::with_pool(Weights::load(art.join("model_M.bin"))?, pool.clone());
            for &bsz in &[1usize, 4, 16] {
                let mut proto = build_cache(spec, &ctx)?;
                proto.set_runtime(&bench_rt(pool.clone()));
                let _ = eng_t.prefill(&prompt, &mut *proto);
                let mut caches: Vec<Box<dyn KvCache>> =
                    (0..bsz - 1).map(|_| proto.fork()).collect();
                caches.push(proto);
                let toks: Vec<u32> = vec![7; bsz];
                let mut pos = prompt.len();
                let st = bench_ms(3, 20, || {
                    let poss: Vec<usize> = vec![pos; bsz];
                    let mut refs: Vec<&mut dyn KvCache> =
                        caches.iter_mut().map(|c| &mut **c).collect();
                    let _ = eng_t.decode_batch(&toks, &poss, &mut refs);
                    pos += 1;
                });
                let tok_s = bsz as f64 * 1e3 / st.mean;
                let base = *base_tok_s.entry(bsz).or_insert(tok_s);
                let speedup = tok_s / base;
                println!(
                    "T={threads:<2} B={bsz:<3} {:>9.4} ms/token  {:>8.1} tok/s  speedup ×{speedup:<5.2} efficiency {:>5.1}%",
                    st.mean / bsz as f64,
                    tok_s,
                    100.0 * speedup / threads as f64
                );
            }
        }
    }

    // Shared-prefix amortization: serving N sessions that share a prompt
    // prefix. Cold = every session prefills the whole prompt; prefix-hit =
    // the prefix is prefilled (and captured) once, then each session is a
    // fork of the prototype + a suffix-only prefill — the batcher's
    // admission path on a prefix-cache hit. The gap is the serving win;
    // for lexico the fork also shares the compressed prefix pages
    // physically (shared_prefix_bytes reported below).
    let n_sessions = 8;
    let split = prompt.len() - 16;
    println!(
        "\nshared-prefix prefill amortization ({} prefix + {} suffix tokens, {} sessions):\n",
        split,
        prompt.len() - split,
        n_sessions
    );
    for spec in ["full", "lexico:s=8,nb=32"] {
        let st_cold = bench_ms(1, 4, || {
            for _ in 0..n_sessions {
                let mut c = build_cache(spec, &ctx).unwrap();
                let _ = engine.prefill(&prompt, &mut *c);
            }
        });
        let mut proto = build_cache(spec, &ctx)?;
        let (_, state) = engine.prefill_capture(&prompt[..split], &mut *proto);
        let mut shared_bytes = 0.0;
        let st_hit = bench_ms(1, 4, || {
            for _ in 0..n_sessions {
                let mut c = proto.fork();
                let _ = engine.prefill_suffix(&state, &prompt[split..], &mut *c);
                shared_bytes = c.shared_prefix_bytes();
            }
        });
        println!(
            "{spec:<24} cold {:>8.2} ms/session   prefix-hit {:>8.2} ms/session   amortization ×{:.1}   shared {:.1} KiB/fork",
            st_cold.mean / n_sessions as f64,
            st_hit.mean / n_sessions as f64,
            st_cold.mean / st_hit.mean.max(1e-9),
            shared_bytes / 1024.0
        );
    }

    // Multi-query attend_batch against ONE prefilled cache — the fan-out
    // candidate-scoring shape (b independent queries, one stored state):
    // one streaming pass over the dictionaries / K/V serves every query.
    println!("\nmulti-query attend_batch on one prefilled cache:\n");
    for spec in ["full", "lexico:s=8,nb=32", "kivi:bits=2,g=16,nb=16"] {
        let mut cache = build_cache(spec, &ctx)?;
        let _ = engine.prefill(&prompt, &mut *cache);
        let qd = engine.shape().q_dim();
        let n_layers = engine.shape().n_layers;
        let mut base = f64::NAN;
        for bsz in [1usize, 4, 16] {
            let qs = rng.normal_vec(bsz * qd);
            let mut out = vec![0.0; bsz * qd];
            let st = bench_ms(2, 20, || {
                for l in 0..n_layers {
                    cache.attend_batch(l, &qs, &mut out, bsz);
                }
            });
            let per_q = st.mean / bsz as f64;
            if bsz == 1 {
                base = per_q;
            }
            println!(
                "{spec:<28} b={bsz:<3} {per_q:>9.4} ms/query  speedup ×{:.2}",
                base / per_q
            );
        }
    }

    // PJRT path (dense cache graph) for the cross-engine comparison
    if art.join("model.hlo.txt").exists() {
        println!("\nPJRT decode (AOT artifacts through the XLA CPU client):\n");
        let pjrt = lexico::runtime::PjrtEngine::load(&art, &art.join("model_M.bin"))?;
        let short: Vec<u32> = prompt.iter().copied().take(120).collect();
        let st = bench_ms(1, 5, || {
            let _ = pjrt.generate(&short, 8, None).unwrap();
        });
        report("pjrt generate (120-tok prefill + 8 decode)", &st);
    }
    Ok(())
}
