//! OMP kernel micro-bench: vectors/second vs (N, s, δ) — the L3 hot-path
//! profile that drives the §Perf iteration in EXPERIMENTS.md.
//!
//!   cargo bench --bench omp_throughput

use lexico::dict::Dictionary;
use lexico::omp::{omp_encode, OmpWorkspace};
use lexico::util::rng::Rng;
use lexico::util::stats::{bench_ms, report};

fn main() {
    let m = 32;
    let mut rng = Rng::new(1);
    let batch = 64;
    let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(m)).collect();
    println!("batched OMP, head_dim={m}, {batch} vectors per iteration\n");
    for n_atoms in [256usize, 1024, 4096] {
        let d = Dictionary::random(m, n_atoms, 7);
        for s in [4usize, 8, 16] {
            let mut ws = OmpWorkspace::new(n_atoms, m, s);
            let st = bench_ms(3, 20, || {
                for x in &xs {
                    let _ = omp_encode(&d.atoms, n_atoms, m, x, s, 0.0, &mut ws);
                }
            });
            let vps = batch as f64 / (st.mean / 1e3);
            report(&format!("N={n_atoms:<5} s={s:<3} ({vps:>9.0} vec/s)"), &st);
        }
        // threshold mode at δ=0.4 (early termination saves iterations)
        let mut ws = OmpWorkspace::new(n_atoms, m, 16);
        let st = bench_ms(3, 20, || {
            for x in &xs {
                let _ = omp_encode(&d.atoms, n_atoms, m, x, 16, 0.4, &mut ws);
            }
        });
        report(&format!("N={n_atoms:<5} delta=0.4 (max s=16)"), &st);
    }
}
