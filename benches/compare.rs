//! Perf-regression gate: diff a fresh `BENCH_PR5.json` against the
//! committed `benches/baseline.json` and fail (non-zero exit) on a >25%
//! regression in any gated metric — attend ns/token (lower is better) or
//! decode tokens/s (higher is better). CI runs this after the bench smoke
//! step on every PR, so a kernel or scheduler regression fails the job
//! instead of merging silently.
//!
//!   cargo bench --bench decode_engines -- --smoke        # writes BENCH_PR5.json
//!   cargo bench --bench compare -- BENCH_PR5.json benches/baseline.json
//!
//! Bootstrapping: a baseline with `"bootstrap": true` (or an empty `gate`
//! object) applies no gate — the committed placeholder until someone runs
//! the smoke bench on the reference machine and records real numbers:
//!
//!   cargo bench --bench compare -- BENCH_PR5.json benches/baseline.json --write-baseline
//!
//! Metric direction is inferred from the key: `*_per_s` regresses when it
//! falls, `*_ns_*`/`*_ms_*` regress when they rise.

use anyhow::{bail, Context, Result};
use lexico::util::json::Json;
use std::path::{Path, PathBuf};

const MAX_REGRESSION: f64 = 0.25;

/// Bench binaries run with cwd = the package root (`rust/`); resolve
/// workspace-root-relative paths (where the smoke bench writes its JSON)
/// so CI can pass plain `BENCH_PR5.json` / `benches/baseline.json`.
fn resolve(p: &str) -> PathBuf {
    let direct = PathBuf::from(p);
    if direct.exists() || direct.is_absolute() {
        return direct;
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|root| root.join(p))
        .filter(|q| q.exists())
        .unwrap_or(direct)
}

fn load(path: &Path) -> Result<Json> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let files: Vec<&String> = argv.iter().filter(|a| !a.starts_with("--")).collect();
    let write_baseline = argv.iter().any(|a| a == "--write-baseline");
    if files.is_empty() {
        // a bare `cargo bench` runs every harness=false target with no
        // args — that must stay green; the gate only engages when CI
        // passes the two report files explicitly
        println!(
            "compare: no reports given, nothing to gate.\n\
             usage: cargo bench --bench compare -- <current.json> <baseline.json> [--write-baseline]"
        );
        return Ok(());
    }
    if files.len() != 2 {
        bail!("usage: cargo bench --bench compare -- <current.json> <baseline.json> [--write-baseline]");
    }
    let cur_path = resolve(files[0]);
    let cur = load(&cur_path)?;
    let gate = cur.get("gate");
    let Some(gate_obj) = gate.as_obj() else {
        bail!("{}: no \"gate\" object — not a PR5 bench report", cur_path.display());
    };

    if write_baseline {
        let base_path = resolve(files[1]);
        let smoke = cur.get("smoke").as_bool().unwrap_or(false);
        let fields = vec![
            ("bench", cur.get("bench").clone()),
            ("bootstrap", Json::Bool(false)),
            ("recorded_from", Json::Str(format!("smoke={smoke}"))),
            ("gate", gate.clone()),
        ];
        let obj = lexico::util::json::obj(fields);
        std::fs::write(&base_path, obj.to_string() + "\n")
            .with_context(|| format!("writing {}", base_path.display()))?;
        println!(
            "recorded baseline with {} gated metrics to {}",
            gate_obj.len(),
            base_path.display()
        );
        return Ok(());
    }

    let base_path = resolve(files[1]);
    let base = match load(&base_path) {
        Ok(b) => b,
        Err(e) => {
            println!("no baseline ({e}); perf gate skipped — commit one with --write-baseline");
            return Ok(());
        }
    };
    // a baseline recorded from a full run is not comparable to a --smoke
    // run (different sweep sizes and round counts shift every gated
    // metric systematically) — refuse to gate across workloads
    if let Some(recorded) = base.get("recorded_from").as_str() {
        let cur_workload = format!("smoke={}", cur.get("smoke").as_bool().unwrap_or(false));
        if recorded != cur_workload {
            println!(
                "baseline was recorded from '{recorded}' but this run is '{cur_workload}' — \
                 workloads differ, perf gate skipped. Re-record the baseline from the same \
                 bench mode CI runs (--smoke)."
            );
            return Ok(());
        }
    }
    let bootstrap = base.get("bootstrap").as_bool().unwrap_or(false);
    let base_gate = base.get("gate").as_obj().cloned().unwrap_or_default();
    if bootstrap || base_gate.is_empty() {
        println!(
            "baseline {} is bootstrap-only — no gate applied.\n\
             Record real numbers on the reference machine with:\n  \
             cargo bench --bench decode_engines -- --smoke\n  \
             cargo bench --bench compare -- BENCH_PR5.json benches/baseline.json --write-baseline",
            base_path.display()
        );
        return Ok(());
    }

    let mut failures = Vec::new();
    for (key, bval) in &base_gate {
        let Some(b) = bval.as_f64() else { continue };
        let Some(c) = gate.get(key).as_f64() else {
            failures.push(format!("{key}: present in baseline but missing from the current run"));
            continue;
        };
        // direction by key convention: throughputs regress downward,
        // latencies regress upward
        let higher_is_better = key.contains("per_s");
        let regression = if higher_is_better { (b - c) / b } else { (c - b) / b };
        let verdict = if regression > MAX_REGRESSION { "FAIL" } else { "ok" };
        println!(
            "{verdict:<4} {key:<24} baseline {b:>12.2}  current {c:>12.2}  change {:+.1}%",
            -regression * 100.0 * if higher_is_better { 1.0 } else { -1.0 }
        );
        if regression > MAX_REGRESSION {
            failures.push(format!(
                "{key}: {:.1}% regression (baseline {b:.2} → current {c:.2}, limit {:.0}%)",
                regression * 100.0,
                MAX_REGRESSION * 100.0
            ));
        }
    }
    if !failures.is_empty() {
        bail!("perf regression gate failed:\n  {}", failures.join("\n  "));
    }
    println!(
        "perf gate passed ({} metrics within {:.0}%)",
        base_gate.len(),
        MAX_REGRESSION * 100.0
    );
    Ok(())
}
