//! Adaptive dictionary learning at generation time (paper §4.2.4).
//!
//! Starts from the pretrained universal dictionary and grows it with
//! session-specific atoms whenever OMP cannot reach the δ error target;
//! also demonstrates the *native* dictionary trainer on freshly collected
//! KV vectors (the `lexico train-dict` path).
//!
//!   cargo run --release --example adaptive_dict

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::dict::DictionarySet;
use lexico::eval::{evaluate, EvalConfig};
use lexico::model::{Engine, Weights};
use lexico::tasks::Task;

fn main() -> anyhow::Result<()> {
    let art = lexico::artifacts_dir();
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    // the small (N=256) dictionary leaves headroom for adaptation to matter
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N256.bin"))?);
    let n = 40;

    println!("arith accuracy, base N=256 dictionary, s=4, FP16 coefficients\n");
    println!("{:<44} {:>9} {:>8}", "config", "KV size", "score");
    for spec in [
        "lexico:s=4,nb=32,fp16".to_string(),
        "lexico:s=4,nb=32,fp16,adaptive=256:0.35".to_string(),
        "lexico:s=4,nb=32,fp16,adaptive=256:0.30".to_string(),
        "lexico:s=4,nb=32,fp16,adaptive=256:0.25".to_string(),
    ] {
        let r = evaluate(&engine, Some(dicts.clone()), &spec,
                         &EvalConfig::new(Task::Arith, n, 606))?;
        println!("{:<44} {:>8.1}% {:>8.2}", r.method, 100.0 * r.kv_ratio, r.score);
    }
    println!("\ntighter δ ⇒ more added atoms ⇒ better fidelity, bigger KV —");
    println!("the paper's Table 6 trade-off.\n");

    // Show the raw mechanism on one session: count atoms added.
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let mut rng = lexico::util::rng::Rng::new(7);
    let inst = lexico::tasks::gen_needle(&mut rng, 24);
    let mut prompt = vec![lexico::tasks::BOS];
    prompt.extend(lexico::tasks::encode(&inst.prompt));
    let mut cache = build_cache("lexico:s=4,nb=16,fp16,adaptive=256:0.30", &ctx)?;
    let _ = engine.generate(&prompt, 6, None, &mut *cache);
    println!(
        "one session over a {}-token prompt grew the cache to {:.1}% \
         (includes the session-private atoms).",
        prompt.len(),
        100.0 * cache.kv_ratio()
    );
    Ok(())
}
