//! Long-context workload: retrieval accuracy vs context length for Lexico
//! and the quantization/eviction baselines — the setting where the paper's
//! O(Nm + Ts) attention and per-token byte savings matter most.
//!
//!   cargo run --release --example longcontext

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::dict::DictionarySet;
use lexico::model::{Engine, Weights};
use lexico::tasks;
use lexico::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let art = lexico::artifacts_dir();
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let n_samples = 30;

    println!("needle-retrieval accuracy vs context length (n={n_samples} each)\n");
    println!("{:<24} {:>8} {:>8} {:>8} {:>10}", "method", "16 pairs", "28 pairs", "40 pairs", "KV @40");
    for spec in [
        "full",
        "lexico:s=8,nb=32",
        "lexico:s=4,nb=32",
        "lexico:s=2,nb=32",
        "kivi:bits=2,g=16,nb=16",
        "snapkv:cap=64,win=8",
    ] {
        let mut accs = Vec::new();
        let mut kv_last = 0.0;
        for pairs in [16usize, 28, 40] {
            let mut rng = Rng::new(31337 + pairs as u64);
            let mut correct = 0;
            let mut kv_sum = 0.0;
            for _ in 0..n_samples {
                let inst = tasks::gen_needle(&mut rng, pairs);
                let mut prompt = vec![tasks::BOS];
                prompt.extend(tasks::encode(&inst.prompt));
                let mut cache = build_cache(spec, &ctx)?;
                let out = engine.generate(&prompt, 6, Some(tasks::newline_id()), &mut *cache);
                correct +=
                    (tasks::decode(&out).trim_end_matches('\n') == inst.answer) as usize;
                kv_sum += cache.kv_ratio();
            }
            accs.push(100.0 * correct as f64 / n_samples as f64);
            kv_last = kv_sum / n_samples as f64;
        }
        println!(
            "{spec:<24} {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}%",
            accs[0], accs[1], accs[2], 100.0 * kv_last
        );
    }
    println!("\nEviction loses the needle once it falls outside the kept set;");
    println!("Lexico keeps *every* token at ~3s+2 bytes and degrades smoothly.");
    Ok(())
}
