//! Serving demo: starts the coordinator in-process, fires a batch of
//! concurrent requests through the TCP front end, and prints latency /
//! throughput / KV-size metrics — the memory-bound-serving story of the
//! paper (§1): smaller KV per session ⇒ more sessions per budget.
//!
//!   cargo run --release --example serve_demo [-- --threads N]
//!
//! `--threads N` sizes the worker pool the coordinator runs on (default:
//! LEXICO_THREADS, then available parallelism); token streams are bitwise
//! identical at every thread count.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use lexico::model::{Engine, Weights};
use lexico::server::batcher::{self, BatcherConfig};
use lexico::server::http;
use lexico::server::metrics::Metrics;
use lexico::tasks;
use lexico::util::json::Json;

fn main() -> anyhow::Result<()> {
    // --threads N (or --threads=N): size the exec pool before the engine
    let argv: Vec<String> = std::env::args().collect();
    if let Some(t) = lexico::exec::threads_from_args(&argv).map_err(anyhow::Error::msg)? {
        if !lexico::exec::configure_default(t) {
            eprintln!("warning: exec pool already initialized; --threads {t} ignored");
        }
    }
    let art = lexico::artifacts_dir();
    let engine = Arc::new(Engine::new(Weights::load(art.join("model_M.bin"))?));
    println!("exec pool: {} threads", engine.pool().threads());
    let dicts = Arc::new(lexico::dict::DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    let metrics = Arc::new(Mutex::new(Metrics::new()));

    // coordinator: Lexico default method, a deliberately small KV budget,
    // and a small prefill chunk so long admissions visibly interleave
    let cfg = BatcherConfig {
        default_method: "lexico:s=6,nb=32".into(),
        kv_budget_bytes: 2.0 * 1024.0 * 1024.0,
        max_sessions: 16,
        prefill_chunk: 64,
        ..Default::default()
    };
    let (jtx, jrx) = channel();
    let (eng2, m2) = (engine.clone(), metrics.clone());
    std::thread::spawn(move || batcher::run(eng2, Some(dicts), cfg, jrx, m2));

    // TCP front end on an ephemeral port
    let (atx, arx) = channel();
    let m3 = metrics.clone();
    std::thread::spawn(move || {
        http::serve("127.0.0.1:0", jtx, m3, move |a| {
            let _ = atx.send(a);
        })
    });
    let addr = arx.recv()?;
    println!("serving on {addr}\n");

    // 12 concurrent clients, mixed workloads, some explicitly full-cache
    let mut handles = Vec::new();
    for i in 0..12u64 {
        handles.push(std::thread::spawn(move || -> anyhow::Result<(u64, Json)> {
            let mut rng = lexico::util::rng::Rng::new(90 + i);
            let inst = if i % 2 == 0 {
                tasks::gen_needle(&mut rng, 20)
            } else {
                tasks::gen_arith_prompt(&mut rng, 3, 3)
            };
            let method = if i % 3 == 0 { "full" } else { "" };
            let fanout = if i % 4 == 1 { 2 } else { 1 };
            let mut conn = TcpStream::connect(addr)?;
            writeln!(
                conn,
                r#"{{"prompt": "{}", "max_new": 6, "method": "{method}", "best_of": {fanout}}}"#,
                inst.prompt.replace('\n', "\\n")
            )?;
            let mut line = String::new();
            BufReader::new(conn).read_line(&mut line)?;
            Ok((i, Json::parse(&line).map_err(|e| anyhow::anyhow!(e))?))
        }));
    }
    for h in handles {
        let (i, v) = h.join().unwrap()?;
        let n_alts = v.get("alts").as_arr().map_or(0, |a| a.len());
        println!(
            "req {i:>2}: {:>6.1} ms total, {:>6.1} ms TTFT, KV {:>5.1}%, alts {n_alts}, reply {:?}",
            v.get("total_ms").as_f64().unwrap_or(0.0),
            v.get("ttft_ms").as_f64().unwrap_or(0.0),
            100.0 * v.get("kv_ratio").as_f64().unwrap_or(0.0),
            v.get("text").as_str().unwrap_or("").trim_end()
        );
    }

    // token streaming: one {"id","token","i"} line per generated token,
    // terminated by the usual final-response line
    println!("\n=== streaming ===");
    {
        let mut conn = TcpStream::connect(addr)?;
        writeln!(conn, r#"{{"prompt": "1+2=", "max_new": 8, "stream": true}}"#)?;
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let mut tokens = 0usize;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let v = Json::parse(&line).map_err(|e| anyhow::anyhow!(e))?;
            if let Some(tok) = v.get("token").as_str() {
                tokens += 1;
                println!("  delta {:>2}: {:?}", v.get("i").as_usize().unwrap_or(0), tok);
            } else {
                println!(
                    "  final  : {} tokens streamed, text {:?}",
                    tokens,
                    v.get("text").as_str().unwrap_or("").trim_end()
                );
                break;
            }
        }
    }

    println!("\n=== aggregate metrics ===");
    println!("{}", metrics.lock().unwrap().report());

    // shut the listener down cleanly
    let mut conn = TcpStream::connect(addr)?;
    writeln!(conn, r#"{{"cmd": "shutdown"}}"#)?;
    Ok(())
}
