//! Quickstart: the Lexico pipeline in ~60 lines.
//!
//! Loads the trained M model + its universal dictionaries, compresses a
//! prompt's KV cache with OMP, and compares generation quality and memory
//! against the full cache.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::full::FullCache;
use lexico::dict::DictionarySet;
use lexico::model::{Engine, Weights};
use lexico::tasks;

fn main() -> anyhow::Result<()> {
    let art = lexico::artifacts_dir();
    let engine = Engine::new(Weights::load(art.join("model_M.bin"))?);
    let dicts = Arc::new(DictionarySet::load(art.join("dict_M_N1024.bin"))?);
    println!("model M loaded; head_dim={}, dictionaries N={}",
             engine.shape().head_dim, dicts.keys[0].n);

    // A long-context retrieval prompt the model was trained to solve.
    let mut rng = lexico::util::rng::Rng::new(2024);
    let inst = tasks::gen_needle(&mut rng, 24);
    let mut prompt = vec![tasks::BOS];
    prompt.extend(tasks::encode(&inst.prompt));
    println!("\nprompt ({} tokens): …{}", prompt.len(),
             &inst.prompt[inst.prompt.len().saturating_sub(40)..]);
    println!("expected answer: {}", inst.answer);

    // Full-precision baseline.
    let mut full = FullCache::new(engine.shape());
    let out = engine.generate(&prompt, 6, Some(tasks::newline_id()), &mut full);
    println!("\nfull cache   → {:?}  (KV size 100%)", tasks::decode(&out).trim_end());

    // Lexico at several sparsity levels: each vector of the compressed
    // prefix is s (index, FP8-coefficient) pairs = 3s+2 bytes vs 64 FP16.
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    for s in [8usize, 4, 2] {
        let spec = format!("lexico:s={s},nb=32");
        let mut cache = build_cache(&spec, &ctx)?;
        let out = engine.generate(&prompt, 6, Some(tasks::newline_id()), &mut *cache);
        println!(
            "{spec:<18} → {:?}  (KV size {:.1}%)",
            tasks::decode(&out).trim_end(),
            100.0 * cache.kv_ratio()
        );
    }
    println!(
        "\nLexico reproduces the full-cache decoding at a fraction of the \
         memory — the paper's claim. (Whether that decoding is the *right* \
         answer depends on the model's training budget; see EXPERIMENTS.md \
         §Setup.)"
    );
    Ok(())
}
