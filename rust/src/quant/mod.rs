//! Integer group-quantization primitives used by the baseline methods
//! (KIVI, per-token quantization, ZipCache) and by Fig. 5's int4 weights.
//!
//! Asymmetric uniform quantization: for a group of values, store
//! `q = round((x - zero) / scale)` in `bits` bits with FP16 scale/zero per
//! group. `quantize`/`dequantize` round-trip through the *exact* storage
//! (u8 codes + f16 metadata) so results match a bit-packed implementation.

use crate::sparse::fp8::{f16_to_f32, f32_to_f16};

/// A quantized group: codes plus f16-rounded scale and zero-point.
#[derive(Clone, Debug)]
pub struct QuantGroup {
    pub codes: Vec<u8>,
    pub scale: f32,
    pub zero: f32,
    pub bits: u8,
}

impl QuantGroup {
    /// Exact storage bytes: packed codes + 2×2 bytes metadata.
    pub fn bytes(&self) -> f64 {
        self.codes.len() as f64 * self.bits as f64 / 8.0 + 4.0
    }
}

/// Quantize one group of values to `bits` bits (1..=8).
pub fn quantize_group(xs: &[f32], bits: u8) -> QuantGroup {
    debug_assert!((1..=8).contains(&bits));
    let levels = ((1u32 << bits) - 1) as f32;
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() {
        lo = 0.0;
        hi = 0.0;
    }
    let scale = f16_to_f32(f32_to_f16(((hi - lo) / levels).max(1e-8)));
    let zero = f16_to_f32(f32_to_f16(lo));
    let codes = xs
        .iter()
        .map(|&x| (((x - zero) / scale).round().clamp(0.0, levels)) as u8)
        .collect();
    QuantGroup { codes, scale, zero, bits }
}

/// Dequantize into `out` (len == codes.len()).
pub fn dequantize_group(g: &QuantGroup, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(&g.codes) {
        *o = g.zero + g.scale * c as f32;
    }
}

/// Fake-quantize a row-major matrix in place, grouping along each row in
/// chunks of `g` (per-output-channel grouping for weights). Used for the
/// Fig. 5 "4-bit weights" model variant.
pub fn fake_quant_rows(w: &mut [f32], g: usize, bits: u8) {
    for chunk in w.chunks_mut(g) {
        let q = quantize_group(chunk, bits);
        dequantize_group(&q, chunk);
    }
}

/// Quantize a vector split into groups of `g`; returns groups in order.
pub fn quantize_vector(xs: &[f32], g: usize, bits: u8) -> Vec<QuantGroup> {
    xs.chunks(g).map(|c| quantize_group(c, bits)).collect()
}

/// Dequantize a vector of groups back into a flat buffer.
pub fn dequantize_vector(groups: &[QuantGroup], out: &mut [f32]) {
    let mut off = 0;
    for g in groups {
        let n = g.codes.len();
        dequantize_group(g, &mut out[off..off + n]);
        off += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn quant_error_bound() {
        // Max abs error ≤ scale/2 + f16 metadata rounding slack.
        Prop::new(64).check("quant_err", |rng, size| {
            let n = 4 + rng.below(size * 4 + 4);
            let xs = rng.normal_vec(n);
            for bits in [2u8, 4, 8] {
                let q = quantize_group(&xs, bits);
                let mut out = vec![0.0; n];
                dequantize_group(&q, &mut out);
                let bound = q.scale * 0.501 + q.scale * 0.01 + 1e-4;
                for (x, o) in xs.iter().zip(&out) {
                    if (x - o).abs() > bound {
                        return Err(format!("bits {bits}: {x} → {o}, scale {}", q.scale));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn constant_group() {
        let xs = vec![3.0; 8];
        let q = quantize_group(&xs, 2);
        let mut out = vec![0.0; 8];
        dequantize_group(&q, &mut out);
        for o in out {
            assert!((o - 3.0).abs() < 2e-3);
        }
    }

    #[test]
    fn bytes_accounting() {
        let q = quantize_group(&[0.0; 32], 2);
        assert_eq!(q.bytes(), 32.0 * 2.0 / 8.0 + 4.0); // 12 B
        let q = quantize_group(&[0.0; 32], 4);
        assert_eq!(q.bytes(), 20.0);
    }

    #[test]
    fn fake_quant_reduces_precision_but_close() {
        let mut r = crate::util::rng::Rng::new(3);
        let mut w = r.normal_vec(64);
        let orig = w.clone();
        fake_quant_rows(&mut w, 16, 4);
        let mse: f32 =
            w.iter().zip(&orig).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / 64.0;
        assert!(mse > 0.0);
        assert!(mse < 0.05, "mse {mse}");
    }
}
