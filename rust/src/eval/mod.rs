//! Evaluation harness: run (model × cache-method × task) and report
//! score + KV size, the two axes of every figure/table in the paper.

pub mod keygeom;

use anyhow::Result;
use std::sync::Arc;

use crate::cache::factory::{build_cache, CacheContext};
use crate::dict::DictionarySet;
use crate::model::Engine;
use crate::tasks::{self, Metric, Task};
use crate::util::rng::Rng;

/// One evaluation configuration.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub task: Task,
    pub n_samples: usize,
    pub seed: u64,
    /// context-length stretch ∈ [0,1] for the long-context tasks
    pub scale: f64,
}

impl EvalConfig {
    pub fn new(task: Task, n_samples: usize, seed: u64) -> Self {
        EvalConfig { task, n_samples, seed, scale: 1.0 }
    }
}

/// Aggregated result of one (method, task) evaluation.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub task: &'static str,
    pub method: String,
    /// task score in [0,100] (accuracy / edit-sim %) or perplexity
    pub score: f64,
    /// mean "KV size" ratio at end of generation (paper metric)
    pub kv_ratio: f64,
    /// fidelity to the uncompressed model: mean edit similarity (%) between
    /// this method's greedy generation and the full cache's on the same
    /// prompt. 100 = byte-identical decoding. NaN for perplexity tasks.
    /// This is the discriminative quality axis when absolute task
    /// competence is limited by the training budget (EXPERIMENTS.md §Setup).
    pub agree: f64,
    pub n: usize,
}

/// Maximum tokens to generate per task.
fn max_new_for(task: Task, answer_len: usize) -> usize {
    match task {
        Task::Copy => answer_len + 2,
        Task::Lm => 0,
        _ => answer_len + 3,
    }
}

/// Evaluate one cache-method spec on one task.
pub fn evaluate(
    engine: &Engine,
    dicts: Option<Arc<DictionarySet>>,
    spec: &str,
    cfg: &EvalConfig,
) -> Result<EvalResult> {
    let ctx = CacheContext::new(engine.shape(), dicts);
    let mut rng = Rng::new(cfg.seed);
    let nl = tasks::newline_id();
    let mut total = 0.0f64;
    let mut kv_sum = 0.0f64;
    let mut agree_sum = 0.0f64;
    let max_seq = engine.weights.cfg.max_seq;
    let mut n_done = 0usize;
    let is_full = spec == "full";

    for _ in 0..cfg.n_samples {
        let inst = cfg.task.gen(&mut rng, cfg.scale);
        let mut cache = build_cache(spec, &ctx)?;
        if cfg.task.metric() == Metric::Perplexity {
            let mut ids = vec![tasks::BOS];
            ids.extend(tasks::encode(&inst.prompt));
            ids.truncate(max_seq - 1);
            let nll = engine.nll(&ids, &mut *cache);
            total += nll;
        } else {
            let mut ids = vec![tasks::BOS];
            ids.extend(tasks::encode(&inst.prompt));
            if ids.len() + 8 > max_seq {
                continue; // instance too long for the model
            }
            let max_new = max_new_for(cfg.task, inst.answer.len());
            let out = engine.generate(&ids, max_new, Some(nl), &mut *cache);
            let text = tasks::decode(&out);
            total += tasks::score(cfg.task.metric(), &text, &inst.answer);
            // fidelity: how close is the decoding to the full cache's?
            if is_full {
                agree_sum += 1.0;
            } else {
                let mut fc = build_cache("full", &ctx)?;
                let out_full = engine.generate(&ids, max_new, Some(nl), &mut *fc);
                agree_sum +=
                    tasks::edit_similarity(&text, &tasks::decode(&out_full));
            }
        }
        kv_sum += cache.kv_ratio();
        n_done += 1;
    }
    let n = n_done.max(1);
    let (score, agree) = match cfg.task.metric() {
        Metric::Perplexity => ((total / n as f64).exp(), f64::NAN),
        _ => (100.0 * total / n as f64, 100.0 * agree_sum / n as f64),
    };
    Ok(EvalResult {
        task: cfg.task.name(),
        method: spec.to_string(),
        score,
        kv_ratio: kv_sum / n as f64,
        agree,
        n,
    })
}

/// Evaluate a method on several tasks, returning per-task results.
pub fn evaluate_suite(
    engine: &Engine,
    dicts: Option<Arc<DictionarySet>>,
    spec: &str,
    suite: &[Task],
    n_samples: usize,
    seed: u64,
) -> Result<Vec<EvalResult>> {
    suite
        .iter()
        .map(|&task| {
            evaluate(engine, dicts.clone(), spec, &EvalConfig::new(task, n_samples, seed))
        })
        .collect()
}

/// Pretty row formatting for the repro drivers.
pub fn format_row(r: &EvalResult) -> String {
    let agree = if r.agree.is_nan() {
        "    –".to_string()
    } else {
        format!("{:>5.1}", r.agree)
    };
    format!(
        "{:<34} {:>10} {:>8.1}% {:>9.2} {agree}",
        r.method,
        r.task,
        100.0 * r.kv_ratio,
        r.score
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_weights;

    #[test]
    fn full_cache_eval_runs() {
        let engine = Engine::new(tiny_weights(7));
        // tiny random model: score will be ~0, but the harness must run and
        // report ratio 1.0 for the full cache.
        let r = evaluate(
            &engine,
            None,
            "full",
            &EvalConfig::new(Task::Sort, 3, 42),
        )
        .unwrap();
        assert_eq!(r.n, 3);
        assert!((r.kv_ratio - 1.0).abs() < 1e-9);
        assert!(r.score >= 0.0 && r.score <= 100.0);
    }

    #[test]
    fn quantized_eval_reports_smaller_cache() {
        let engine = Engine::new(tiny_weights(8));
        let r = evaluate(
            &engine,
            None,
            "pertoken:bits=4,g=8",
            &EvalConfig::new(Task::Sort, 2, 1),
        )
        .unwrap();
        assert!(r.kv_ratio < 0.6, "ratio {}", r.kv_ratio);
    }

    #[test]
    fn perplexity_task_runs() {
        let engine = Engine::new(tiny_weights(9));
        let r = evaluate(&engine, None, "full", &EvalConfig::new(Task::Lm, 1, 5)).unwrap();
        assert!(r.score > 1.0, "ppl {}", r.score); // ppl of random model ≈ vocab
    }
}
