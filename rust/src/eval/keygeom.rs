//! Key-geometry analysis (paper Fig. 3): do key vectors from *different*
//! inputs cluster in shared low-dimensional subspaces?
//!
//! We run the model over two unrelated prompts, collect the post-RoPE keys
//! of one layer, and compute the pairwise cosine-similarity matrix sorted
//! by greedy cluster order. The driver reports summary statistics (mean
//! within-cluster vs. global similarity, and cross-input cluster overlap)
//! — the quantitative content behind the paper's heat-map figure.

use anyhow::Result;

use crate::cache::full::FullCache;
use crate::model::Engine;
use crate::tensor::{dot, norm2};

/// Collect the keys of `layer` for a prompt (all kv heads concatenated).
/// Returns row-major [n_vecs][head_dim].
pub fn collect_keys(engine: &Engine, prompt: &[u32], layer: usize) -> Vec<Vec<f32>> {
    let mut cache = FullCache::new(engine.shape());
    let _ = engine.prefill(prompt, &mut cache);
    let kvd = engine.shape().kv_dim();
    let m = engine.shape().head_dim;
    let ks = cache.keys(layer);
    let t = ks.len() / kvd;
    let mut out = Vec::with_capacity(t * engine.shape().n_kv_heads);
    for g in 0..engine.shape().n_kv_heads {
        for ti in 0..t {
            out.push(ks[ti * kvd + g * m..ti * kvd + (g + 1) * m].to_vec());
        }
    }
    out
}

/// Pairwise cosine similarity, rows sorted by greedy nearest-neighbour
/// cluster order (the ordering the paper's figure uses to expose blocks).
pub fn cosine_matrix_sorted(keys: &[Vec<f32>]) -> (Vec<f32>, Vec<usize>) {
    let n = keys.len();
    let norms: Vec<f32> = keys.iter().map(|k| norm2(k).max(1e-12)).collect();
    let cos = |a: usize, b: usize| dot(&keys[a], &keys[b]) / (norms[a] * norms[b]);
    // greedy ordering: start anywhere, repeatedly append the unvisited key
    // most similar to the last placed one
    let mut order = Vec::with_capacity(n);
    let mut used = vec![false; n];
    let mut cur = 0usize;
    used[0] = true;
    order.push(0);
    for _ in 1..n {
        let mut best = usize::MAX;
        let mut best_sim = f32::NEG_INFINITY;
        for j in 0..n {
            if !used[j] {
                let s = cos(cur, j);
                if s > best_sim {
                    best_sim = s;
                    best = j;
                }
            }
        }
        used[best] = true;
        order.push(best);
        cur = best;
    }
    let mut mat = vec![0.0f32; n * n];
    for (i, &a) in order.iter().enumerate() {
        for (j, &b) in order.iter().enumerate() {
            mat[i * n + j] = cos(a, b);
        }
    }
    (mat, order)
}

/// Summary statistics of a sorted similarity matrix: mean |cos| overall,
/// mean |cos| in the banded near-diagonal (window w), and the fraction of
/// keys whose nearest neighbour exceeds 0.9 cosine similarity.
pub struct GeomStats {
    pub n: usize,
    pub mean_abs_all: f64,
    pub mean_abs_band: f64,
    pub frac_nn_above_09: f64,
}

pub fn stats(mat: &[f32], n: usize, band: usize) -> GeomStats {
    let mut sum_all = 0.0f64;
    let mut cnt_all = 0usize;
    let mut sum_band = 0.0f64;
    let mut cnt_band = 0usize;
    let mut nn_hits = 0usize;
    for i in 0..n {
        let mut best = f32::NEG_INFINITY;
        for j in 0..n {
            if i == j {
                continue;
            }
            let v = mat[i * n + j];
            sum_all += v.abs() as f64;
            cnt_all += 1;
            if i.abs_diff(j) <= band {
                sum_band += v.abs() as f64;
                cnt_band += 1;
            }
            best = best.max(v);
        }
        nn_hits += (best > 0.9) as usize;
    }
    GeomStats {
        n,
        mean_abs_all: sum_all / cnt_all.max(1) as f64,
        mean_abs_band: sum_band / cnt_band.max(1) as f64,
        frac_nn_above_09: nn_hits as f64 / n.max(1) as f64,
    }
}

/// Cross-input analysis: fraction of keys in `b` whose best match in `a`
/// exceeds the given cosine threshold (Fig. 3 right panel's message:
/// clusters recur across unrelated inputs).
pub fn cross_match_fraction(a: &[Vec<f32>], b: &[Vec<f32>], thresh: f32) -> f64 {
    let na: Vec<f32> = a.iter().map(|k| norm2(k).max(1e-12)).collect();
    let nb: Vec<f32> = b.iter().map(|k| norm2(k).max(1e-12)).collect();
    let mut hits = 0usize;
    for (j, kb) in b.iter().enumerate() {
        let mut best = f32::NEG_INFINITY;
        for (i, ka) in a.iter().enumerate() {
            best = best.max(dot(ka, kb) / (na[i] * nb[j]));
        }
        hits += (best > thresh) as usize;
    }
    hits as f64 / b.len().max(1) as f64
}

/// End-to-end Fig. 3 computation for a given engine.
pub fn fig3(engine: &Engine, layer: usize, seed: u64) -> Result<(GeomStats, f64, f64)> {
    use crate::tasks;
    let mut rng = crate::util::rng::Rng::new(seed);
    let text_a = tasks::gen_lm_text(&mut rng, 220);
    let inst_b = tasks::gen_needle(&mut rng, 24);
    let mut pa = vec![tasks::BOS];
    pa.extend(tasks::encode(&text_a));
    let mut pb = vec![tasks::BOS];
    pb.extend(tasks::encode(&inst_b.prompt));
    let ka = collect_keys(engine, &pa, layer);
    let kb = collect_keys(engine, &pb, layer);
    let (mat, _) = cosine_matrix_sorted(&ka);
    let st = stats(&mat, ka.len(), 4);
    let cross = cross_match_fraction(&ka, &kb, 0.8);
    // control: random gaussian vectors at matched dimension
    let m = engine.shape().head_dim;
    let rand: Vec<Vec<f32>> = (0..kb.len()).map(|_| rng.normal_vec(m)).collect();
    let cross_rand = cross_match_fraction(&ka, &rand, 0.8);
    Ok((st, cross, cross_rand))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn clustered_data_shows_banding() {
        // three tight clusters → near-diagonal band similarity ≫ global
        let mut rng = Rng::new(1);
        let mut keys = Vec::new();
        for _ in 0..3 {
            let center = rng.normal_vec(16);
            for _ in 0..10 {
                let mut k = center.clone();
                for x in k.iter_mut() {
                    *x += 0.05 * rng.normal();
                }
                keys.push(k);
            }
        }
        let (mat, order) = cosine_matrix_sorted(&keys);
        assert_eq!(order.len(), 30);
        let st = stats(&mat, 30, 3);
        assert!(
            st.mean_abs_band > st.mean_abs_all + 0.2,
            "band {} vs all {}",
            st.mean_abs_band,
            st.mean_abs_all
        );
        assert!(st.frac_nn_above_09 > 0.9);
    }

    #[test]
    fn cross_match_detects_shared_structure() {
        let mut rng = Rng::new(2);
        let shared: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(16)).collect();
        let jitter = |c: &Vec<f32>, rng: &mut Rng| -> Vec<f32> {
            c.iter().map(|x| x + 0.02 * rng.normal()).collect()
        };
        let a: Vec<Vec<f32>> = (0..20).map(|i| jitter(&shared[i % 5], &mut rng)).collect();
        let b: Vec<Vec<f32>> = (0..20).map(|i| jitter(&shared[i % 5], &mut rng)).collect();
        let c: Vec<Vec<f32>> = (0..20).map(|_| rng.normal_vec(16)).collect();
        assert!(cross_match_fraction(&a, &b, 0.9) > 0.9);
        assert!(cross_match_fraction(&a, &c, 0.9) < 0.3);
    }
}
