//! Minimal f32 tensor kernels for the native inference engine.
//!
//! Everything is row-major `&[f32]` + explicit dims; the handful of shapes
//! the transformer needs (GEMM, GEMM with transposed RHS, row softmax,
//! RMSNorm, SiLU) is implemented directly with cache-friendly loop orders.
//! The perf pass (EXPERIMENTS.md §Perf) iterates on these kernels.
//!
//! Each GEMM has a `par_*` twin that shards the *output elements* across an
//! [`ExecPool`]: contiguous column blocks, each element still accumulated in
//! the exact floating-point order of the sequential kernel (the inner op is
//! element-independent — `c[i][j] += a[i][k]·b[k][j]` in ascending-k order
//! for the axpy kernels, one whole [`dot`] per element for `matmul_bt`), so
//! the parallel results are **bitwise identical** to the sequential ones at
//! every thread count. Column sharding (rather than rows) keeps every shard
//! busy even at `M = 1` (single-session decode) and streams each element of
//! `B` through memory exactly once across the whole pool.
//!
//! [`dot`] and [`axpy`] — the inner kernels of every GEMM here — follow the
//! canonical blocked reduction order defined in [`simd`] and dispatch once
//! per process to the best vectorized implementation the host offers
//! (AVX2/SSE2/NEON); all implementations are bitwise identical to the
//! blocked scalar, so vector dispatch never perturbs the determinism
//! contract. See DESIGN.md §8.

pub mod simd;

use crate::exec::{ExecPool, SendPtr};

/// Below roughly this many multiply-adds a parallel launch costs more than
/// it saves; the `par_*` kernels (and the engine's sharded unembedding)
/// fall back to their sequential twins.
pub const PAR_MIN_MACS: usize = 16 * 1024;

/// Shard the column range `0..n` into at most `threads` contiguous blocks
/// of at least `min_cols` columns. Returns the shard count; shard `si`
/// covers `si*n/shards .. (si+1)*n/shards`. Shared with the engine's
/// vocab-sharded unembedding so the sharding policy lives in one place.
#[inline]
pub(crate) fn col_shards(n: usize, threads: usize, min_cols: usize) -> usize {
    threads.min(n / min_cols.max(1)).max(1)
}

/// C[M,N] += A[M,K] @ B[K,N]. `C` must be zeroed by the caller if `+=` is
/// not wanted. i-k-j loop order: the inner loop streams B and C rows.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            axpy(crow, aik, brow);
        }
    }
}

/// C[M,N] = A[M,K] @ B[K,N].
pub fn matmul(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    matmul_acc(c, a, b, m, k, n);
}

/// C[M,N] = A[M,K] @ B[K,N], k-major loop order: each row of B is loaded
/// exactly once and applied to every row of A, so a weight matrix streams
/// through memory once per call *regardless of M* (the i-k-j order of
/// [`matmul`] re-streams B for every row of A). This is the batched-decode
/// kernel: M = number of concurrent sessions (small), so C stays
/// cache-resident while B streams.
///
/// Per output element the contributions arrive in ascending-k order through
/// the same [`axpy`] kernel as [`matmul`], so results are bitwise identical
/// to `matmul` — the batch-parity guarantee rests on this.
pub fn matmul_kmajor(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for kk in 0..k {
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            axpy(&mut c[i * n..(i + 1) * n], aik, brow);
        }
    }
}

/// C[M,N] = A[M,K] @ B^T where B is [N,K] (dot-product form; good when both
/// operands are row-major and N is small, e.g. attention scores).
pub fn matmul_bt(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// [`matmul`] on the pool: output columns are sharded, each element keeps
/// the sequential ascending-k accumulation — bitwise identical to `matmul`.
pub fn par_matmul(
    pool: &ExecPool,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let shards = col_shards(n, pool.threads(), 8);
    if shards == 1 || m * k * n < PAR_MIN_MACS {
        matmul(c, a, b, m, k, n);
        return;
    }
    c.fill(0.0);
    let cp = SendPtr::new(c.as_mut_ptr());
    pool.parallel_for(shards, move |si| {
        let (lo, hi) = (si * n / shards, (si + 1) * n / shards);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            // SAFETY: shard si exclusively owns columns lo..hi of every row.
            let crow = unsafe { std::slice::from_raw_parts_mut(cp.get().add(i * n + lo), hi - lo) };
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                axpy(crow, aik, &b[kk * n + lo..kk * n + hi]);
            }
        }
    });
}

/// [`matmul_kmajor`] on the pool: output columns are sharded, the k-major
/// loop order is preserved per shard, and each weight element is read by
/// exactly one shard — one streaming pass over `B` across the whole pool.
/// Bitwise identical to `matmul_kmajor` (and therefore to `matmul`).
pub fn par_matmul_kmajor(
    pool: &ExecPool,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let shards = col_shards(n, pool.threads(), 8);
    if shards == 1 || m * k * n < PAR_MIN_MACS {
        matmul_kmajor(c, a, b, m, k, n);
        return;
    }
    c.fill(0.0);
    let cp = SendPtr::new(c.as_mut_ptr());
    pool.parallel_for(shards, move |si| {
        let (lo, hi) = (si * n / shards, (si + 1) * n / shards);
        for kk in 0..k {
            let brow = &b[kk * n + lo..kk * n + hi];
            for i in 0..m {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                // SAFETY: shard si exclusively owns columns lo..hi of row i.
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(cp.get().add(i * n + lo), hi - lo) };
                axpy(crow, aik, brow);
            }
        }
    });
}

/// [`matmul_bt`] on the pool: output columns (rows of `B`) are sharded;
/// every element is one whole [`dot`], so results are bitwise identical to
/// `matmul_bt`. Each `B` row is streamed by exactly one shard — this is the
/// batched-OMP correlation kernel (`R[A,m] · Dᵀ`, atoms sharded).
pub fn par_matmul_bt(
    pool: &ExecPool,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let shards = col_shards(n, pool.threads(), 4);
    if shards == 1 || m * k * n < PAR_MIN_MACS {
        matmul_bt(c, a, b, m, k, n);
        return;
    }
    let cp = SendPtr::new(c.as_mut_ptr());
    pool.parallel_for(shards, move |si| {
        let (lo, hi) = (si * n / shards, (si + 1) * n / shards);
        for j in lo..hi {
            let brow = &b[j * k..(j + 1) * k];
            for i in 0..m {
                // SAFETY: shard si exclusively owns columns lo..hi.
                unsafe { *cp.get().add(i * n + j) = dot(&a[i * k..(i + 1) * k], brow) };
            }
        }
    });
}

/// Symmetric rank-k product G[N,N] = A·Aᵀ for row-major `A` [N,K] — the
/// Gram kernel behind the precomputed-Gram OMP tier (DESIGN.md §12). Only
/// the lower triangle is computed (one canonical [`dot`] per element,
/// j ≤ i); each strict-lower element is mirrored into the upper triangle,
/// so `g` holds the full symmetric matrix and consumers get unit-stride
/// row access to any Gram column.
pub fn syrk(g: &mut [f32], a: &[f32], n: usize, k: usize) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(g.len(), n * n);
    for i in 0..n {
        let ai = &a[i * k..(i + 1) * k];
        for j in 0..=i {
            let v = dot(ai, &a[j * k..(j + 1) * k]);
            g[i * n + j] = v;
            if j != i {
                g[j * n + i] = v;
            }
        }
    }
}

/// [`syrk`] on the pool: rows of the lower triangle are claimed round-robin
/// (row `i` to shard `i % shards`), balancing the triangle's linearly
/// growing per-row cost without splitting any element — each element is
/// still one whole canonical [`dot`], so the result is bitwise identical to
/// `syrk` at every thread count. Write disjointness: the shard owning row
/// `i` writes the lower-triangle row `(i, j ≤ i)` and its mirror, the
/// strict-upper column `(j < i, i)`. Lower writes from different rows live
/// in different rows; upper writes from different rows live in different
/// columns; and no lower write (j ≤ i) can collide with an upper write
/// (row < column), so every cell has exactly one writer.
pub fn par_syrk(pool: &ExecPool, g: &mut [f32], a: &[f32], n: usize, k: usize) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(g.len(), n * n);
    let shards = pool.threads().min(n).max(1);
    if shards == 1 || n * (n + 1) / 2 * k < PAR_MIN_MACS {
        syrk(g, a, n, k);
        return;
    }
    let gp = SendPtr::new(g.as_mut_ptr());
    pool.parallel_for(shards, move |si| {
        let mut i = si;
        while i < n {
            let ai = &a[i * k..(i + 1) * k];
            for j in 0..=i {
                let v = dot(ai, &a[j * k..(j + 1) * k]);
                // SAFETY: shard si exclusively owns row i of the lower
                // triangle and column i of the strict upper triangle (rows
                // are claimed round-robin; see the disjointness argument in
                // the doc comment).
                unsafe {
                    *gp.get().add(i * n + j) = v;
                    if j != i {
                        *gp.get().add(j * n + i) = v;
                    }
                }
            }
            i += shards;
        }
    });
}

/// y += alpha * x (the GEMM inner kernel), in the canonical element-wise
/// order of [`simd`] — dispatched once per process to the best vectorized
/// implementation the host supports; every implementation is bitwise
/// identical to [`simd::axpy_blocked`].
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    (simd::active().axpy)(y, alpha, x)
}

/// Dot product in the canonical 8-lane blocked order of [`simd`] (fixed
/// accumulator tree, sequential tail) — dispatched once per process;
/// every implementation is bitwise identical to [`simd::dot_blocked`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (simd::active().dot)(a, b)
}

/// In-place numerically-stable softmax over a row.
pub fn softmax(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// out = x * g / rms(x) (RMSNorm, eps matching the JAX model).
pub fn rmsnorm(out: &mut [f32], x: &[f32], g: &[f32], eps: f32) {
    let ms = dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * g[i];
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Argmax over a slice (first max wins, like jnp.argmax).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        Prop::new(32).check("matmul", |rng, size| {
            let (m, k, n) = (1 + rng.below(size + 3), 1 + rng.below(size + 7), 1 + rng.below(size + 3));
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let mut c = vec![0.0; m * n];
            matmul(&mut c, &a, &b, m, k, n);
            crate::util::prop::assert_close(&c, &naive_matmul(&a, &b, m, k, n), 1e-4, "matmul")
        });
    }

    #[test]
    fn matmul_kmajor_is_bitwise_identical_to_matmul() {
        // Not just close: the batched decode path relies on exact equality.
        Prop::new(32).check("matmul_kmajor", |rng, size| {
            let (m, k, n) = (1 + rng.below(size + 3), 1 + rng.below(size + 7), 1 + rng.below(size + 3));
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            matmul(&mut c1, &a, &b, m, k, n);
            matmul_kmajor(&mut c2, &a, &b, m, k, n);
            if c1 == c2 {
                Ok(())
            } else {
                Err(format!("kmajor diverged at m={m} k={k} n={n}"))
            }
        });
    }

    #[test]
    fn matmul_bt_matches() {
        Prop::new(32).check("matmul_bt", |rng, size| {
            let (m, k, n) = (1 + rng.below(size + 2), 1 + rng.below(size + 8), 1 + rng.below(size + 5));
            let a = rng.normal_vec(m * k);
            let bt = rng.normal_vec(n * k); // B^T stored [N,K]
            // build B [K,N]
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for kk in 0..k {
                    b[kk * n + j] = bt[j * k + kk];
                }
            }
            let mut c1 = vec![0.0; m * n];
            matmul_bt(&mut c1, &a, &bt, m, k, n);
            crate::util::prop::assert_close(&c1, &naive_matmul(&a, &b, m, k, n), 1e-4, "bt")
        });
    }

    #[test]
    fn par_kernels_are_bitwise_identical_on_ragged_shapes() {
        // The exec-layer determinism contract, as a property: every par_*
        // kernel equals its sequential twin bit for bit, at several thread
        // counts, on ragged (non-round, non-aligned) shapes — including
        // shapes big enough to clear the PAR_MIN_MACS inline fallback.
        for &threads in &[1usize, 2, 3, 4] {
            let pool = ExecPool::new(threads);
            Prop::new(24).seed(0xBEEF + threads as u64).check("par_gemm", |rng, size| {
                let m = 1 + rng.below(size + 4);
                let k = 1 + rng.below(size + 9);
                let n = 1 + rng.below(8 * size + 37);
                let a = rng.normal_vec(m * k);
                let b = rng.normal_vec(k * n);
                let bt = rng.normal_vec(n * k);
                let mut c_seq = vec![0.0; m * n];
                let mut c_par = vec![0.0; m * n];

                matmul(&mut c_seq, &a, &b, m, k, n);
                par_matmul(&pool, &mut c_par, &a, &b, m, k, n);
                if c_seq != c_par {
                    return Err(format!("par_matmul diverged at T={threads} m={m} k={k} n={n}"));
                }

                matmul_kmajor(&mut c_seq, &a, &b, m, k, n);
                par_matmul_kmajor(&pool, &mut c_par, &a, &b, m, k, n);
                if c_seq != c_par {
                    return Err(format!(
                        "par_matmul_kmajor diverged at T={threads} m={m} k={k} n={n}"
                    ));
                }

                matmul_bt(&mut c_seq, &a, &bt, m, k, n);
                par_matmul_bt(&pool, &mut c_par, &a, &bt, m, k, n);
                if c_seq != c_par {
                    return Err(format!(
                        "par_matmul_bt diverged at T={threads} m={m} k={k} n={n}"
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn par_kernels_handle_degenerate_and_large_shapes() {
        let pool = ExecPool::new(4);
        // m = 1 (single-session decode): column sharding must still engage
        // and still match exactly.
        let mut rng = Rng::new(9);
        let (m, k, n) = (1usize, 96usize, 512usize);
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        matmul_kmajor(&mut c1, &a, &b, m, k, n);
        par_matmul_kmajor(&pool, &mut c2, &a, &b, m, k, n);
        assert_eq!(c1, c2, "m=1 column sharding diverged");
        // n = 1: collapses to a single shard (inline sequential path).
        let b1 = rng.normal_vec(k);
        let mut d1 = vec![0.0; 1];
        let mut d2 = vec![0.0; 1];
        matmul(&mut d1, &a, &b1, 1, k, 1);
        par_matmul(&pool, &mut d2, &a, &b1, 1, k, 1);
        assert_eq!(d1, d2);
    }

    #[test]
    fn syrk_matches_naive_and_is_symmetric() {
        Prop::new(32).check("syrk", |rng, size| {
            let n = 1 + rng.below(size + 9);
            let k = 1 + rng.below(size + 7);
            let a = rng.normal_vec(n * k);
            let mut g = vec![0.0; n * n];
            syrk(&mut g, &a, n, k);
            // reference: A·Aᵀ via the naive matmul with B = Aᵀ
            let mut at = vec![0.0; k * n];
            for i in 0..n {
                for kk in 0..k {
                    at[kk * n + i] = a[i * k + kk];
                }
            }
            let naive = naive_matmul(&a, &at, n, k, n);
            crate::util::prop::assert_close(&g, &naive, 1e-3, "syrk")?;
            for i in 0..n {
                for j in 0..i {
                    if g[i * n + j] != g[j * n + i] {
                        return Err(format!("not symmetric at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn par_syrk_is_bitwise_identical_at_every_thread_count() {
        // The gram-tier determinism contract starts here: the Gram matrix
        // itself must be bitwise independent of the pool width, including
        // shapes large enough to clear the PAR_MIN_MACS inline fallback.
        for &threads in &[1usize, 2, 3, 4] {
            let pool = ExecPool::new(threads);
            Prop::new(16).seed(0xC0DE + threads as u64).check("par_syrk", |rng, size| {
                let n = 1 + rng.below(16 * size + 61);
                let k = 1 + rng.below(size + 17);
                let a = rng.normal_vec(n * k);
                let mut g_seq = vec![0.0; n * n];
                let mut g_par = vec![0.0; n * n];
                syrk(&mut g_seq, &a, n, k);
                par_syrk(&pool, &mut g_par, &a, n, k);
                if g_seq != g_par {
                    return Err(format!("par_syrk diverged at T={threads} n={n} k={k}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn syrk_entries_match_canonical_dot() {
        // Gram entries must be the very dots the canonical OMP Cholesky
        // computes on the fly — this is what makes the gram tier's factor
        // bitwise equal to the canonical tier's on identical selections.
        let mut rng = Rng::new(23);
        let (n, k) = (37usize, 19usize);
        let a = rng.normal_vec(n * k);
        let mut g = vec![0.0; n * n];
        syrk(&mut g, &a, n, k);
        for i in 0..n {
            for j in 0..n {
                let d = dot(&a[i * k..(i + 1) * k], &a[j * k..(j + 1) * k]);
                assert_eq!(g[i * n + j], d, "G[{i},{j}] != dot");
            }
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut r = Rng::new(5);
        for _ in 0..20 {
            let mut row = r.normal_vec(17);
            softmax(&mut row);
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut row = vec![-1e30, 0.0, -1e30];
        softmax(&mut row);
        assert!((row[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0, 4.0];
        let g = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&mut out, &x, &g, 0.0);
        // rms = sqrt(12.5); out = x / rms
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-5);
        assert!((out[1] - 4.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn argmax_first_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }
}
