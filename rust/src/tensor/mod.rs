//! Minimal f32 tensor kernels for the native inference engine.
//!
//! Everything is row-major `&[f32]` + explicit dims; the handful of shapes
//! the transformer needs (GEMM, GEMM with transposed RHS, row softmax,
//! RMSNorm, SiLU) is implemented directly with cache-friendly loop orders.
//! The perf pass (EXPERIMENTS.md §Perf) iterates on these kernels.

/// C[M,N] += A[M,K] @ B[K,N]. `C` must be zeroed by the caller if `+=` is
/// not wanted. i-k-j loop order: the inner loop streams B and C rows.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            axpy(crow, aik, brow);
        }
    }
}

/// C[M,N] = A[M,K] @ B[K,N].
pub fn matmul(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    matmul_acc(c, a, b, m, k, n);
}

/// C[M,N] = A[M,K] @ B[K,N], k-major loop order: each row of B is loaded
/// exactly once and applied to every row of A, so a weight matrix streams
/// through memory once per call *regardless of M* (the i-k-j order of
/// [`matmul`] re-streams B for every row of A). This is the batched-decode
/// kernel: M = number of concurrent sessions (small), so C stays
/// cache-resident while B streams.
///
/// Per output element the contributions arrive in ascending-k order through
/// the same [`axpy`] kernel as [`matmul`], so results are bitwise identical
/// to `matmul` — the batch-parity guarantee rests on this.
pub fn matmul_kmajor(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for kk in 0..k {
        let brow = &b[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            axpy(&mut c[i * n..(i + 1) * n], aik, brow);
        }
    }
}

/// C[M,N] = A[M,K] @ B^T where B is [N,K] (dot-product form; good when both
/// operands are row-major and N is small, e.g. attention scores).
pub fn matmul_bt(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// y += alpha * x (the GEMM inner kernel; unrolled by 8 for the autovectorizer).
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    // Unrolled main body — LLVM turns this into packed FMA.
    for c in 0..chunks {
        let i = c * 8;
        let yc = &mut y[i..i + 8];
        let xc = &x[i..i + 8];
        for l in 0..8 {
            yc[l] += alpha * xc[l];
        }
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Dot product, 8-way unrolled with independent accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// In-place numerically-stable softmax over a row.
pub fn softmax(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// out = x * g / rms(x) (RMSNorm, eps matching the JAX model).
pub fn rmsnorm(out: &mut [f32], x: &[f32], g: &[f32], eps: f32) {
    let ms = dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * g[i];
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Argmax over a slice (first max wins, like jnp.argmax).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        Prop::new(32).check("matmul", |rng, size| {
            let (m, k, n) = (1 + rng.below(size + 3), 1 + rng.below(size + 7), 1 + rng.below(size + 3));
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let mut c = vec![0.0; m * n];
            matmul(&mut c, &a, &b, m, k, n);
            crate::util::prop::assert_close(&c, &naive_matmul(&a, &b, m, k, n), 1e-4, "matmul")
        });
    }

    #[test]
    fn matmul_kmajor_is_bitwise_identical_to_matmul() {
        // Not just close: the batched decode path relies on exact equality.
        Prop::new(32).check("matmul_kmajor", |rng, size| {
            let (m, k, n) = (1 + rng.below(size + 3), 1 + rng.below(size + 7), 1 + rng.below(size + 3));
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            matmul(&mut c1, &a, &b, m, k, n);
            matmul_kmajor(&mut c2, &a, &b, m, k, n);
            if c1 == c2 {
                Ok(())
            } else {
                Err(format!("kmajor diverged at m={m} k={k} n={n}"))
            }
        });
    }

    #[test]
    fn matmul_bt_matches() {
        Prop::new(32).check("matmul_bt", |rng, size| {
            let (m, k, n) = (1 + rng.below(size + 2), 1 + rng.below(size + 8), 1 + rng.below(size + 5));
            let a = rng.normal_vec(m * k);
            let bt = rng.normal_vec(n * k); // B^T stored [N,K]
            // build B [K,N]
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for kk in 0..k {
                    b[kk * n + j] = bt[j * k + kk];
                }
            }
            let mut c1 = vec![0.0; m * n];
            matmul_bt(&mut c1, &a, &bt, m, k, n);
            crate::util::prop::assert_close(&c1, &naive_matmul(&a, &b, m, k, n), 1e-4, "bt")
        });
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut r = Rng::new(5);
        for _ in 0..20 {
            let mut row = r.normal_vec(17);
            softmax(&mut row);
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut row = vec![-1e30, 0.0, -1e30];
        softmax(&mut row);
        assert!((row[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0, 4.0];
        let g = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&mut out, &x, &g, 0.0);
        // rms = sqrt(12.5); out = x / rms
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-5);
        assert!((out[1] - 4.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn argmax_first_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }
}
