//! Runtime-dispatched SIMD kernels for [`dot`](crate::tensor::dot) and
//! [`axpy`](crate::tensor::axpy) under one **canonical reduction order**.
//!
//! The canonical order (DESIGN.md §8) is what every implementation —
//! blocked scalar, SSE2, AVX2, NEON — must reproduce bit for bit:
//!
//! * `dot`: the main body runs in chunks of 8 elements; lane `l`
//!   accumulates `a[8c+l] * b[8c+l]` with a separate multiply and add
//!   (never a fused multiply-add — FMA rounds once where mul+add rounds
//!   twice, so contraction would break cross-kernel bit-equality). The 8
//!   lane sums are then combined by a fixed binary tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — exactly the shape a vector
//!   register's horizontal reduction produces — and the `n % 8` tail
//!   elements are added sequentially to the tree sum.
//! * `axpy`: `y[i] += alpha * x[i]` element-wise (mul, then add). Each
//!   element is independent, so any vector width reproduces the scalar
//!   result exactly; only FMA contraction is forbidden.
//!
//! Because every kernel performs identical per-lane IEEE operations in
//! identical order, the dispatch choice (scalar vs SSE2 vs AVX2 vs NEON,
//! `target-cpu=native` or not) can never change a result — the exec-layer
//! determinism contract (DESIGN.md §7) extends across instruction sets.
//! The property tests below enforce bit-equality of every available
//! kernel against the blocked scalar on all lane remainders.
//!
//! Dispatch is resolved once per process: `LEXICO_SIMD`
//! (`scalar|sse2|avx2|neon`) forces a kernel when that kernel is
//! available on the host, otherwise the best detected instruction set
//! wins (AVX2 → SSE2 on x86_64, NEON on aarch64, blocked scalar
//! elsewhere).

use std::sync::OnceLock;

/// One dot/axpy implementation pair. All pairs compute bitwise-identical
/// results; they differ only in speed.
#[derive(Clone, Copy)]
pub struct Kernels {
    pub name: &'static str,
    pub dot: fn(&[f32], &[f32]) -> f32,
    pub axpy: fn(&mut [f32], f32, &[f32]),
}

/// The canonical 8-lane combine: a fixed binary tree, matching the
/// horizontal reduction of one 8-wide (or two 4-wide) vector registers.
#[inline(always)]
fn lane_tree8(l: &[f32; 8]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Blocked-scalar `dot` in the canonical order — the reference every
/// vectorized kernel is tested against (and the fallback dispatch).
pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = lane_tree8(&acc);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// Blocked-scalar `axpy` (8-way unrolled for the autovectorizer;
/// element-independent, so the unroll shape carries no numeric meaning).
pub fn axpy_blocked(y: &mut [f32], alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        let yc = &mut y[i..i + 8];
        let xc = &x[i..i + 8];
        for l in 0..8 {
            yc[l] += alpha * xc[l];
        }
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

const SCALAR: Kernels = Kernels { name: "scalar", dot: dot_blocked, axpy: axpy_blocked };

// ---------------------------------------------------------------------------
// x86_64: SSE2 (baseline, always present) and AVX2 (detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::lane_tree8;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure SSE2 is available (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        // two 4-lane accumulators = lanes 0..4 and 4..8 of the canonical
        // 8-lane block; mul then add, never FMA
        let mut acc_lo = _mm_setzero_ps();
        let mut acc_hi = _mm_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let a_lo = _mm_loadu_ps(a.as_ptr().add(i));
            let b_lo = _mm_loadu_ps(b.as_ptr().add(i));
            acc_lo = _mm_add_ps(acc_lo, _mm_mul_ps(a_lo, b_lo));
            let a_hi = _mm_loadu_ps(a.as_ptr().add(i + 4));
            let b_hi = _mm_loadu_ps(b.as_ptr().add(i + 4));
            acc_hi = _mm_add_ps(acc_hi, _mm_mul_ps(a_hi, b_hi));
        }
        let mut lanes = [0f32; 8];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc_lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), acc_hi);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure SSE2 is available (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sse2(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 4;
        let va = _mm_set1_ps(alpha);
        for c in 0..chunks {
            let i = c * 4;
            let vy = _mm_loadu_ps(y.as_ptr().add(i));
            let vx = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(y.as_mut_ptr().add(i), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
        }
        for i in chunks * 4..n {
            y[i] += alpha * x[i];
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (checked at dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            // vmulps + vaddps: per-lane identical to the scalar mul + add
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (checked at dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 8;
        let va = _mm256_set1_ps(alpha);
        for c in 0..chunks {
            let i = c * 8;
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i];
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: SSE2 is part of the x86_64 baseline.
    unsafe { x86::dot_sse2(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_sse2(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: SSE2 is part of the x86_64 baseline.
    unsafe { x86::axpy_sse2(y, alpha, x) }
}

#[cfg(target_arch = "x86_64")]
fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: only reachable through dispatch/tests after AVX2 detection.
    unsafe { x86::dot_avx2(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_avx2(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: only reachable through dispatch/tests after AVX2 detection.
    unsafe { x86::axpy_avx2(y, alpha, x) }
}

#[cfg(target_arch = "x86_64")]
const SSE2: Kernels = Kernels { name: "sse2", dot: dot_sse2, axpy: axpy_sse2 };

#[cfg(target_arch = "x86_64")]
const AVX2: Kernels = Kernels { name: "avx2", dot: dot_avx2, axpy: axpy_avx2 };

// ---------------------------------------------------------------------------
// aarch64: NEON (baseline, always present)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::lane_tree8;
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let i = c * 8;
            acc_lo = vaddq_f32(
                acc_lo,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i))),
            );
            acc_hi = vaddq_f32(
                acc_hi,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4))),
            );
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 4;
        let va = vdupq_n_f32(alpha);
        for c in 0..chunks {
            let i = c * 4;
            let vy = vld1q_f32(y.as_ptr().add(i));
            let vx = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vmulq_f32(va, vx)));
        }
        for i in chunks * 4..n {
            y[i] += alpha * x[i];
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: NEON is part of the aarch64 baseline.
    unsafe { arm::dot_neon(a, b) }
}

#[cfg(target_arch = "aarch64")]
fn axpy_neon(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: NEON is part of the aarch64 baseline.
    unsafe { arm::axpy_neon(y, alpha, x) }
}

#[cfg(target_arch = "aarch64")]
const NEON: Kernels = Kernels { name: "neon", dot: dot_neon, axpy: axpy_neon };

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Every kernel implementation usable on this host, best first. The blocked
/// scalar is always present and always last.
pub fn available() -> Vec<Kernels> {
    let mut v = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            v.push(AVX2);
        }
        v.push(SSE2);
    }
    #[cfg(target_arch = "aarch64")]
    v.push(NEON);
    v.push(SCALAR);
    v
}

fn select() -> Kernels {
    let avail = available();
    if let Ok(forced) = std::env::var("LEXICO_SIMD") {
        let want = forced.trim();
        if let Some(k) = avail.iter().find(|k| k.name == want) {
            return *k;
        }
        eprintln!(
            "warning: LEXICO_SIMD={want} not available on this host (have: {}); auto-selecting",
            avail.iter().map(|k| k.name).collect::<Vec<_>>().join(",")
        );
    }
    avail[0]
}

static ACTIVE: OnceLock<Kernels> = OnceLock::new();

/// The kernel pair the process dispatches to (resolved once, then free).
#[inline]
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(select)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Lengths covering every lane remainder (0..8 twice), the chunk
    /// boundaries, and sizes past several chunks.
    fn probe_lengths() -> Vec<usize> {
        let mut v: Vec<usize> = (0..=17).collect();
        v.extend([23, 31, 32, 33, 63, 64, 65, 96, 127, 128, 129, 255, 1000]);
        v
    }

    #[test]
    fn every_available_kernel_matches_blocked_scalar_bitwise() {
        let mut rng = Rng::new(0xD07);
        for kern in available() {
            for &n in &probe_lengths() {
                for rep in 0..4 {
                    let a = rng.normal_vec(n);
                    let b = rng.normal_vec(n);
                    let want = dot_blocked(&a, &b);
                    let got = (kern.dot)(&a, &b);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{} dot diverged at n={n} rep={rep}: {got} vs {want}",
                        kern.name
                    );
                    let alpha = if rep == 3 { 0.0 } else { rng.range_f32(-2.0, 2.0) };
                    let y0 = rng.normal_vec(n);
                    let mut y_want = y0.clone();
                    let mut y_got = y0;
                    axpy_blocked(&mut y_want, alpha, &b);
                    (kern.axpy)(&mut y_got, alpha, &b);
                    for i in 0..n {
                        assert_eq!(
                            y_got[i].to_bits(),
                            y_want[i].to_bits(),
                            "{} axpy diverged at n={n} i={i} alpha={alpha}",
                            kern.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn kernels_tolerate_mismatched_slice_lengths() {
        // dot/axpy contract: operate on the shorter length (callers rely on
        // this for strided views).
        let a = vec![1.0f32; 20];
        let b = vec![2.0f32; 13];
        for kern in available() {
            assert_eq!((kern.dot)(&a, &b), dot_blocked(&a, &b), "{}", kern.name);
            let mut y1 = vec![1.0f32; 11];
            let mut y2 = y1.clone();
            axpy_blocked(&mut y1, 0.5, &a);
            (kern.axpy)(&mut y2, 0.5, &a);
            assert_eq!(y1, y2, "{}", kern.name);
        }
    }

    #[test]
    fn lane_tree_matches_register_reduction_shape() {
        // sanity-pin the canonical combine: NOT a linear left fold
        let l = [1e8f32, 1.0, -1e8, 1.0, 3.0, 4.0, 5.0, 6.0];
        let tree = ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]));
        assert_eq!(lane_tree8(&l), tree);
        // and the linear fold genuinely differs on this input, so the test
        // would catch a silent reversion to the old order
        let linear: f32 = l.iter().sum();
        assert_ne!(tree.to_bits(), linear.to_bits());
    }

    #[test]
    fn active_is_one_of_available() {
        let a = active();
        assert!(available().iter().any(|k| k.name == a.name), "{}", a.name);
        // and it computes the canonical result
        let x = vec![0.25f32; 37];
        let y = vec![-1.5f32; 37];
        assert_eq!((a.dot)(&x, &y).to_bits(), dot_blocked(&x, &y).to_bits());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        for kern in available() {
            assert_eq!((kern.dot)(&[], &[]), 0.0, "{}", kern.name);
            assert_eq!((kern.dot)(&[2.0], &[3.0]), 6.0, "{}", kern.name);
            let mut y: [f32; 0] = [];
            (kern.axpy)(&mut y, 1.0, &[]);
            let mut y = [1.0f32];
            (kern.axpy)(&mut y, 2.0, &[3.0]);
            assert_eq!(y[0], 7.0, "{}", kern.name);
        }
    }
}
