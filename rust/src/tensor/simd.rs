//! Runtime-dispatched SIMD kernels for [`dot`](crate::tensor::dot) and
//! [`axpy`](crate::tensor::axpy) under one **canonical reduction order**.
//!
//! The canonical order (DESIGN.md §8) is what every implementation —
//! blocked scalar, SSE2, AVX2, NEON — must reproduce bit for bit:
//!
//! * `dot`: the main body runs in chunks of 8 elements; lane `l`
//!   accumulates `a[8c+l] * b[8c+l]` with a separate multiply and add
//!   (never a fused multiply-add — FMA rounds once where mul+add rounds
//!   twice, so contraction would break cross-kernel bit-equality). The 8
//!   lane sums are then combined by a fixed binary tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — exactly the shape a vector
//!   register's horizontal reduction produces — and the `n % 8` tail
//!   elements are added sequentially to the tree sum.
//! * `axpy`: `y[i] += alpha * x[i]` element-wise (mul, then add). Each
//!   element is independent, so any vector width reproduces the scalar
//!   result exactly; only FMA contraction is forbidden.
//!
//! Because every kernel performs identical per-lane IEEE operations in
//! identical order, the dispatch choice (scalar vs SSE2 vs AVX2 vs NEON,
//! `target-cpu=native` or not) can never change a result — the exec-layer
//! determinism contract (DESIGN.md §7) extends across instruction sets.
//! The property tests below enforce bit-equality of every available
//! kernel against the blocked scalar on all lane remainders.
//!
//! # Dispatch tiers
//!
//! | tier      | kernels                                   | contract |
//! |-----------|-------------------------------------------|----------|
//! | canonical | `scalar`, `sse2`, `avx2`, `neon`          | bitwise identical to [`dot_blocked`]/[`axpy_blocked`]; mul-then-add, FMA forbidden |
//! | fast-math | `fast-scalar`, `fma`, `avx512-fma`, `neon-fma` | bitwise identical to [`dot_fast_blocked`]/[`axpy_fast_blocked`] (fused canonical order); within ~1 ulp per operation of the canonical tier, pinned by tolerance goldens |
//!
//! The **fast-math tier** is opt-in (`--fast-math` on the CLI, or
//! `LEXICO_FAST_MATH=1`/`LEXICO_FAST_MATH=<kernel>` in the environment)
//! and trades the cross-tier bitwise contract for fused multiply-adds —
//! one rounding per lane step instead of two, which both sharpens and
//! speeds up the reduction (FMA ports on x86, `vfmaq` on NEON). The tier
//! keeps its *own* canonical order: every fast kernel performs the same
//! correctly-rounded `mul_add` per lane in the same blocked/tree shape,
//! so results within the tier are still bitwise reproducible across
//! hosts, thread counts and instruction sets — only comparisons *across*
//! tiers are relaxed, and those are pinned by tolerance goldens (kernel
//! ulp bounds + an end-to-end max |Δlogit| bound) instead of exact
//! snapshots.
//!
//! Dispatch is resolved once per process: `LEXICO_SIMD`
//! (`scalar|sse2|avx2|neon`) forces a canonical kernel when available on
//! the host; `LEXICO_FAST_MATH` (truthy, or a fast-kernel name) opts into
//! the fast tier. Otherwise the best detected canonical instruction set
//! wins (AVX2 → SSE2 on x86_64, NEON on aarch64, blocked scalar
//! elsewhere).

use std::sync::OnceLock;

/// One dot/axpy implementation pair. All pairs within a tier compute
/// bitwise-identical results; they differ only in speed. Across tiers
/// (canonical vs fast-math) results agree to tolerance, not bits.
#[derive(Clone, Copy)]
pub struct Kernels {
    pub name: &'static str,
    pub dot: fn(&[f32], &[f32]) -> f32,
    pub axpy: fn(&mut [f32], f32, &[f32]),
}

/// The canonical 8-lane combine: a fixed binary tree, matching the
/// horizontal reduction of one 8-wide (or two 4-wide) vector registers.
#[inline(always)]
fn lane_tree8(l: &[f32; 8]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Blocked-scalar `dot` in the canonical order — the reference every
/// vectorized kernel is tested against (and the fallback dispatch).
pub fn dot_blocked(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = lane_tree8(&acc);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// Blocked-scalar `axpy` (8-way unrolled for the autovectorizer;
/// element-independent, so the unroll shape carries no numeric meaning).
pub fn axpy_blocked(y: &mut [f32], alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        let yc = &mut y[i..i + 8];
        let xc = &x[i..i + 8];
        for l in 0..8 {
            yc[l] += alpha * xc[l];
        }
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

const SCALAR: Kernels = Kernels { name: "scalar", dot: dot_blocked, axpy: axpy_blocked };

// ---------------------------------------------------------------------------
// Fast-math tier: fused canonical order (opt-in, see module doc)
// ---------------------------------------------------------------------------

/// Blocked-scalar fused `dot` — the reference for the fast-math tier.
///
/// Same blocked/tree shape as [`dot_blocked`], but each lane step is one
/// correctly-rounded `f32::mul_add` instead of mul-then-add, and the tail
/// is fused too. Every fast-tier vector kernel must match this bit for
/// bit (hardware FMA and `mul_add` are both correctly rounded, so they
/// agree exactly); it matches the canonical tier only to tolerance.
pub fn dot_fast_blocked(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] = a[i + l].mul_add(b[i + l], acc[l]);
        }
    }
    let mut s = lane_tree8(&acc);
    for i in chunks * 8..n {
        s = a[i].mul_add(b[i], s);
    }
    s
}

/// Blocked-scalar fused `axpy` — `y[i] = alpha.mul_add(x[i], y[i])`.
/// Element-independent, so vector width carries no numeric meaning; the
/// only contract is one fused rounding per element.
pub fn axpy_fast_blocked(y: &mut [f32], alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        let yc = &mut y[i..i + 8];
        let xc = &x[i..i + 8];
        for l in 0..8 {
            yc[l] = alpha.mul_add(xc[l], yc[l]);
        }
    }
    for i in chunks * 8..n {
        y[i] = alpha.mul_add(x[i], y[i]);
    }
}

const FAST_SCALAR: Kernels =
    Kernels { name: "fast-scalar", dot: dot_fast_blocked, axpy: axpy_fast_blocked };

// ---------------------------------------------------------------------------
// x86_64: SSE2 (baseline, always present) and AVX2 (detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::lane_tree8;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure SSE2 is available (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        // two 4-lane accumulators = lanes 0..4 and 4..8 of the canonical
        // 8-lane block; mul then add, never FMA
        let mut acc_lo = _mm_setzero_ps();
        let mut acc_hi = _mm_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let a_lo = _mm_loadu_ps(a.as_ptr().add(i));
            let b_lo = _mm_loadu_ps(b.as_ptr().add(i));
            acc_lo = _mm_add_ps(acc_lo, _mm_mul_ps(a_lo, b_lo));
            let a_hi = _mm_loadu_ps(a.as_ptr().add(i + 4));
            let b_hi = _mm_loadu_ps(b.as_ptr().add(i + 4));
            acc_hi = _mm_add_ps(acc_hi, _mm_mul_ps(a_hi, b_hi));
        }
        let mut lanes = [0f32; 8];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc_lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), acc_hi);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure SSE2 is available (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sse2(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 4;
        let va = _mm_set1_ps(alpha);
        for c in 0..chunks {
            let i = c * 4;
            let vy = _mm_loadu_ps(y.as_ptr().add(i));
            let vx = _mm_loadu_ps(x.as_ptr().add(i));
            _mm_storeu_ps(y.as_mut_ptr().add(i), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
        }
        for i in chunks * 4..n {
            y[i] += alpha * x[i];
        }
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (checked at dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            // vmulps + vaddps: per-lane identical to the scalar mul + add
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure AVX2 is available (checked at dispatch).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 8;
        let va = _mm256_set1_ps(alpha);
        for c in 0..chunks {
            let i = c * 8;
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i];
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: SSE2 is part of the x86_64 baseline.
    unsafe { x86::dot_sse2(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_sse2(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: SSE2 is part of the x86_64 baseline.
    unsafe { x86::axpy_sse2(y, alpha, x) }
}

#[cfg(target_arch = "x86_64")]
fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: only reachable through dispatch/tests after AVX2 detection.
    unsafe { x86::dot_avx2(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_avx2(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: only reachable through dispatch/tests after AVX2 detection.
    unsafe { x86::axpy_avx2(y, alpha, x) }
}

#[cfg(target_arch = "x86_64")]
const SSE2: Kernels = Kernels { name: "sse2", dot: dot_sse2, axpy: axpy_sse2 };

#[cfg(target_arch = "x86_64")]
const AVX2: Kernels = Kernels { name: "avx2", dot: dot_avx2, axpy: axpy_avx2 };

#[cfg(target_arch = "x86_64")]
mod x86_fast {
    use super::lane_tree8;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure AVX2 and FMA are available (checked at dispatch).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            // vfmadd231ps: per-lane identical to the scalar mul_add
            acc = _mm256_fmadd_ps(va, vb, acc);
        }
        let mut lanes = [0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s = a[i].mul_add(b[i], s);
        }
        s
    }

    /// # Safety
    /// Caller must ensure AVX2 and FMA are available (checked at dispatch).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_fma(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 8;
        let va = _mm256_set1_ps(alpha);
        for c in 0..chunks {
            let i = c * 8;
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_fmadd_ps(va, vx, vy));
        }
        for i in chunks * 8..n {
            y[i] = alpha.mul_add(x[i], y[i]);
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: only reachable through dispatch/tests after avx2+fma detection.
    unsafe { x86_fast::dot_fma(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn axpy_fma(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: only reachable through dispatch/tests after avx2+fma detection.
    unsafe { x86_fast::axpy_fma(y, alpha, x) }
}

#[cfg(target_arch = "x86_64")]
const FMA: Kernels = Kernels { name: "fma", dot: dot_fma, axpy: axpy_fma };

// AVX-512 variant: compile-time gated (target-cpu=native on an avx512f
// host, as in the CI test-native job). The loops below stay in safe code
// and autovectorize to 16-wide zmm FMAs; the numeric result is defined
// by the per-lane mul_adds and the lane_tree8 combine, not by the vector
// width the compiler picks, so it remains bitwise within the fast tier.
#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
fn dot_avx512_fma(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] = a[i + l].mul_add(b[i + l], acc[l]);
        }
    }
    let mut s = lane_tree8(&acc);
    for i in chunks * 8..n {
        s = a[i].mul_add(b[i], s);
    }
    s
}

#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
fn axpy_avx512_fma(y: &mut [f32], alpha: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    for i in 0..n {
        y[i] = alpha.mul_add(x[i], y[i]);
    }
}

#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
const AVX512_FMA: Kernels =
    Kernels { name: "avx512-fma", dot: dot_avx512_fma, axpy: axpy_avx512_fma };

// ---------------------------------------------------------------------------
// aarch64: NEON (baseline, always present)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::lane_tree8;
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let i = c * 8;
            acc_lo = vaddq_f32(
                acc_lo,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i))),
            );
            acc_hi = vaddq_f32(
                acc_hi,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4))),
            );
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 4;
        let va = vdupq_n_f32(alpha);
        for c in 0..chunks {
            let i = c * 4;
            let vy = vld1q_f32(y.as_ptr().add(i));
            let vx = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vmulq_f32(va, vx)));
        }
        for i in chunks * 4..n {
            y[i] += alpha * x[i];
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: NEON is part of the aarch64 baseline.
    unsafe { arm::dot_neon(a, b) }
}

#[cfg(target_arch = "aarch64")]
fn axpy_neon(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: NEON is part of the aarch64 baseline.
    unsafe { arm::axpy_neon(y, alpha, x) }
}

#[cfg(target_arch = "aarch64")]
const NEON: Kernels = Kernels { name: "neon", dot: dot_neon, axpy: axpy_neon };

#[cfg(target_arch = "aarch64")]
mod arm_fast {
    use super::lane_tree8;
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon_fma(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let i = c * 8;
            // vfmaq: per-lane identical to the scalar mul_add
            acc_lo = vfmaq_f32(acc_lo, vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            acc_hi = vfmaq_f32(
                acc_hi,
                vld1q_f32(a.as_ptr().add(i + 4)),
                vld1q_f32(b.as_ptr().add(i + 4)),
            );
        }
        let mut lanes = [0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        let mut s = lane_tree8(&lanes);
        for i in chunks * 8..n {
            s = a[i].mul_add(b[i], s);
        }
        s
    }

    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon_fma(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let chunks = n / 4;
        let va = vdupq_n_f32(alpha);
        for c in 0..chunks {
            let i = c * 4;
            let vy = vld1q_f32(y.as_ptr().add(i));
            let vx = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vfmaq_f32(vy, va, vx));
        }
        for i in chunks * 4..n {
            y[i] = alpha.mul_add(x[i], y[i]);
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn dot_neon_fma(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: NEON is part of the aarch64 baseline.
    unsafe { arm_fast::dot_neon_fma(a, b) }
}

#[cfg(target_arch = "aarch64")]
fn axpy_neon_fma(y: &mut [f32], alpha: f32, x: &[f32]) {
    // SAFETY: NEON is part of the aarch64 baseline.
    unsafe { arm_fast::axpy_neon_fma(y, alpha, x) }
}

#[cfg(target_arch = "aarch64")]
const NEON_FMA: Kernels = Kernels { name: "neon-fma", dot: dot_neon_fma, axpy: axpy_neon_fma };

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// Every kernel implementation usable on this host, best first. The blocked
/// scalar is always present and always last.
pub fn available() -> Vec<Kernels> {
    let mut v = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            v.push(AVX2);
        }
        v.push(SSE2);
    }
    #[cfg(target_arch = "aarch64")]
    v.push(NEON);
    v.push(SCALAR);
    v
}

/// Every fast-math kernel usable on this host, best first. The fused
/// blocked scalar is always present and always last. All entries compute
/// bitwise-identical results *within this tier* (see module doc).
pub fn fast_available() -> Vec<Kernels> {
    let mut v = Vec::new();
    #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
    v.push(AVX512_FMA);
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            v.push(FMA);
        }
    }
    #[cfg(target_arch = "aarch64")]
    v.push(NEON_FMA);
    v.push(FAST_SCALAR);
    v
}

/// Whether the process has opted into the fast-math tier: `--fast-math`
/// on the CLI (which sets the env var before dispatch) or any
/// `LEXICO_FAST_MATH` value other than empty/`0`.
pub fn fast_math_requested() -> bool {
    match std::env::var("LEXICO_FAST_MATH") {
        Ok(v) => {
            let v = v.trim();
            !v.is_empty() && v != "0"
        }
        Err(_) => false,
    }
}

fn select() -> Kernels {
    let forced = std::env::var("LEXICO_SIMD").ok();
    let want = forced.as_deref().map(str::trim).filter(|w| !w.is_empty());
    if fast_math_requested() {
        let fast = fast_available();
        // LEXICO_SIMD may name a fast kernel to pin one explicitly; a
        // canonical name under the fast-math flag is a contradiction we
        // resolve in favor of the explicit flag, with a warning.
        if let Some(w) = want {
            if let Some(k) = fast.iter().find(|k| k.name == w) {
                return *k;
            }
            eprintln!(
                "warning: LEXICO_SIMD={w} is not a fast-math kernel (have: {}); \
                 LEXICO_FAST_MATH is set, auto-selecting from the fast tier",
                fast.iter().map(|k| k.name).collect::<Vec<_>>().join(",")
            );
        }
        return fast[0];
    }
    let avail = available();
    if let Some(w) = want {
        if let Some(k) = avail.iter().find(|k| k.name == w) {
            return *k;
        }
        eprintln!(
            "warning: LEXICO_SIMD={w} not available on this host (have: {}); auto-selecting",
            avail.iter().map(|k| k.name).collect::<Vec<_>>().join(",")
        );
    }
    avail[0]
}

static ACTIVE: OnceLock<Kernels> = OnceLock::new();

/// The kernel pair the process dispatches to (resolved once, then free).
#[inline]
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(select)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Lengths covering every lane remainder (0..8 twice), the chunk
    /// boundaries, and sizes past several chunks.
    fn probe_lengths() -> Vec<usize> {
        let mut v: Vec<usize> = (0..=17).collect();
        v.extend([23, 31, 32, 33, 63, 64, 65, 96, 127, 128, 129, 255, 1000]);
        v
    }

    #[test]
    fn every_available_kernel_matches_blocked_scalar_bitwise() {
        let mut rng = Rng::new(0xD07);
        for kern in available() {
            for &n in &probe_lengths() {
                for rep in 0..4 {
                    let a = rng.normal_vec(n);
                    let b = rng.normal_vec(n);
                    let want = dot_blocked(&a, &b);
                    let got = (kern.dot)(&a, &b);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{} dot diverged at n={n} rep={rep}: {got} vs {want}",
                        kern.name
                    );
                    let alpha = if rep == 3 { 0.0 } else { rng.range_f32(-2.0, 2.0) };
                    let y0 = rng.normal_vec(n);
                    let mut y_want = y0.clone();
                    let mut y_got = y0;
                    axpy_blocked(&mut y_want, alpha, &b);
                    (kern.axpy)(&mut y_got, alpha, &b);
                    for i in 0..n {
                        assert_eq!(
                            y_got[i].to_bits(),
                            y_want[i].to_bits(),
                            "{} axpy diverged at n={n} i={i} alpha={alpha}",
                            kern.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_fast_kernel_matches_fast_blocked_scalar_bitwise() {
        // The fast tier has its own canonical order (fused lane steps);
        // every fast kernel must reproduce dot_fast_blocked/axpy_fast_blocked
        // bit for bit — hardware FMA and f32::mul_add are both correctly
        // rounded, so exact agreement is the contract, not an aspiration.
        let mut rng = Rng::new(0xFA57);
        for kern in fast_available() {
            for &n in &probe_lengths() {
                for rep in 0..4 {
                    let a = rng.normal_vec(n);
                    let b = rng.normal_vec(n);
                    let want = dot_fast_blocked(&a, &b);
                    let got = (kern.dot)(&a, &b);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "{} dot diverged at n={n} rep={rep}: {got} vs {want}",
                        kern.name
                    );
                    let alpha = if rep == 3 { 0.0 } else { rng.range_f32(-2.0, 2.0) };
                    let y0 = rng.normal_vec(n);
                    let mut y_want = y0.clone();
                    let mut y_got = y0;
                    axpy_fast_blocked(&mut y_want, alpha, &b);
                    (kern.axpy)(&mut y_got, alpha, &b);
                    for i in 0..n {
                        assert_eq!(
                            y_got[i].to_bits(),
                            y_want[i].to_bits(),
                            "{} axpy diverged at n={n} i={i} alpha={alpha}",
                            kern.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fast_tier_matches_canonical_within_tolerance() {
        // Cross-tier contract: fused vs mul-then-add differ by at most one
        // rounding per lane step, so |fast - canonical| is bounded by a few
        // ulps of the magnitude sum Σ|a_i·b_i| (the worst case when terms
        // cancel). Pin that bound so a fast kernel that silently reorders
        // the reduction (not just fuses it) fails loudly.
        let mut rng = Rng::new(0x70E5);
        for &n in &probe_lengths() {
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let want = dot_blocked(&a, &b);
            let got = dot_fast_blocked(&a, &b);
            let mag: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            let tol = (mag * 2e-6).max(1e-6);
            assert!(
                (got - want).abs() <= tol,
                "fast dot drifted past tolerance at n={n}: {got} vs {want} (tol {tol})"
            );
            let y0 = rng.normal_vec(n);
            let alpha = rng.range_f32(-2.0, 2.0);
            let mut y_want = y0.clone();
            let mut y_fast = y0;
            axpy_blocked(&mut y_want, alpha, &b);
            axpy_fast_blocked(&mut y_fast, alpha, &b);
            for i in 0..n {
                let tol = ((alpha * b[i]).abs() * 2e-6).max(1e-6);
                assert!(
                    (y_fast[i] - y_want[i]).abs() <= tol,
                    "fast axpy drifted past tolerance at n={n} i={i}"
                );
            }
        }
    }

    #[test]
    fn fast_tier_attention_readout_tolerance_golden() {
        // End-to-end tolerance golden for the fast tier on the shape that
        // matters: compressed-attention readout (scores → softmax → axpy
        // accumulate → logit dots). Bounds max |Δlogit| between canonical
        // and every fast kernel, pinning the tier's user-visible drift.
        let mut rng = Rng::new(0x10617);
        let (m, n_tok, n_logit) = (64usize, 96usize, 32usize);
        let q = rng.normal_vec(m);
        let keys: Vec<Vec<f32>> = (0..n_tok).map(|_| rng.normal_vec(m)).collect();
        let vals: Vec<Vec<f32>> = (0..n_tok).map(|_| rng.normal_vec(m)).collect();
        let heads: Vec<Vec<f32>> = (0..n_logit).map(|_| rng.normal_vec(m)).collect();
        let readout = |kern: &Kernels| -> Vec<f32> {
            let mut scores: Vec<f32> = keys.iter().map(|k| (kern.dot)(&q, k)).collect();
            let scale = 1.0 / (m as f32).sqrt();
            for s in &mut scores {
                *s *= scale;
            }
            crate::tensor::softmax(&mut scores);
            let mut o = vec![0f32; m];
            for (w, v) in scores.iter().zip(&vals) {
                (kern.axpy)(&mut o, *w, v);
            }
            heads.iter().map(|h| (kern.dot)(&o, h)).collect()
        };
        let want = readout(&SCALAR);
        for kern in fast_available() {
            let got = readout(&kern);
            let max_dlogit = got
                .iter()
                .zip(&want)
                .map(|(g, w)| (g - w).abs())
                .fold(0f32, f32::max);
            assert!(
                max_dlogit < 1e-4,
                "{}: max |Δlogit| = {max_dlogit} exceeds the fast-math golden bound",
                kern.name
            );
        }
    }

    #[test]
    fn kernels_tolerate_mismatched_slice_lengths() {
        // dot/axpy contract: operate on the shorter length (callers rely on
        // this for strided views).
        let a = vec![1.0f32; 20];
        let b = vec![2.0f32; 13];
        for kern in available() {
            assert_eq!((kern.dot)(&a, &b), dot_blocked(&a, &b), "{}", kern.name);
            let mut y1 = vec![1.0f32; 11];
            let mut y2 = y1.clone();
            axpy_blocked(&mut y1, 0.5, &a);
            (kern.axpy)(&mut y2, 0.5, &a);
            assert_eq!(y1, y2, "{}", kern.name);
        }
        for kern in fast_available() {
            assert_eq!((kern.dot)(&a, &b), dot_fast_blocked(&a, &b), "{}", kern.name);
            let mut y1 = vec![1.0f32; 11];
            let mut y2 = y1.clone();
            axpy_fast_blocked(&mut y1, 0.5, &a);
            (kern.axpy)(&mut y2, 0.5, &a);
            assert_eq!(y1, y2, "{}", kern.name);
        }
    }

    #[test]
    fn lane_tree_matches_register_reduction_shape() {
        // sanity-pin the canonical combine: NOT a linear left fold
        let l = [1e8f32, 1.0, -1e8, 1.0, 3.0, 4.0, 5.0, 6.0];
        let tree = ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]));
        assert_eq!(lane_tree8(&l), tree);
        // and the linear fold genuinely differs on this input, so the test
        // would catch a silent reversion to the old order
        let linear: f32 = l.iter().sum();
        assert_ne!(tree.to_bits(), linear.to_bits());
    }

    #[test]
    fn active_is_one_of_available() {
        // Dispatch is frozen per process, so respect whichever tier the
        // environment selected: the active kernel must come from that
        // tier's list and reproduce that tier's reference bit for bit.
        let a = active();
        let x = vec![0.25f32; 37];
        let y = vec![-1.5f32; 37];
        if fast_math_requested() {
            assert!(fast_available().iter().any(|k| k.name == a.name), "{}", a.name);
            assert_eq!((a.dot)(&x, &y).to_bits(), dot_fast_blocked(&x, &y).to_bits());
        } else {
            assert!(available().iter().any(|k| k.name == a.name), "{}", a.name);
            assert_eq!((a.dot)(&x, &y).to_bits(), dot_blocked(&x, &y).to_bits());
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        for kern in available().into_iter().chain(fast_available()) {
            assert_eq!((kern.dot)(&[], &[]), 0.0, "{}", kern.name);
            assert_eq!((kern.dot)(&[2.0], &[3.0]), 6.0, "{}", kern.name);
            let mut y: [f32; 0] = [];
            (kern.axpy)(&mut y, 1.0, &[]);
            let mut y = [1.0f32];
            (kern.axpy)(&mut y, 2.0, &[3.0]);
            assert_eq!(y[0], 7.0, "{}", kern.name);
        }
    }
}
