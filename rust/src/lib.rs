//! # Lexico — extreme KV cache compression via sparse coding
//!
//! Full-system reproduction of *"Lexico: Extreme KV Cache Compression via
//! Sparse Coding over Universal Dictionaries"* (ICML 2025) as a three-layer
//! Rust + JAX + Pallas stack. This crate is Layer 3: the serving
//! coordinator, the native inference engine, every cache-compression
//! backend the paper evaluates, and the PJRT runtime that executes the
//! AOT-compiled L1/L2 artifacts. See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for the paper-vs-measured results.

pub mod cache;
pub mod dict;
pub mod eval;
pub mod exec;
pub mod model;
pub mod omp;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod sparse;
pub mod store;
pub mod tasks;
pub mod tensor;
pub mod util;

/// Default artifacts directory (overridable via `LEXICO_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("LEXICO_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// Default reports directory (overridable via `LEXICO_REPORTS`).
pub fn reports_dir() -> std::path::PathBuf {
    std::env::var_os("LEXICO_REPORTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("reports"))
}
