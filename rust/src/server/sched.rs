//! SLO-aware multi-tenant scheduling policy: priority-ordered admission,
//! per-tenant quotas, graceful-overload shedding, deadline bookkeeping and
//! the TTFT/TPOT governors that steer the batcher's per-round budgets.
//!
//! Every *decision* in this module is a pure function of explicit inputs —
//! queue snapshots, observed round latencies, a scheduler clock — so the
//! batcher's shed/priority/deadline behavior is pinned bitwise by unit
//! tests: tests drive a [`Clock::Manual`] time source and seeded arrival
//! orders, production swaps in wall time without changing a single
//! decision rule. The split keeps the batcher's scheduling loop honest:
//! it *observes* (measures round latency, stamps arrival sequence numbers)
//! and this module *decides* (who admits, who sheds, who expires, how many
//! prompt tokens and decode seats this round may spend).

use std::collections::BTreeMap;
use std::time::Instant;

/// Round-time prior used for `retry_after_ms` hints before any round has
/// been measured (a freshly started — or manually clocked — batcher).
pub const DEFAULT_ROUND_MS: f64 = 5.0;

// ---------------------------------------------------------------------------
// Scheduler clock
// ---------------------------------------------------------------------------

/// The scheduler's time source. Production uses wall time since batcher
/// start; tests pin a manual value so deadline expiry and shed decisions
/// replay bitwise — the determinism scope promised in DESIGN.md §13 (no
/// wall-clock reads sit in the decision path under test).
#[derive(Clone, Debug)]
pub enum Clock {
    /// milliseconds elapsed since the batcher started
    Wall(Instant),
    /// a fixed time in milliseconds, advanced explicitly by tests
    Manual(f64),
}

impl Clock {
    pub fn wall() -> Self {
        Clock::Wall(Instant::now())
    }

    pub fn now_ms(&self) -> f64 {
        match self {
            Clock::Wall(t0) => t0.elapsed().as_secs_f64() * 1e3,
            Clock::Manual(ms) => *ms,
        }
    }
}

// ---------------------------------------------------------------------------
// SLO targets
// ---------------------------------------------------------------------------

/// Service-level targets steering the round budgets (0 = target unset).
/// `ttft_ms` bounds time-to-first-token: a prefilling request past half its
/// target abandons chunk pacing and rushes its remaining prompt. `tpot_ms`
/// bounds per-round latency: sustained overshoot shrinks the prefill chunk
/// budget and caps the decode batch (highest-priority sessions keep their
/// cadence; lower priorities are paced down instead of everyone missing).
#[derive(Clone, Copy, Debug, Default)]
pub struct SloTargets {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
}

// ---------------------------------------------------------------------------
// Per-tenant quotas
// ---------------------------------------------------------------------------

/// Admission limits for one tenant (0 = unlimited on that axis).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantQuota {
    /// concurrent seats (sessions incl. pending fan-out candidates)
    pub seats: usize,
    /// charged KV bytes across the tenant's live sessions
    pub kv_bytes: f64,
}

/// The `--tenant-quota` table. A request's `tenant` field selects its row;
/// the `*` row (if present) applies to tenants without an explicit entry,
/// and tenants matching no row are unlimited. Over-quota jobs are *skipped*
/// (left queued), never rejected — quota pressure resolves as the tenant's
/// own sessions retire, while other tenants admit past the blocked job
/// (no head-of-line blocking across tenants).
#[derive(Clone, Debug, Default)]
pub struct TenantQuotas {
    quotas: BTreeMap<String, TenantQuota>,
}

impl TenantQuotas {
    /// Parse a spec like `"free=seats:2,kv_mb:4;pro=seats:16;*=seats:8"`.
    /// Entries are `;`-separated, limits `,`-separated `key:value` pairs
    /// with keys `seats` and `kv_mb`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut quotas = BTreeMap::new();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (name, limits) = entry
                .split_once('=')
                .ok_or_else(|| format!("tenant quota entry '{entry}' is not NAME=LIMITS"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(format!("tenant quota entry '{entry}' has an empty tenant name"));
            }
            let mut q = TenantQuota::default();
            for limit in limits.split(',').map(str::trim).filter(|l| !l.is_empty()) {
                let (key, val) = limit
                    .split_once(':')
                    .ok_or_else(|| format!("tenant limit '{limit}' is not key:value"))?;
                match key.trim() {
                    "seats" => {
                        q.seats = val
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad seats value '{val}'"))?;
                    }
                    "kv_mb" => {
                        let mb: f64 = val
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad kv_mb value '{val}'"))?;
                        if mb.is_nan() || mb < 0.0 {
                            return Err(format!("bad kv_mb value '{val}'"));
                        }
                        q.kv_bytes = mb * 1024.0 * 1024.0;
                    }
                    other => return Err(format!("unknown tenant limit key '{other}'")),
                }
            }
            if quotas.insert(name.to_string(), q).is_some() {
                return Err(format!("duplicate tenant quota entry for '{name}'"));
            }
        }
        Ok(TenantQuotas { quotas })
    }

    /// The quota governing `tenant`: its own row, else the `*` row, else
    /// none (unlimited).
    pub fn get(&self, tenant: &str) -> Option<TenantQuota> {
        self.quotas.get(tenant).or_else(|| self.quotas.get("*")).copied()
    }

    pub fn is_empty(&self) -> bool {
        self.quotas.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Priority-ordered admission + overload shedding
// ---------------------------------------------------------------------------

/// What the admission/shed policy may see of one queued job: its arrival
/// sequence number (stamped at enqueue — the seeded, deterministic order)
/// and its priority. Nothing time-valued enters these decisions.
#[derive(Clone, Copy, Debug)]
pub struct QueueSlot {
    pub seq: u64,
    pub priority: i64,
    /// whether overload may shed this job (generate requests; session
    /// save/resume verbs are cheap bookkeeping and are never shed)
    pub sheddable: bool,
}

/// Admission order replacing the FIFO: highest priority first, FIFO within
/// a priority class. With all-default priorities this degenerates to
/// exactly the old arrival order.
pub fn admission_order(slots: &[QueueSlot]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..slots.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(slots[i].priority), slots[i].seq));
    order
}

/// The queued job graceful overload sheds next: lowest priority first,
/// newest arrival within the class (the oldest waiter of a class has paid
/// the most and is closest to service — shedding it would maximize wasted
/// wait). None when nothing is sheddable.
pub fn shed_victim(slots: &[QueueSlot]) -> Option<usize> {
    (0..slots.len())
        .filter(|&i| slots[i].sheddable)
        .max_by_key(|&i| (std::cmp::Reverse(slots[i].priority), slots[i].seq))
}

/// Backoff hint for a shed (or busy-rejected) client: a lower bound on the
/// queue's drain time — `depth` jobs ahead, at most `max_sessions` retiring
/// per round, `round_ms` per round. Deterministic in its inputs; clamped to
/// at least 1 ms so a `retry_after_ms` of 0 never tells a client to
/// hot-loop.
pub fn retry_after_ms(depth: usize, max_sessions: usize, round_ms: f64) -> u64 {
    let rounds = (depth as f64 / max_sessions.max(1) as f64).ceil().max(1.0);
    (rounds * round_ms.max(1.0)).ceil() as u64
}

// ---------------------------------------------------------------------------
// TTFT/TPOT governors
// ---------------------------------------------------------------------------

/// Adaptive per-round prefill chunk budget: AIMD against the TPOT target.
/// A round over target halves the budget (multiplicative decrease — long
/// prompts yield the round to decode cadence); a round under half target
/// grows it additively back toward the configured base. With no target the
/// budget pins to the base, making the governor invisible.
#[derive(Clone, Debug)]
pub struct ChunkGovernor {
    base: usize,
    min: usize,
    budget: usize,
}

impl ChunkGovernor {
    /// `base` is the configured `--prefill-chunk` (0 = monolithic prefill,
    /// which the governor leaves alone: an unchunkable admission cannot be
    /// paced, only scheduled).
    pub fn new(base: usize) -> Self {
        let base = if base == 0 { usize::MAX } else { base };
        let min = if base == usize::MAX { usize::MAX } else { (base / 16).max(1) };
        ChunkGovernor { base, min, budget: base }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Feed one observed round latency; returns the next round's budget.
    pub fn observe(&mut self, round_ms: f64, target_ms: f64) -> usize {
        if target_ms <= 0.0 || self.base == usize::MAX {
            self.budget = self.base;
        } else if round_ms > target_ms {
            self.budget = (self.budget / 2).max(self.min);
        } else if round_ms * 2.0 < target_ms {
            self.budget = self.budget.saturating_add((self.base / 8).max(1)).min(self.base);
        }
        self.budget
    }
}

/// Adaptive decode-batch cap under TPOT pressure: when rounds run hot the
/// cap halves (highest-priority sessions keep advancing every round, the
/// rest are paced), and recovers multiplicatively once rounds run cool.
/// `usize::MAX` = uncapped, the steady state whenever the target is unset
/// or met — the batcher's fast path skips selection entirely then, so the
/// governor is bitwise invisible to existing workloads.
#[derive(Clone, Debug, Default)]
pub struct BatchGovernor {
    cap: usize,
}

impl BatchGovernor {
    pub fn new() -> Self {
        BatchGovernor { cap: usize::MAX }
    }

    pub fn cap(&self) -> usize {
        if self.cap == 0 { usize::MAX } else { self.cap }
    }

    /// Feed one observed round latency at the given batch size.
    pub fn observe(&mut self, round_ms: f64, target_ms: f64, batch: usize) -> usize {
        if target_ms <= 0.0 {
            self.cap = usize::MAX;
        } else if round_ms > target_ms * 1.5 && batch > 1 {
            self.cap = (self.cap.min(batch) / 2).max(1);
        } else if round_ms * 2.0 < target_ms && self.cap != usize::MAX {
            let doubled = self.cap.saturating_mul(2);
            self.cap = if doubled >= batch { usize::MAX } else { doubled };
        }
        self.cap()
    }
}

impl Default for ChunkGovernor {
    fn default() -> Self {
        ChunkGovernor::new(0)
    }
}

/// Whether a prefilling request should abandon chunk pacing and rush its
/// remaining prompt this round: past half the TTFT target, finishing the
/// prefill dominates protecting other sessions' round latency.
pub fn ttft_rush(age_ms: f64, ttft_target_ms: f64) -> bool {
    ttft_target_ms > 0.0 && age_ms * 2.0 >= ttft_target_ms
}

// ---------------------------------------------------------------------------
// Capped decode-batch composition
// ---------------------------------------------------------------------------

/// What decode selection may see of one decodable session: priority, the
/// round it last advanced (aging — within a priority class the session
/// paced longest goes first, so a cap rotates fairly instead of starving),
/// and its seat order as the final deterministic tie-break.
#[derive(Clone, Copy, Debug)]
pub struct DecodeSlot {
    pub priority: i64,
    pub last_step_round: u64,
    pub seat: u64,
}

/// Indices (into `slots`, ascending) of the sessions that advance this
/// round under `cap`. Selection changes only *pacing*: a deferred session
/// keeps its pending token and produces the identical stream later.
pub fn decode_selection(slots: &[DecodeSlot], cap: usize) -> Vec<usize> {
    if slots.len() <= cap {
        return (0..slots.len()).collect();
    }
    let mut order: Vec<usize> = (0..slots.len()).collect();
    order.sort_by_key(|&i| {
        (std::cmp::Reverse(slots[i].priority), slots[i].last_step_round, slots[i].seat)
    });
    order.truncate(cap);
    order.sort_unstable();
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(seq: u64, priority: i64) -> QueueSlot {
        QueueSlot { seq, priority, sheddable: true }
    }

    #[test]
    fn admission_order_is_priority_then_fifo() {
        let slots =
            [slot(0, 0), slot(1, 5), slot(2, 5), slot(3, -1), slot(4, 0)];
        assert_eq!(admission_order(&slots), vec![1, 2, 0, 4, 3]);
        // all-default priorities degenerate to exact arrival order
        let flat = [slot(10, 0), slot(11, 0), slot(12, 0)];
        assert_eq!(admission_order(&flat), vec![0, 1, 2]);
    }

    #[test]
    fn shed_victim_is_lowest_priority_newest_arrival() {
        let slots = [slot(0, 0), slot(1, 5), slot(2, 0), slot(3, 9)];
        // two priority-0 jobs: the newer one (seq 2) sheds first
        assert_eq!(shed_victim(&slots), Some(2));
        // save/resume verbs are never shed
        let mut pinned = [slot(0, 0), slot(1, -5)];
        pinned[1].sheddable = false;
        assert_eq!(shed_victim(&pinned), Some(0));
        pinned[0].sheddable = false;
        assert_eq!(shed_victim(&pinned), None);
    }

    #[test]
    fn retry_after_scales_with_queue_depth_and_never_hits_zero() {
        assert_eq!(retry_after_ms(0, 4, 5.0), 5);
        assert_eq!(retry_after_ms(8, 4, 5.0), 10);
        assert_eq!(retry_after_ms(9, 4, 5.0), 15);
        assert!(retry_after_ms(1, 1000, 0.0) >= 1);
    }

    #[test]
    fn tenant_quota_parse_and_lookup() {
        let q = TenantQuotas::parse("free=seats:2,kv_mb:4; pro = seats:16 ;*=seats:8").unwrap();
        assert_eq!(q.get("free").unwrap().seats, 2);
        assert_eq!(q.get("free").unwrap().kv_bytes, 4.0 * 1024.0 * 1024.0);
        assert_eq!(q.get("pro").unwrap(), TenantQuota { seats: 16, kv_bytes: 0.0 });
        // unlisted tenant falls to the wildcard row
        assert_eq!(q.get("other").unwrap().seats, 8);
        // no wildcard → unlisted tenants are unlimited
        let q2 = TenantQuotas::parse("free=seats:1").unwrap();
        assert!(q2.get("other").is_none());
        assert!(TenantQuotas::parse("").unwrap().is_empty());
    }

    #[test]
    fn tenant_quota_parse_rejects_malformed_specs() {
        assert!(TenantQuotas::parse("free").is_err());
        assert!(TenantQuotas::parse("=seats:1").is_err());
        assert!(TenantQuotas::parse("a=seats").is_err());
        assert!(TenantQuotas::parse("a=seats:x").is_err());
        assert!(TenantQuotas::parse("a=kv_mb:-1").is_err());
        assert!(TenantQuotas::parse("a=pages:3").is_err());
        assert!(TenantQuotas::parse("a=seats:1;a=seats:2").is_err());
    }

    #[test]
    fn chunk_governor_aimd_against_tpot_target() {
        let mut g = ChunkGovernor::new(256);
        assert_eq!(g.budget(), 256);
        // no target: pinned to base regardless of latency
        assert_eq!(g.observe(1e9, 0.0), 256);
        // over target: halves, floored at base/16
        assert_eq!(g.observe(10.0, 5.0), 128);
        assert_eq!(g.observe(10.0, 5.0), 64);
        for _ in 0..10 {
            g.observe(10.0, 5.0);
        }
        assert_eq!(g.budget(), 16);
        // under half target: additive recovery, capped at base
        assert_eq!(g.observe(1.0, 5.0), 48);
        for _ in 0..10 {
            g.observe(1.0, 5.0);
        }
        assert_eq!(g.budget(), 256);
        // between half and full target: hold
        assert_eq!(g.observe(4.0, 5.0), 256);
        // monolithic base stays monolithic
        let mut m = ChunkGovernor::new(0);
        assert_eq!(m.observe(1e9, 1.0), usize::MAX);
    }

    #[test]
    fn batch_governor_caps_under_pressure_and_recovers() {
        let mut g = BatchGovernor::new();
        assert_eq!(g.cap(), usize::MAX);
        // hot rounds at batch 8: cap 4, then 2, then 1
        assert_eq!(g.observe(10.0, 5.0, 8), 4);
        assert_eq!(g.observe(10.0, 5.0, 4), 2);
        assert_eq!(g.observe(10.0, 5.0, 2), 1);
        assert_eq!(g.observe(10.0, 5.0, 1), 1); // a batch of 1 can't shrink
        // cool rounds: doubles, then uncaps once it covers the batch
        assert_eq!(g.observe(1.0, 5.0, 8), 2);
        assert_eq!(g.observe(1.0, 5.0, 8), 4);
        assert_eq!(g.observe(1.0, 5.0, 8), usize::MAX);
        // unset target is always uncapped
        assert_eq!(g.observe(1e9, 0.0, 64), usize::MAX);
    }

    #[test]
    fn ttft_rush_past_half_target() {
        assert!(!ttft_rush(10.0, 100.0));
        assert!(ttft_rush(50.0, 100.0));
        assert!(ttft_rush(99.0, 100.0));
        assert!(!ttft_rush(1e9, 0.0)); // unset target never rushes
    }

    #[test]
    fn decode_selection_priority_then_aging_then_seat() {
        let s = |priority, last_step_round, seat| DecodeSlot { priority, last_step_round, seat };
        let slots = [s(0, 5, 0), s(5, 5, 1), s(0, 3, 2), s(5, 5, 3)];
        // uncapped: everyone advances (fast path)
        assert_eq!(decode_selection(&slots, usize::MAX), vec![0, 1, 2, 3]);
        // cap 2: both priority-5 sessions (seat order breaks their tie)
        assert_eq!(decode_selection(&slots, 2), vec![1, 3]);
        // cap 3: the longest-paced priority-0 session (aging) joins
        assert_eq!(decode_selection(&slots, 3), vec![1, 2, 3]);
        assert_eq!(decode_selection(&slots, 0), Vec::<usize>::new());
    }

    #[test]
    fn manual_clock_is_pinned() {
        let c = Clock::Manual(123.5);
        assert_eq!(c.now_ms(), 123.5);
        assert!(Clock::wall().now_ms() >= 0.0);
    }
}
