//! The serving coordinator: request router, continuous batcher,
//! prefill/decode scheduler with a KV-memory admission budget, metrics.
//!
//! Architecture (std-thread based — the image has no async runtime):
//!
//! ```text
//!   TCP clients ──► http.rs (thread per conn, JSON-lines)
//!        │ mpsc                                   ▲ per-request channel
//!        ▼                                        │
//!   batcher.rs  — iteration-level scheduling loop (Orca-style):
//!     admit pending requests while the KV budget allows (prefill),
//!     then advance ALL active sessions one token per round through a
//!     single layer-major Engine::decode_batch call (continuous
//!     batching, batch-first), retiring finished sessions.
//! ```
//!
//! Every session owns its KV cache through the same [`KvCache`] backends
//! the offline evals use, so serving with `--method lexico:…` exercises
//! exactly the paper's system: compressed prefix + recency buffer + OMP
//! compression riding along with decoding.

pub mod batcher;
pub mod http;
pub mod metrics;

use std::sync::mpsc::Sender;

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    /// cache-method spec; empty = server default
    pub method: String,
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub kv_ratio: f64,
    pub error: Option<String>,
}

/// A request plus its reply channel (what the batcher consumes).
pub struct Job {
    pub request: Request,
    pub reply: Sender<Response>,
}
