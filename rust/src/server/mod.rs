//! The serving coordinator: request router, continuous batcher,
//! prefill/decode scheduler with a KV-memory admission budget, metrics.
//!
//! Architecture (std-thread based — the image has no async runtime):
//!
//! ```text
//!   TCP clients ──► http.rs (thread per conn, JSON-lines)
//!        │ mpsc                                   ▲ per-request channel
//!        ▼                                        │
//!   batcher.rs  — iteration-level scheduling loop (Orca-style):
//!     admit pending requests while the KV budget allows (prefill),
//!     then advance ALL active sessions one token per round through a
//!     single layer-major Engine::decode_batch call (continuous
//!     batching, batch-first), retiring finished sessions.
//! ```
//!
//! Every session owns its KV cache through the same [`KvCache`] backends
//! the offline evals use, so serving with `--method lexico:…` exercises
//! exactly the paper's system: compressed prefix + recency buffer + OMP
//! compression riding along with decoding.

pub mod batcher;
pub mod http;
pub mod metrics;
pub mod sched;

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::{Arc, Mutex, MutexGuard};

/// Bound on buffered-but-unread stream deltas per request: a slow reader's
/// channel fills to this depth and further deltas are dropped (clamped)
/// instead of growing an unbounded queue — the final response still carries
/// the full text, so clamping costs the client incremental display only.
pub const STREAM_BUFFER: usize = 256;

/// Poison-tolerant mutex lock: a panic on another thread while it held the
/// lock must not cascade into every later lock site panicking too (one
/// crashed request would otherwise kill the whole server). The protected
/// data is plain counters/gauges, always valid, so recovering the guard
/// from a poisoned lock is safe.
pub fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// What a request asks the batcher to do with its (optional) named session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SessionVerb {
    /// run the prompt; if `session` is named, hibernate on completion
    #[default]
    Generate,
    /// `{"cmd":"save"}`: persist the named hibernated session's snapshot
    /// and evict its pages from RAM
    Save,
    /// `{"cmd":"resume"}`: wake the named session (from RAM or from its
    /// on-disk snapshot after a restart) and continue decoding
    Resume,
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    /// cache-method spec; empty = server default
    pub method: String,
    /// candidate sessions decoded from one prefill (`best_of` fan-out):
    /// candidate `i` starts from the i-th most likely first token, all
    /// candidates fork the same prefilled cache and advance in the same
    /// decode round. 0 or 1 = a single greedy continuation.
    pub fanout: usize,
    /// session name (`[A-Za-z0-9_-]`); empty = anonymous. A named session
    /// hibernates instead of retiring — on completion or on client
    /// disconnect — so a later `resume` continues it bitwise-identically.
    pub session: String,
    /// what to do with the named session (generate / save / resume)
    pub verb: SessionVerb,
    /// tenant the request bills against (quota lookup key and metrics
    /// label); empty = the anonymous default tenant
    pub tenant: String,
    /// admission priority: higher admits first, FIFO within a class, and
    /// graceful overload sheds the lowest class first (default 0)
    pub priority: i64,
    /// milliseconds from enqueue until the job expires (0 = no deadline).
    /// Past-deadline jobs — queued or mid-flight — are retired at round
    /// top with a `deadline_expired` error, freeing their budget the same
    /// round like cancellation.
    pub deadline_ms: u64,
}

impl Request {
    /// A plain single-continuation request (the common case in tests).
    pub fn greedy(
        id: u64,
        prompt: impl Into<String>,
        max_new: usize,
        method: impl Into<String>,
    ) -> Self {
        Request {
            id,
            prompt: prompt.into(),
            max_new,
            method: method.into(),
            fanout: 1,
            session: String::new(),
            verb: SessionVerb::Generate,
            tenant: String::new(),
            priority: 0,
            deadline_ms: 0,
        }
    }
}

/// The server's reply.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// the primary (greedy, top-first-token) continuation
    pub text: String,
    /// alternate continuations, one per extra fan-out candidate
    pub alts: Vec<String>,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub kv_ratio: f64,
    /// whether the prompt was served from the shared-prefix cache
    pub prefix_hit: bool,
    pub error: Option<String>,
    /// backoff hint accompanying `overloaded`/`busy` errors: the client
    /// should wait at least this long before retrying
    pub retry_after_ms: Option<u64>,
}

impl Response {
    /// An error reply for a request that never started decoding.
    pub fn failed(id: u64, n_prompt: usize, error: String) -> Self {
        Response {
            id,
            text: String::new(),
            alts: Vec::new(),
            n_prompt,
            n_generated: 0,
            ttft_ms: 0.0,
            total_ms: 0.0,
            kv_ratio: 0.0,
            prefix_hit: false,
            error: Some(error),
            retry_after_ms: None,
        }
    }

    /// The graceful-overload shed reply: structured `overloaded` error plus
    /// a deterministic backoff hint.
    pub fn overloaded(id: u64, retry_after_ms: u64) -> Self {
        Response {
            retry_after_ms: Some(retry_after_ms),
            ..Response::failed(id, 0, "overloaded".to_string())
        }
    }
}

/// One streamed token (`"stream": true` requests): emitted by the batcher
/// the round the token is committed, relayed by the front end as one JSON
/// line `{"id", "token", "i"}` ahead of the final response line. Only the
/// primary (greedy) candidate streams; fan-out alternates arrive in the
/// final response as usual.
#[derive(Clone, Debug)]
pub struct StreamDelta {
    pub id: u64,
    /// decoded text of this token (concatenating all deltas in `i` order
    /// reproduces the final response's `text` exactly)
    pub token: String,
    /// 0-based index of the token in the generated stream
    pub i: usize,
}

/// A request plus its reply channels (what the batcher consumes).
pub struct Job {
    pub request: Request,
    pub reply: Sender<Response>,
    /// per-token delta channel for streaming requests (None = buffered).
    /// Bounded ([`STREAM_BUFFER`]): the batcher sends with `try_send`, so
    /// a slow reader clamps its own stream instead of stalling the round
    /// or buffering without limit.
    pub stream: Option<SyncSender<StreamDelta>>,
    /// set by the front end when the client vanishes (or on shutdown); the
    /// batcher retires the request's sessions the same round, returning
    /// their KV bytes to the admission budget
    pub cancel: Arc<AtomicBool>,
}

impl Job {
    /// A buffered (non-streaming) job with a fresh cancellation flag.
    pub fn new(request: Request, reply: Sender<Response>) -> Self {
        Job { request, reply, stream: None, cancel: Arc::new(AtomicBool::new(false)) }
    }

    /// Whether the front end has abandoned this job.
    pub fn cancelled(&self) -> bool {
        self.cancel.load(std::sync::atomic::Ordering::SeqCst)
    }
}
