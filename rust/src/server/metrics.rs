//! Serving metrics: latency distributions and throughput counters.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

#[derive(Default)]
pub struct Metrics {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub tokens_generated: u64,
    /// prompt tokens actually run through the engine's prefill (a
    /// prefix-cache hit adds only the suffix length — the saved work is
    /// visible as the gap to `prefill_tokens_total`)
    pub prefill_tokens: u64,
    /// prompt tokens across all admitted requests (prefix + suffix)
    pub prefill_tokens_total: u64,
    /// admissions served from the shared-prefix cache
    pub prefix_hits: u64,
    /// admissions that ran a cold prefill
    pub prefix_misses: u64,
    /// accumulated bytes that forks shared with a prototype at admission
    /// time (charged once by the budget instead of per session)
    pub shared_bytes: f64,
    /// sessions created beyond one per request (fan-out candidates)
    pub fanout_sessions: u64,
    /// requests abandoned by their client (disconnect mid-stream or while
    /// queued) and retired by the batcher before finishing
    pub cancelled: u64,
    /// queued prefills shed by graceful overload (`overloaded` reply with
    /// a `retry_after_ms` hint)
    pub shed_prefills: u64,
    /// jobs — queued or mid-flight — retired past their `deadline_ms`
    pub deadline_expired: u64,
    /// connections turned away at the front end's concurrency cap (`busy`)
    pub http_busy: u64,
    /// tokens forwarded through `"stream": true` delta channels
    pub streamed_tokens: u64,
    /// stream deltas dropped because a slow reader's bounded channel was
    /// full (the final reply still carries the full text)
    pub stream_clamped: u64,
    /// prompt chunks landed by the chunked-prefill scheduler
    pub prefill_chunks: u64,
    /// most prompt tokens any single round prefilled — bounded by
    /// `prefill_chunk × prefilling sessions`; with one admission in
    /// flight, by the chunk budget itself (the TPOT-cliff guard)
    pub max_round_prefill_tokens: u64,
    /// named sessions woken by a `resume` request
    pub resumed: u64,
    /// gauges refreshed at the end of every scheduling round
    pub active_sessions: u64,
    pub prefilling_sessions: u64,
    /// admission queue depth (gauge)
    pub queue_depth: u64,
    pub kv_used_bytes: f64,
    /// per-tenant `(name, seats, kv_bytes)` gauges, refreshed each round
    /// (anonymous-tenant traffic is not listed)
    pub tenants: Vec<(String, u64, f64)>,
    /// bytes held by realized dictionary Gram caches (gauge; nonzero only
    /// once some cache opts into the precomputed-Gram OMP tier)
    pub gram_bytes: f64,
    /// adaptive-overlay atoms folded into sessions' universal dictionaries
    /// by the online refresh pass (`--dict-refresh N`)
    pub dict_refresh_atoms: u64,
    /// named sessions parked for a later `resume` (gauge)
    pub hibernated_sessions: u64,
    /// CSR pages written to the spill store over the server's lifetime
    pub spilled_pages: u64,
    /// bytes of KV state currently evicted to disk (gauge)
    pub spill_bytes: f64,
    /// spilled pages read back because a decode round needed them
    pub faults: u64,
    pub ttft_ms: Vec<f64>,
    pub per_token_ms: Vec<f64>,
    /// wall time of each batched decode round (all active sessions advanced
    /// one token) — the serving loop's unit of work; TPOT is this divided
    /// by the round's batch size
    pub decode_round_ms: Vec<f64>,
    pub kv_ratios: Vec<f64>,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => self.tokens_generated as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn ttft(&self) -> Option<Summary> {
        (!self.ttft_ms.is_empty()).then(|| summarize(&self.ttft_ms))
    }

    pub fn tpot(&self) -> Option<Summary> {
        (!self.per_token_ms.is_empty()).then(|| summarize(&self.per_token_ms))
    }

    pub fn decode_round(&self) -> Option<Summary> {
        (!self.decode_round_ms.is_empty()).then(|| summarize(&self.decode_round_ms))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} completed={} rejected={} cancelled={} shed={} expired={} tokens={} \
             throughput={:.1} tok/s",
            self.requests,
            self.completed,
            self.rejected,
            self.cancelled,
            self.shed_prefills,
            self.deadline_expired,
            self.tokens_generated,
            self.throughput_tok_s()
        );
        s += &format!(
            "\nsessions: active={} prefilling={} queue_depth={} kv_used={:.1} KiB",
            self.active_sessions,
            self.prefilling_sessions,
            self.queue_depth,
            self.kv_used_bytes / 1024.0
        );
        if self.gram_bytes > 0.0 {
            s += &format!(" gram={:.1} KiB", self.gram_bytes / 1024.0);
        }
        if !self.tenants.is_empty() {
            s += "\ntenants :";
            for (name, seats, bytes) in &self.tenants {
                s += &format!(" {name}=seats:{seats},kv:{:.1}KiB", bytes / 1024.0);
            }
        }
        if self.http_busy > 0 {
            s += &format!("\nhttp    : {} busy rejections", self.http_busy);
        }
        if self.dict_refresh_atoms > 0 {
            s += &format!("\nrefresh : {} dictionary atoms folded", self.dict_refresh_atoms);
        }
        if self.spilled_pages + self.faults + self.hibernated_sessions + self.resumed > 0 {
            s += &format!(
                "\nspill   : hibernated={} resumed={} spilled_pages={} spill_bytes={:.1} KiB faults={}",
                self.hibernated_sessions,
                self.resumed,
                self.spilled_pages,
                self.spill_bytes / 1024.0,
                self.faults
            );
        }
        if let Some(t) = self.ttft() {
            s += &format!(
                "\nTTFT   ms: p50 {:.2} p95 {:.2} p99 {:.2} mean {:.2}",
                t.p50, t.p95, t.p99, t.mean
            );
        }
        if let Some(t) = self.tpot() {
            s += &format!(
                "\nTPOT   ms: p50 {:.2} p95 {:.2} p99 {:.2} mean {:.2}",
                t.p50, t.p95, t.p99, t.mean
            );
        }
        if let Some(t) = self.decode_round() {
            s += &format!(
                "\nround  ms: p50 {:.2} p95 {:.2} p99 {:.2} mean {:.2} (n={})",
                t.p50, t.p95, t.p99, t.mean, t.n
            );
        }
        if !self.kv_ratios.is_empty() {
            let mean: f64 = self.kv_ratios.iter().sum::<f64>() / self.kv_ratios.len() as f64;
            s += &format!("\nKV size : {:.1}% of full cache (mean)", 100.0 * mean);
        }
        if self.prefix_hits + self.prefix_misses > 0 {
            s += &format!(
                "\nprefix  : {} hits / {} misses, prefilled {}/{} prompt tokens, {:.1} KiB shared",
                self.prefix_hits,
                self.prefix_misses,
                self.prefill_tokens,
                self.prefill_tokens_total,
                self.shared_bytes / 1024.0
            );
        }
        if self.prefill_chunks > 0 {
            s += &format!(
                "\nchunks  : {} prefill chunks, max {} prompt tokens in one round",
                self.prefill_chunks, self.max_round_prefill_tokens
            );
        }
        if self.streamed_tokens + self.stream_clamped > 0 {
            s += &format!("\nstream  : {} tokens streamed", self.streamed_tokens);
            if self.stream_clamped > 0 {
                s += &format!(", {} clamped", self.stream_clamped);
            }
        }
        if self.fanout_sessions > 0 {
            s += &format!("\nfanout  : {} extra candidate sessions", self.fanout_sessions);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let mut m = Metrics::new();
        m.requests = 3;
        m.completed = 2;
        m.tokens_generated = 20;
        m.ttft_ms.extend([1.0, 3.0]);
        m.per_token_ms.extend([0.5, 0.7, 0.6]);
        m.decode_round_ms.extend([1.5, 2.1, 1.8]);
        m.kv_ratios.push(0.25);
        m.prefix_hits = 1;
        m.prefix_misses = 2;
        m.prefill_tokens = 30;
        m.prefill_tokens_total = 50;
        m.shared_bytes = 2048.0;
        m.fanout_sessions = 3;
        m.cancelled = 1;
        m.shed_prefills = 4;
        m.deadline_expired = 2;
        m.http_busy = 3;
        m.streamed_tokens = 7;
        m.stream_clamped = 5;
        m.prefill_chunks = 5;
        m.max_round_prefill_tokens = 256;
        m.active_sessions = 4;
        m.prefilling_sessions = 1;
        m.queue_depth = 6;
        m.kv_used_bytes = 4096.0;
        m.tenants = vec![("pro".into(), 2, 2048.0), ("free".into(), 1, 1024.0)];
        m.gram_bytes = 65536.0;
        m.dict_refresh_atoms = 5;
        m.hibernated_sessions = 2;
        m.resumed = 1;
        m.spilled_pages = 6;
        m.spill_bytes = 3072.0;
        m.faults = 4;
        let r = m.report();
        assert!(r.contains("completed=2"));
        assert!(r.contains("cancelled=1 shed=4 expired=2"), "{r}");
        assert!(
            r.contains("active=4 prefilling=1 queue_depth=6 kv_used=4.0 KiB gram=64.0 KiB"),
            "{r}"
        );
        assert!(r.contains("tenants : pro=seats:2,kv:2.0KiB free=seats:1,kv:1.0KiB"), "{r}");
        assert!(r.contains("3 busy rejections"), "{r}");
        assert!(r.contains("5 dictionary atoms folded"), "{r}");
        assert!(r.contains("7 tokens streamed, 5 clamped"), "{r}");
        assert!(
            r.contains("hibernated=2 resumed=1 spilled_pages=6 spill_bytes=3.0 KiB faults=4"),
            "{r}"
        );
        assert!(r.contains("5 prefill chunks, max 256"), "{r}");
        assert!(r.contains("7 tokens streamed"), "{r}");
        assert!(r.contains("TTFT"));
        assert!(r.contains("p99"), "{r}");
        assert!(r.contains("round  ms"), "{r}");
        assert!(m.decode_round().is_some());
        assert!(r.contains("1 hits / 2 misses"), "{r}");
        assert!(r.contains("30/50 prompt tokens"), "{r}");
        assert!(r.contains("2.0 KiB shared"), "{r}");
        assert!(r.contains("3 extra candidate"), "{r}");
        assert!(m.throughput_tok_s() > 0.0);
    }
}
