//! Serving metrics: latency distributions and throughput counters.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

#[derive(Default)]
pub struct Metrics {
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub tokens_generated: u64,
    pub ttft_ms: Vec<f64>,
    pub per_token_ms: Vec<f64>,
    pub kv_ratios: Vec<f64>,
    started: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { started: Some(Instant::now()), ..Default::default() }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        match self.started {
            Some(t0) => self.tokens_generated as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn ttft(&self) -> Option<Summary> {
        (!self.ttft_ms.is_empty()).then(|| summarize(&self.ttft_ms))
    }

    pub fn tpot(&self) -> Option<Summary> {
        (!self.per_token_ms.is_empty()).then(|| summarize(&self.per_token_ms))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} completed={} rejected={} tokens={} throughput={:.1} tok/s",
            self.requests,
            self.completed,
            self.rejected,
            self.tokens_generated,
            self.throughput_tok_s()
        );
        if let Some(t) = self.ttft() {
            s += &format!("\nTTFT   ms: p50 {:.2} p95 {:.2} mean {:.2}", t.p50, t.p95, t.mean);
        }
        if let Some(t) = self.tpot() {
            s += &format!("\nTPOT   ms: p50 {:.2} p95 {:.2} mean {:.2}", t.p50, t.p95, t.mean);
        }
        if !self.kv_ratios.is_empty() {
            let mean: f64 = self.kv_ratios.iter().sum::<f64>() / self.kv_ratios.len() as f64;
            s += &format!("\nKV size : {:.1}% of full cache (mean)", 100.0 * mean);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_aggregate() {
        let mut m = Metrics::new();
        m.requests = 3;
        m.completed = 2;
        m.tokens_generated = 20;
        m.ttft_ms.extend([1.0, 3.0]);
        m.per_token_ms.extend([0.5, 0.7, 0.6]);
        m.kv_ratios.push(0.25);
        let r = m.report();
        assert!(r.contains("completed=2"));
        assert!(r.contains("TTFT"));
        assert!(m.throughput_tok_s() > 0.0);
    }
}
