//! Network front end: newline-delimited JSON over TCP.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "max_new": 16, "method": "lexico:s=8,nb=32"}
//!   ← {"id": 1, "text": "...", "ttft_ms": ..., "total_ms": ...,
//!      "kv_ratio": ..., "n_generated": ...}
//! Special request {"cmd": "metrics"} returns the aggregate report;
//! {"cmd": "shutdown"} stops the listener.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::metrics::Metrics;
use super::{Job, Request, Response};
use crate::util::json::{self, Json};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn response_json(r: &Response) -> String {
    let mut fields = vec![
        ("id", json::num(r.id as f64)),
        ("text", json::s(&r.text)),
        ("n_prompt", json::num(r.n_prompt as f64)),
        ("n_generated", json::num(r.n_generated as f64)),
        ("ttft_ms", json::num(r.ttft_ms)),
        ("total_ms", json::num(r.total_ms)),
        ("kv_ratio", json::num(r.kv_ratio)),
        ("prefix_hit", Json::Bool(r.prefix_hit)),
    ];
    if !r.alts.is_empty() {
        fields.push(("alts", json::arr(r.alts.iter().map(|a| json::s(a)).collect())));
    }
    if let Some(e) = &r.error {
        fields.push(("error", json::s(e)));
    }
    json::obj(fields).to_string()
}

fn handle_conn(
    stream: TcpStream,
    jobs: Sender<Job>,
    metrics: Arc<Mutex<Metrics>>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match Json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                writeln!(writer, "{}", json::obj(vec![("error", json::s(&e))]).to_string())?;
                continue;
            }
        };
        match parsed.get("cmd").as_str() {
            Some("metrics") => {
                let report = metrics.lock().unwrap().report();
                writeln!(writer, "{}", json::obj(vec![("metrics", json::s(&report))]).to_string())?;
                continue;
            }
            Some("shutdown") => {
                shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{}", json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
                return Ok(());
            }
            _ => {}
        }
        let fanout = parsed
            .get("fanout")
            .as_usize()
            .or_else(|| parsed.get("best_of").as_usize())
            .unwrap_or(1);
        let request = Request {
            id: NEXT_ID.fetch_add(1, Ordering::SeqCst),
            prompt: parsed.get("prompt").as_str().unwrap_or("").to_string(),
            max_new: parsed.get("max_new").as_usize().unwrap_or(16),
            method: parsed.get("method").as_str().unwrap_or("").to_string(),
            fanout,
        };
        let (rtx, rrx) = channel();
        if jobs.send(Job { request, reply: rtx }).is_err() {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("server shutting down"))]).to_string()
            )?;
            return Ok(());
        }
        match rrx.recv() {
            Ok(resp) => writeln!(writer, "{}", response_json(&resp))?,
            Err(_) => writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("batcher dropped request"))]).to_string()
            )?,
        }
    }
    Ok(())
}

/// Serve until a `shutdown` command arrives. Returns the bound address
/// through `on_bound` (useful for tests binding port 0).
pub fn serve(
    addr: &str,
    jobs: Sender<Job>,
    metrics: Arc<Mutex<Metrics>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let shutdown = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                let jobs = jobs.clone();
                let metrics = metrics.clone();
                let sd = shutdown.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, jobs, metrics, sd);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_weights;
    use crate::model::Engine;
    use crate::server::batcher::{self, BatcherConfig};
    use std::io::{BufRead, BufReader, Write};

    fn spawn_server() -> std::net::SocketAddr {
        let engine = Arc::new(Engine::new(tiny_weights(17)));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (jtx, jrx) = channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || {
            batcher::run(
                engine,
                None,
                BatcherConfig { default_method: "full".into(), ..Default::default() },
                jrx,
                m2,
            )
        });
        let (atx, arx) = channel();
        std::thread::spawn(move || {
            serve("127.0.0.1:0", jtx, metrics, move |a| {
                let _ = atx.send(a);
            })
        });
        arx.recv_timeout(std::time::Duration::from_secs(10)).unwrap()
    }

    #[test]
    fn end_to_end_tcp_roundtrip() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"prompt": "2,1>", "max_new": 4}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        assert!(v.get("n_generated").as_usize().unwrap() >= 1);
        // metrics + shutdown
        writeln!(conn, r#"{{"cmd": "metrics"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("completed"));
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn oov_prompt_round_trips_as_error_and_server_survives() {
        // regression for the tasks::char_id panic: an out-of-vocabulary
        // character in a request must come back as a JSON error reply on
        // the same connection, and the batcher must keep serving.
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "{{\"prompt\": \"caf\u{e9}\", \"max_new\": 3}}").unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        let err = v.get("error").as_str().expect("OOV must reply an error");
        assert!(err.contains("unsupported character"), "{line}");
        // the same connection and batcher still serve valid requests
        writeln!(conn, r#"{{"prompt": "1+2=", "max_new": 3}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn emoji_prompt_survives_json_surrogate_pairs_end_to_end() {
        // Regression for the BMP-only \u parser: a prompt carrying U+1F600
        // as a surrogate pair must reach the batcher as one code point. The
        // tiny vocab rejects it, and the error reply must quote the
        // *intact* emoji — the old parser mangled the pair into two
        // replacement chars before the batcher ever saw it.
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, r#"{{"prompt": "1+\uD83D\uDE00=", "max_new": 3}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        let err = v.get("error").as_str().expect("OOV emoji must reply an error");
        assert!(err.contains("unsupported character"), "{line}");
        assert!(err.contains('\u{1F600}'), "emoji was mangled in transit: {err}");
        assert!(!err.contains('\u{FFFD}'), "replacement char leaked: {err}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn fanout_round_trip_returns_alternates() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, r#"{{"prompt": "7,3,5>", "max_new": 4, "best_of": 3}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        let alts = v.get("alts").as_arr().expect("fanout reply carries alts");
        assert_eq!(alts.len(), 2, "{line}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }
}
