//! Network front end: newline-delimited JSON over TCP.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "max_new": 16, "method": "lexico:s=8,nb=32"}
//!   ← {"id": 1, "text": "...", "ttft_ms": ..., "total_ms": ...,
//!      "kv_ratio": ..., "n_generated": ...}
//!
//! With `"stream": true` the reply is one `{"id", "token", "i"}` line per
//! generated token (primary candidate, in order, emitted the round each
//! token is produced), terminated by the usual final-response line. If the
//! client disconnects mid-stream the handler flags the job cancelled and
//! the batcher retires its sessions the same round, returning their KV
//! bytes to the admission budget.
//!
//! Special request {"cmd": "metrics"} returns the aggregate report;
//! {"cmd": "shutdown"} stops the listener. Reads poll with a short
//! timeout (accumulating partial lines), so shutdown unblocks every
//! handler — including idle connections and handlers waiting on in-flight
//! decodes, whose jobs are cancelled — instead of hanging serve()'s join
//! on a blocking read.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::metrics::Metrics;
use super::{lock_tolerant, Job, Request, Response, SessionVerb, StreamDelta, STREAM_BUFFER};
use crate::util::json::{self, Json};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// How long reads and reply waits block before re-checking the shutdown
/// flag — bounds how long a shutdown can go unnoticed by any handler.
const POLL: Duration = Duration::from_millis(25);

/// Hard bound on one request line: a client that streams bytes without ever
/// sending a newline gets a structured error and its connection closed,
/// instead of growing the assembly buffer without limit.
const MAX_LINE: usize = 256 * 1024;

/// Front-end limits (the listener side of graceful overload).
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// concurrent connection cap: accepts beyond it get a structured
    /// `busy` reply with a retry hint and are closed, instead of an
    /// unbounded thread per connection (0 = unlimited)
    pub max_conns: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { max_conns: 256 }
    }
}

/// Decrements the live-connection gauge when a handler exits — by any
/// path, including a panic, so a crashed handler can never leak a slot.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn response_json(r: &Response) -> String {
    let mut fields = vec![
        ("id", json::num(r.id as f64)),
        ("text", json::s(&r.text)),
        ("n_prompt", json::num(r.n_prompt as f64)),
        ("n_generated", json::num(r.n_generated as f64)),
        ("ttft_ms", json::num(r.ttft_ms)),
        ("total_ms", json::num(r.total_ms)),
        ("kv_ratio", json::num(r.kv_ratio)),
        ("prefix_hit", Json::Bool(r.prefix_hit)),
    ];
    if !r.alts.is_empty() {
        fields.push(("alts", json::arr(r.alts.iter().map(|a| json::s(a)).collect())));
    }
    if let Some(e) = &r.error {
        fields.push(("error", json::s(e)));
    }
    if let Some(ms) = r.retry_after_ms {
        fields.push(("retry_after_ms", json::num(ms as f64)));
    }
    json::obj(fields).to_string()
}

fn delta_json(d: &StreamDelta) -> String {
    json::obj(vec![
        ("id", json::num(d.id as f64)),
        ("token", json::s(&d.token)),
        ("i", json::num(d.i as f64)),
    ])
    .to_string()
}

fn handle_conn(
    stream: TcpStream,
    jobs: Sender<Job>,
    metrics: Arc<Mutex<Metrics>>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(Some(POLL))?;
    let mut reader = stream.try_clone()?;
    let mut writer = stream;
    // hand-rolled line assembly: a request may arrive split across reads
    // (partial lines accumulate) or several lines may arrive in one read
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        while let Some(nl) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=nl).collect();
            let line = String::from_utf8_lossy(&line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if !handle_line(&line, &mut writer, &jobs, &metrics, &shutdown)? {
                return Ok(());
            }
        }
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read(&mut chunk) {
            Ok(0) => return Ok(()), // client closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                // a partial line may grow only to the bound; complete lines
                // drain at the top of the loop before the next read
                if buf.len() > MAX_LINE && !buf.contains(&b'\n') {
                    lock_tolerant(&metrics).rejected += 1;
                    let _ = writeln!(
                        writer,
                        "{}",
                        json::obj(vec![("error", json::s("request line too long"))]).to_string()
                    );
                    return Ok(());
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
}

/// Process one request line. Returns `Ok(false)` when the connection
/// should close (shutdown acknowledged, or the server is draining).
fn handle_line(
    line: &str,
    writer: &mut TcpStream,
    jobs: &Sender<Job>,
    metrics: &Arc<Mutex<Metrics>>,
    shutdown: &Arc<AtomicBool>,
) -> Result<bool> {
    let parsed = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            writeln!(writer, "{}", json::obj(vec![("error", json::s(&e))]).to_string())?;
            return Ok(true);
        }
    };
    let mut verb = SessionVerb::Generate;
    match parsed.get("cmd").as_str() {
        Some("metrics") => {
            let report = lock_tolerant(metrics).report();
            writeln!(writer, "{}", json::obj(vec![("metrics", json::s(&report))]).to_string())?;
            return Ok(true);
        }
        Some("shutdown") => {
            shutdown.store(true, Ordering::SeqCst);
            writeln!(writer, "{}", json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
            return Ok(false);
        }
        // session verbs ride the normal request path: they queue a Job and
        // reply with a Response line (error field set on failure)
        Some("save") => verb = SessionVerb::Save,
        Some("resume") => verb = SessionVerb::Resume,
        _ => {}
    }
    let fanout = parsed
        .get("fanout")
        .as_usize()
        .or_else(|| parsed.get("best_of").as_usize())
        .unwrap_or(1);
    let request = Request {
        id: NEXT_ID.fetch_add(1, Ordering::SeqCst),
        prompt: parsed.get("prompt").as_str().unwrap_or("").to_string(),
        max_new: parsed.get("max_new").as_usize().unwrap_or(16),
        method: parsed.get("method").as_str().unwrap_or("").to_string(),
        fanout,
        session: parsed.get("session").as_str().unwrap_or("").to_string(),
        verb,
        tenant: parsed.get("tenant").as_str().unwrap_or("").to_string(),
        priority: parsed.get("priority").as_i64().unwrap_or(0),
        deadline_ms: parsed.get("deadline_ms").as_u64().unwrap_or(0),
    };
    let (rtx, rrx) = channel();
    let mut job = Job::new(request, rtx);
    let cancel = job.cancel.clone();
    let deltas = parsed.get("stream").as_bool().unwrap_or(false).then(|| {
        let (stx, srx) = sync_channel(STREAM_BUFFER);
        job.stream = Some(stx);
        srx
    });
    if jobs.send(job).is_err() {
        writeln!(
            writer,
            "{}",
            json::obj(vec![("error", json::s("server shutting down"))]).to_string()
        )?;
        return Ok(false);
    }
    if let Some(srx) = deltas {
        // relay token lines until the batcher finishes the request and
        // drops the sender (the final response is then waiting in `rrx`)
        loop {
            match srx.recv_timeout(POLL) {
                Ok(d) => {
                    if writeln!(writer, "{}", delta_json(&d)).is_err() {
                        // client gone mid-stream: cancel so the batcher
                        // retires the sessions and frees their KV bytes
                        // in its next round
                        cancel.store(true, Ordering::SeqCst);
                        break;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if shutdown.load(Ordering::SeqCst) {
                        cancel.store(true, Ordering::SeqCst);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    // final response (the batcher always replies, including for cancelled
    // jobs); keep polling so a shutdown cancels in-flight decodes instead
    // of waiting out their full generation
    loop {
        match rrx.recv_timeout(POLL) {
            Ok(resp) => {
                let _ = writeln!(writer, "{}", response_json(&resp));
                return Ok(true);
            }
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    cancel.store(true, Ordering::SeqCst);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    json::obj(vec![("error", json::s("batcher dropped request"))]).to_string()
                );
                return Ok(true);
            }
        }
    }
}

/// Serve until a `shutdown` command arrives, with default limits. Returns
/// the bound address through `on_bound` (useful for tests binding port 0).
pub fn serve(
    addr: &str,
    jobs: Sender<Job>,
    metrics: Arc<Mutex<Metrics>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_opts(addr, ServeOpts::default(), jobs, metrics, on_bound)
}

/// [`serve`] with explicit front-end limits.
pub fn serve_opts(
    addr: &str,
    opts: ServeOpts,
    jobs: Sender<Job>,
    metrics: Arc<Mutex<Metrics>>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let shutdown = Arc::new(AtomicBool::new(false));
    let live = Arc::new(AtomicUsize::new(0));
    let cap = if opts.max_conns == 0 { usize::MAX } else { opts.max_conns };
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                if live.load(Ordering::SeqCst) >= cap {
                    // at capacity: a structured busy reply and close,
                    // instead of an unbounded thread per connection
                    lock_tolerant(&metrics).http_busy += 1;
                    let mut stream = stream;
                    let _ = writeln!(
                        stream,
                        "{}",
                        json::obj(vec![
                            ("error", json::s("busy")),
                            ("retry_after_ms", json::num(100.0)),
                        ])
                        .to_string()
                    );
                    continue;
                }
                live.fetch_add(1, Ordering::SeqCst);
                let guard = ConnGuard(live.clone());
                let jobs = jobs.clone();
                let metrics = metrics.clone();
                let sd = shutdown.clone();
                handles.retain(|h| !h.is_finished());
                handles.push(std::thread::spawn(move || {
                    let _guard = guard;
                    let _ = handle_conn(stream, jobs, metrics, sd);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_weights;
    use crate::model::Engine;
    use crate::server::batcher::{self, BatcherConfig};
    use std::io::{BufRead, BufReader, Write};

    fn spawn_server() -> std::net::SocketAddr {
        let engine = Arc::new(Engine::new(tiny_weights(17)));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (jtx, jrx) = channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || {
            batcher::run(
                engine,
                None,
                BatcherConfig { default_method: "full".into(), ..Default::default() },
                jrx,
                m2,
            )
        });
        let (atx, arx) = channel();
        std::thread::spawn(move || {
            serve("127.0.0.1:0", jtx, metrics, move |a| {
                let _ = atx.send(a);
            })
        });
        arx.recv_timeout(std::time::Duration::from_secs(10)).unwrap()
    }

    #[test]
    fn end_to_end_tcp_roundtrip() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"prompt": "2,1>", "max_new": 4}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        assert!(v.get("n_generated").as_usize().unwrap() >= 1);
        // metrics + shutdown
        writeln!(conn, r#"{{"cmd": "metrics"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("completed"));
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn oov_prompt_round_trips_as_error_and_server_survives() {
        // regression for the tasks::char_id panic: an out-of-vocabulary
        // character in a request must come back as a JSON error reply on
        // the same connection, and the batcher must keep serving.
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "{{\"prompt\": \"caf\u{e9}\", \"max_new\": 3}}").unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        let err = v.get("error").as_str().expect("OOV must reply an error");
        assert!(err.contains("unsupported character"), "{line}");
        // the same connection and batcher still serve valid requests
        writeln!(conn, r#"{{"prompt": "1+2=", "max_new": 3}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn emoji_prompt_survives_json_surrogate_pairs_end_to_end() {
        // Regression for the BMP-only \u parser: a prompt carrying U+1F600
        // as a surrogate pair must reach the batcher as one code point. The
        // tiny vocab rejects it, and the error reply must quote the
        // *intact* emoji — the old parser mangled the pair into two
        // replacement chars before the batcher ever saw it.
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, r#"{{"prompt": "1+\uD83D\uDE00=", "max_new": 3}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        let err = v.get("error").as_str().expect("OOV emoji must reply an error");
        assert!(err.contains("unsupported character"), "{line}");
        assert!(err.contains('\u{1F600}'), "emoji was mangled in transit: {err}");
        assert!(!err.contains('\u{FFFD}'), "replacement char leaked: {err}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn fanout_round_trip_returns_alternates() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, r#"{{"prompt": "7,3,5>", "max_new": 4, "best_of": 3}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        let alts = v.get("alts").as_arr().expect("fanout reply carries alts");
        assert_eq!(alts.len(), 2, "{line}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn streamed_tokens_concatenate_to_the_buffered_text() {
        let addr = spawn_server();
        // buffered reference
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, r#"{{"prompt": "2,1>", "max_new": 6}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        let buffered = Json::parse(&line).unwrap();
        assert!(buffered.get("error").as_str().is_none(), "{line}");
        let text = buffered.get("text").as_str().unwrap().to_string();
        let n_generated = buffered.get("n_generated").as_usize().unwrap();

        // streamed: one delta line per token, then the final response line
        writeln!(conn, r#"{{"prompt": "2,1>", "max_new": 6, "stream": true}}"#).unwrap();
        let mut tokens = Vec::new();
        let finale = loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let v = Json::parse(&line).unwrap();
            if v.get("token").as_str().is_some() {
                assert_eq!(
                    v.get("i").as_usize().unwrap(),
                    tokens.len(),
                    "deltas must arrive in order: {line}"
                );
                tokens.push(v.get("token").as_str().unwrap().to_string());
            } else {
                break v;
            }
        };
        assert!(finale.get("error").as_str().is_none());
        assert_eq!(tokens.len(), n_generated, "one delta per generated token");
        let concat: String = tokens.concat();
        assert_eq!(concat, text, "streamed tokens must reproduce the buffered text");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn partial_line_requests_are_assembled_across_reads() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // a request split into three writes, with pauses longer than the
        // server's read timeout — the handler must assemble the line
        conn.write_all(br#"{"prompt": "#).unwrap();
        conn.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        conn.write_all(br#""1+2=", "#).unwrap();
        conn.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(60));
        conn.write_all(b"\"max_new\": 3}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        // and two requests in a single write both get replies
        conn.write_all(b"{\"prompt\": \"1+2=\", \"max_new\": 2}\n{\"cmd\": \"metrics\"}\n")
            .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("n_generated").as_usize().is_some(), "{line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("completed"), "{line}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn disconnect_mid_stream_cancels_the_session_and_frees_its_budget() {
        let addr = spawn_server();
        // pick a prompt whose greedy stream runs long (streams are
        // deterministic under the fixed test weights; the probe just
        // avoids hard-coding which prompt that is)
        let probe = |prompt: &str| -> usize {
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            writeln!(conn, "{{\"prompt\": \"{prompt}\", \"max_new\": 100}}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(&line).unwrap().get("n_generated").as_usize().unwrap_or(0)
        };
        let prompt = ["2,7,4>", "1+2=", "k01=v11;k01?", "9,9,1>", "abc#"]
            .into_iter()
            .find(|p| probe(p) >= 40)
            .expect("no probe prompt decodes ≥40 tokens under the test weights");

        // the idle baseline (prefix-cache residency only) the budget must
        // return to once the cancelled session's bytes are freed
        let fetch_metrics = || -> String {
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            writeln!(conn, r#"{{"cmd": "metrics"}}"#).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        };
        let kv_used = |report: &str| -> String {
            report
                .split("kv_used=")
                .nth(1)
                .map(|s| s.split(' ').next().unwrap_or("").to_string())
                .unwrap_or_default()
        };
        let baseline = kv_used(&fetch_metrics());

        // stream it, read one delta, vanish
        {
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            writeln!(conn, "{{\"prompt\": \"{prompt}\", \"max_new\": 100, \"stream\": true}}")
                .unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(Json::parse(&line).unwrap().get("token").as_str().is_some(), "{line}");
            // conn drops here — the server's next delta write fails
        }

        // the batcher must notice within a round and return the bytes
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let line = fetch_metrics();
            if line.contains("cancelled=1")
                && line.contains("active=0")
                && kv_used(&line) == baseline
            {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "cancelled session never freed its budget: {line}"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn session_save_resume_round_trip_over_tcp() {
        use crate::dict::{Dictionary, DictionarySet};
        let engine = Arc::new(Engine::new(tiny_weights(17)));
        let shape = engine.shape();
        let dicts = Some(Arc::new(DictionarySet {
            keys: (0..shape.n_layers)
                .map(|i| Dictionary::random(shape.head_dim, 64, 500 + i as u64))
                .collect(),
            values: (0..shape.n_layers)
                .map(|i| Dictionary::random(shape.head_dim, 64, 700 + i as u64))
                .collect(),
        }));
        let dir = std::env::temp_dir().join(format!("lexico_http_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (jtx, jrx) = channel();
        let m2 = metrics.clone();
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=8".into(),
            spill_dir: Some(dir),
            ..Default::default()
        };
        std::thread::spawn(move || batcher::run(engine, dicts, cfg, jrx, m2));
        let (atx, arx) = channel();
        std::thread::spawn(move || {
            serve("127.0.0.1:0", jtx, metrics, move |a| {
                let _ = atx.send(a);
            })
        });
        let addr = arx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();

        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        // a named session generates a couple of tokens, then parks
        writeln!(
            conn,
            r#"{{"prompt": "k01=v11;k02=v12;k03=v13;k04=v14;k05=v15;k01?", "max_new": 2, "session": "tcp-chat"}}"#
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        let text_a = v.get("text").as_str().unwrap().to_string();
        // save: evict its pages to disk
        writeln!(conn, r#"{{"cmd": "save", "session": "tcp-chat"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("error").as_str().is_none(), "{line}");
        // resume: the stream continues from where it parked
        writeln!(conn, r#"{{"cmd": "resume", "session": "tcp-chat", "max_new": 6}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        let text_b = v.get("text").as_str().unwrap().to_string();
        assert!(
            text_b.starts_with(&text_a),
            "resume must extend the saved stream: {text_a:?} -> {text_b:?}"
        );
        // resuming a bogus session errors without killing the server
        writeln!(conn, r#"{{"cmd": "resume", "session": "ghost", "max_new": 2}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let err = Json::parse(&line).unwrap();
        assert!(err.get("error").as_str().unwrap().contains("unknown session"), "{line}");
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn shutdown_returns_promptly_despite_idle_and_busy_connections() {
        // spawn the server by hand so the test can observe serve() return
        let engine = Arc::new(Engine::new(tiny_weights(17)));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (jtx, jrx) = channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || {
            batcher::run(
                engine,
                None,
                BatcherConfig { default_method: "full".into(), ..Default::default() },
                jrx,
                m2,
            )
        });
        let (atx, arx) = channel();
        let (dtx, drx) = channel();
        std::thread::spawn(move || {
            let r = serve("127.0.0.1:0", jtx, metrics, move |a| {
                let _ = atx.send(a);
            });
            let _ = dtx.send(r.is_ok());
        });
        let addr = arx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();

        // an idle connection that never sends a byte (the old blocking
        // reader made serve()'s join hang on exactly this)
        let _idle = std::net::TcpStream::connect(addr).unwrap();
        // a session mid-decode whose handler is blocked awaiting the reply
        let mut busy = std::net::TcpStream::connect(addr).unwrap();
        writeln!(busy, r#"{{"prompt": "2,7,4>", "max_new": 100}}"#).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));

        let mut sd = std::net::TcpStream::connect(addr).unwrap();
        let mut sd_reader = BufReader::new(sd.try_clone().unwrap());
        writeln!(sd, r#"{{"cmd": "shutdown"}}"#).unwrap();
        let mut ack = String::new();
        sd_reader.read_line(&mut ack).unwrap();
        assert!(ack.contains("ok"), "{ack}");
        let ok = drx
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("serve() hung after shutdown (idle/busy connections not unblocked)");
        assert!(ok, "serve() returned an error");
    }

    #[test]
    fn tenant_priority_deadline_fields_round_trip() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(
            conn,
            r#"{{"prompt": "1+2=", "max_new": 3, "tenant": "pro", "priority": -2, "deadline_ms": 60000}}"#
        )
        .unwrap();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().is_none(), "{line}");
        assert!(v.get("n_generated").as_usize().unwrap() >= 1);
        writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn oversized_request_line_gets_a_structured_error_and_close() {
        let addr = spawn_server();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // just past the line bound without a newline, so the server stops
        // reading with only a few KiB left in the socket buffers (a much
        // larger blast could deadlock the test's blocking write_all)
        let junk = vec![b'a'; 260 * 1024];
        conn.write_all(&junk).unwrap();
        conn.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert!(v.get("error").as_str().unwrap().contains("too long"), "{line}");
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "server must close the conn");
        // the listener itself keeps serving
        let mut conn2 = std::net::TcpStream::connect(addr).unwrap();
        let mut reader2 = BufReader::new(conn2.try_clone().unwrap());
        writeln!(conn2, r#"{{"prompt": "1+2=", "max_new": 2}}"#).unwrap();
        line.clear();
        reader2.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("error").as_str().is_none(), "{line}");
        writeln!(conn2, r#"{{"cmd": "shutdown"}}"#).unwrap();
    }

    #[test]
    fn connection_cap_replies_busy_with_a_retry_hint() {
        // spawn by hand with max_conns = 1
        let engine = Arc::new(Engine::new(tiny_weights(17)));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (jtx, jrx) = channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || {
            batcher::run(
                engine,
                None,
                BatcherConfig { default_method: "full".into(), ..Default::default() },
                jrx,
                m2,
            )
        });
        let (atx, arx) = channel();
        let m3 = metrics.clone();
        std::thread::spawn(move || {
            serve_opts("127.0.0.1:0", ServeOpts { max_conns: 1 }, jtx, m3, move |a| {
                let _ = atx.send(a);
            })
        });
        let addr = arx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();

        // the one allowed connection parks idle, holding the slot
        let held = std::net::TcpStream::connect(addr).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(100));
        // the next connection is turned away with a structured busy reply
        let conn2 = std::net::TcpStream::connect(addr).unwrap();
        let mut reader2 = BufReader::new(conn2);
        let mut line = String::new();
        reader2.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("error").as_str(), Some("busy"), "{line}");
        assert!(v.get("retry_after_ms").as_usize().unwrap() > 0, "{line}");
        assert_eq!(lock_tolerant(&metrics).http_busy, 1);

        // freeing the held slot lets a new connection in (poll: the slot
        // frees when the handler notices the closed socket)
        drop(held);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let conn3 = std::net::TcpStream::connect(addr).unwrap();
            let mut reader3 = BufReader::new(conn3.try_clone().unwrap());
            let mut conn3w = conn3;
            writeln!(conn3w, r#"{{"cmd": "metrics"}}"#).unwrap();
            line.clear();
            reader3.read_line(&mut line).unwrap();
            if line.contains("completed") {
                writeln!(conn3w, r#"{{"cmd": "shutdown"}}"#).unwrap();
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "released slot never became available: {line}"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }
}
