//! Iteration-level (continuous) batching with KV-budget admission control,
//! a shared-prefix prefill cache, and copy-on-write session fan-out.
//!
//! The scheduling loop mirrors Orca/vLLM iteration-level scheduling: each
//! round first *admits* pending requests while the KV-memory budget allows
//! (seating them in [`Phase::Prefilling`] — admission itself does zero
//! transformer work), then advances every prefilling session by one
//! budgeted prompt chunk ([`Engine::prefill_chunk`], `--prefill-chunk`
//! tokens per round), then advances every decoding session by exactly one
//! token through a single layer-major [`Engine::decode_batch`] call
//! (weights stream once per layer per round, not once per session),
//! retiring sessions that emit the stop token or exhaust their budget.
//! Chunked prefill is what keeps one 4k-token admission from stalling
//! every active session's decode cadence — the TPOT cliff — while staying
//! bitwise identical to a monolithic prefill (DESIGN.md §9). Lexico's
//! smaller per-token KV footprint directly raises the number of concurrent
//! sessions the budget admits — the paper's memory-bound serving argument —
//! and the batched round is what turns those extra sessions into
//! throughput.
//!
//! **Streaming + cancellation.** A `"stream": true` request gets each
//! committed token of its primary candidate forwarded through the job's
//! [`StreamDelta`] channel the round it is produced. When the front end
//! reports the client gone (the job's `cancel` flag), the request's
//! sessions are retired at the start of the next decode round — before any
//! further work — returning their KV bytes to the admission budget that
//! same round.
//!
//! **Shared-prefix cache.** Real traffic overwhelmingly shares a
//! system-prompt prefix. Admission hashes the request's prompt ids
//! (rolling FNV-1a, one hash per prefix length) and probes the cache for
//! the longest entry matching both hash and method. On a hit the entry's
//! prototype cache is [`KvCache::fork`]ed — for Lexico the compressed
//! prefix pages are shared behind `Arc`s, copy-on-write — the session is
//! seated with a copy of the entry's dense prefix state, and only the
//! prompt *suffix* runs through [`Engine::prefill_chunk`], whose chunks
//! attend in full precision over those stored dense K/V rows (an exact
//! hit skips the row copy and runs zero chunks). Because the stored rows
//! are exactly what a cold prefill computes, a hit is bitwise identical
//! to a cold full-prompt prefill for every backend whose
//! [`crate::cache::CacheCaps::split_prefill_exact`] holds (the only ones the cache
//! serves), while the prefix costs zero transformer work and zero OMP
//! recompression. The budget charges each entry's resident bytes once and
//! each forked session only its private bytes
//! (`mem_bytes − shared_prefix_bytes`); a request that would duplicate an
//! in-flight cacheable prefill waits in the FIFO and resumes as a hit.
//!
//! **Fan-out.** A request with `fanout = n` decodes n candidate
//! continuations from ONE prefill: candidate i starts from the i-th most
//! likely first token, candidates 1.. fork candidate 0's freshly prefilled
//! cache (sharing its compressed prefix), and all n advance in the same
//! `decode_batch` round. The reply carries the primary continuation plus
//! the alternates.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::sched::{self, BatchGovernor, ChunkGovernor, Clock, SloTargets, TenantQuotas};
use super::{lock_tolerant, Job, Response, SessionVerb, StreamDelta};
use crate::cache::factory::{build_cache, CacheContext};
use crate::cache::KvCache;
use crate::dict::DictionarySet;
use crate::exec::ExecPool;
use crate::model::{Engine, PrefixState};
use crate::store::{wire, SpillStore};
use crate::tasks;
use crate::tensor::argmax;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// default cache method for requests that don't specify one
    pub default_method: String,
    /// total KV budget across sessions, bytes (FP16-equivalent accounting)
    pub kv_budget_bytes: f64,
    /// hard cap on concurrently decoding sessions
    pub max_sessions: usize,
    /// shared-prefix cache capacity in entries (0 disables the cache)
    pub prefix_entries: usize,
    /// minimum prompt (or suffix) tokens before a prefix is worth caching
    pub prefix_min_tokens: usize,
    /// hard cap on per-request fan-out candidates
    pub max_fanout: usize,
    /// prompt tokens a prefilling session advances per scheduling round
    /// (the chunked-prefill budget; 0 = monolithic, the whole prompt in
    /// one round). Chunking bounds the latency a long admission adds to
    /// every active session's decode round — the TPOT cliff — and is
    /// bitwise identical to monolithic prefill for every backend whose
    /// [`crate::cache::CacheCaps::split_prefill_exact`] holds; backends
    /// where it does not hold (SnapKV/PyramidKV/ZipCache
    /// observation-window state) are prefilled monolithically regardless.
    pub prefill_chunk: usize,
    /// spill directory for the tiered-residency page store (None disables
    /// spill, hibernation persistence and `save`/`resume` across restarts).
    /// The directory is used exactly as given — two batchers that must see
    /// each other's snapshots (restart recovery) pass the same path.
    pub spill_dir: Option<PathBuf>,
    /// resident-byte target for hibernated sessions: when `kv_used_bytes`
    /// exceeds this, cold hibernated sessions' sealed pages are evicted to
    /// the spill store, LRU by last-touch round — never the sessions in
    /// the current decode batch. 0 = use `kv_budget_bytes`.
    pub resident_budget_bytes: f64,
    /// graceful-overload queue bound: when the pending queue grows past
    /// this, the lowest-priority (newest within its class) queued generate
    /// request is shed with a structured `overloaded` + `retry_after_ms`
    /// reply instead of waiting forever
    pub max_queue: usize,
    /// hard cap on sessions advanced per decode round (0 = all); the
    /// TPOT governor can cap further under latency pressure
    pub max_decode_batch: usize,
    /// TTFT/TPOT targets steering the round budgets (0 = off)
    pub slo: SloTargets,
    /// per-tenant seat/KV-byte admission quotas (empty = unlimited)
    pub tenant_quotas: TenantQuotas,
    /// online dictionary refresh cadence: every N scheduling rounds, fold
    /// each session's adaptive-overlay atoms back into its universal
    /// dictionary (`KvCache::refresh_dicts`, sessions whose
    /// `caps().dict_refresh` holds — adaptive lexico). 0 = never. Decode
    /// output is bitwise unchanged by a fold (the codes keep their indices
    /// and the atoms keep their values); what changes is where the atoms
    /// live, which re-arms the overlay headroom and rotates the dictionary
    /// generation so stale Gram caches can never be served.
    pub dict_refresh: u64,
    /// coefficient-mode override for every cache this batcher builds
    /// (`--coef-mode fp8|fp16|sign`); `None` defers to `LEXICO_COEF_MODE`
    /// and then to each method spec's own flags.
    pub coef_mode: Option<crate::sparse::CoefMode>,
}

/// Distinguishes spill directories of batchers that share the
/// `LEXICO_SPILL_DIR` root (parallel tests, several servers on one box):
/// concurrent appenders on one page file would corrupt it.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            default_method: "lexico:s=8,nb=32".into(),
            kv_budget_bytes: 64.0 * 1024.0 * 1024.0,
            max_sessions: 32,
            prefix_entries: 8,
            prefix_min_tokens: 8,
            max_fanout: 8,
            prefill_chunk: 256,
            // env defaults let CI run the whole suite with spill active
            // without threading flags through every test; each defaulted
            // config gets a private subdirectory (see SPILL_SEQ)
            spill_dir: std::env::var_os("LEXICO_SPILL_DIR").map(|root| {
                PathBuf::from(root).join(format!(
                    "spill_{}_{}",
                    std::process::id(),
                    SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
                ))
            }),
            resident_budget_bytes: std::env::var("LEXICO_RESIDENT_BUDGET")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.0),
            max_queue: 1024,
            max_decode_batch: 0,
            slo: SloTargets::default(),
            tenant_quotas: TenantQuotas::default(),
            dict_refresh: std::env::var("LEXICO_DICT_REFRESH")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0),
            coef_mode: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Shared-prefix cache
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_step(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Rolling prefix hashes of `(method, ids[..n])` for every n in 1..=len —
/// one incremental pass, so probing all prefix lengths is O(len).
fn prefix_hashes(method: &str, ids: &[u32]) -> Vec<u64> {
    let mut h = fnv_step(FNV_OFFSET, method.as_bytes());
    ids.iter()
        .map(|id| {
            h = fnv_step(h, &id.to_le_bytes());
            h
        })
        .collect()
}

/// One cached prompt prefix: the dense prefill state (for exact suffix
/// resume), a prototype cache to fork, and bookkeeping.
struct PrefixEntry {
    /// stable identity, used to hand the charging-owner role to a
    /// surviving fork when the entry is evicted
    id: u64,
    /// rolling hash of (method, state.tokens)
    hash: u64,
    method: String,
    state: PrefixState,
    proto: Box<dyn KvCache>,
    last_used: u64,
}

impl PrefixEntry {
    /// Bytes this entry keeps resident: the prototype's compressed cache
    /// (shared pages live here as long as the entry does, so the budget
    /// charges them exactly once) plus the dense K/V rows.
    fn bytes(&self) -> f64 {
        self.proto.mem_bytes() + self.state.bytes()
    }
}

/// LRU cache of prompt prefixes, longest-match lookup by rolling hash.
struct PrefixCache {
    entries: Vec<PrefixEntry>,
    capacity: usize,
    clock: u64,
    next_id: u64,
}

impl PrefixCache {
    fn new(capacity: usize) -> Self {
        PrefixCache { entries: Vec::new(), capacity, clock: 0, next_id: 0 }
    }

    /// Bytes the cache keeps resident. Nested entries (a prefix and its
    /// cached extension) share sealed pages through their prototypes'
    /// `Arc`s; each prototype reports them fully, so nesting over-charges
    /// the shared part — deliberately conservative for admission control
    /// (the safe direction: defer rather than overrun).
    fn resident_bytes(&self) -> f64 {
        self.entries.iter().map(|e| e.bytes()).sum()
    }

    /// Longest cached prefix of `ids` under `method`; bumps LRU + hit
    /// counters. Returns the entry index.
    fn lookup(&mut self, method: &str, ids: &[u32]) -> Option<usize> {
        if self.capacity == 0 || self.entries.is_empty() {
            return None;
        }
        let hashes = prefix_hashes(method, ids);
        let mut best: Option<usize> = None;
        let mut best_len = 0usize;
        for (ei, e) in self.entries.iter().enumerate() {
            let n = e.state.len();
            if e.method != method || n == 0 || n > ids.len() {
                continue;
            }
            if e.hash != hashes[n - 1] || e.state.tokens[..] != ids[..n] {
                continue;
            }
            if n > best_len {
                best = Some(ei);
                best_len = n;
            }
        }
        if let Some(b) = best {
            self.clock += 1;
            self.entries[b].last_used = self.clock;
        }
        best
    }

    /// Insert a new prefix (returns the existing id if an identical one is
    /// already cached), evicting the least-recently-used entry when full.
    /// The batcher normally pre-frees capacity through
    /// [`Batcher::insert_prefix`] so evicted entries can hand their
    /// charging-owner role to a surviving fork; the internal eviction here
    /// is the standalone backstop.
    fn insert(&mut self, method: String, state: PrefixState, proto: Box<dyn KvCache>) -> Option<u64> {
        if self.capacity == 0 || state.is_empty() {
            return None;
        }
        let hash = *prefix_hashes(&method, &state.tokens).last().unwrap();
        if let Some(e) = self
            .entries
            .iter()
            .find(|e| e.hash == hash && e.method == method && e.state.tokens == state.tokens)
        {
            return Some(e.id);
        }
        while self.entries.len() >= self.capacity {
            if !self.evict_lru() {
                return None;
            }
        }
        self.clock += 1;
        self.next_id += 1;
        let id = self.next_id;
        self.entries.push(PrefixEntry { id, hash, method, state, proto, last_used: self.clock });
        Some(id)
    }

    /// Drop the least-recently-used entry, skipping `keep` (so budget
    /// pressure never evicts the entry the current request just matched —
    /// that would turn a cheap suffix prefill into a more expensive cold
    /// one). Returns the evicted entry's id so the caller can promote a
    /// surviving fork to charge the pages the prototype used to own.
    fn evict_lru_except(&mut self, keep: Option<usize>) -> Option<u64> {
        let lru = self
            .entries
            .iter()
            .enumerate()
            .filter(|&(i, _)| Some(i) != keep)
            .min_by_key(|&(_, e)| e.last_used)
            .map(|(i, _)| i)?;
        Some(self.entries.swap_remove(lru).id)
    }

    fn evict_lru(&mut self) -> bool {
        self.evict_lru_except(None).is_some()
    }
}

// ---------------------------------------------------------------------------
// Sessions and fan-out groups
// ---------------------------------------------------------------------------

/// Where a session is in its lifecycle. Prefill is a first-class scheduled
/// unit: a `Prefilling` session consumes one budgeted chunk of its prompt
/// per round (`pos = state.len()` prompt tokens have landed in the cache so
/// far), interleaved with the round's single `decode_batch` call, so one
/// long admission never stalls every active session's token cadence.
/// `Decoding` sessions emit one token per round.
enum Phase {
    Prefilling {
        /// the full prompt (BOS + encoded body)
        ids: Vec<u32>,
        /// dense rows of the `pos = state.len()` tokens already landed —
        /// the causal context the next chunk attends over. Starts at
        /// [`PrefixState::empty`] (cold) or a clone of the matched
        /// prefix-cache entry's state (hit).
        state: PrefixState,
        /// resolved cache-method spec (for the deferred prefix insert)
        method: String,
        /// candidates to seat when the last chunk lands (fan-out defers
        /// until the first-token logits exist)
        fanout: usize,
        /// insert the finished prompt into the prefix cache on completion
        insert_on_done: bool,
    },
    Decoding,
    /// A named session parked after its request finished (or its client
    /// vanished): it holds no seat, joins no decode batch, and its sealed
    /// pages are evicted to the spill store under residency pressure — LRU
    /// by `last_touch`. A `resume` request wakes it in place.
    Hibernated {
        /// the client-chosen session name (`Request::session`)
        name: String,
        /// resolved cache-method spec (for the on-disk snapshot)
        method: String,
        /// prompt length, echoed in the resume reply
        n_prompt: usize,
        /// whether `next_token` was already committed to `generated`
        /// (finished stream) or is still pending (client vanished before
        /// the commit) — decides whether the wake round skips the commit
        committed: bool,
        /// round number of the last admission/decode activity (LRU key)
        last_touch: u64,
    },
}

/// One decoding candidate (a request with fanout = n owns n sessions).
struct Session {
    /// key into [`Batcher::groups`]
    group: usize,
    /// candidate index within the group (0 = primary/greedy)
    cand: usize,
    cache: Box<dyn KvCache>,
    pos: usize,
    next_token: u32,
    generated: Vec<u32>,
    /// whether the budget charges this session's shared prefix bytes; false
    /// when a prefix-cache prototype or the primary candidate already does
    charges_shared: bool,
    /// the prefix-cache entry this session forked from, if any — used to
    /// promote a surviving fork to charging owner when the entry is evicted
    from_entry: Option<u64>,
    max_new: usize,
    phase: Phase,
    /// set when a resumed session's `next_token` was already committed
    /// before hibernation: the first wake round feeds it straight into
    /// `decode_batch` without re-appending it to `generated`
    skip_commit: bool,
    /// tokens already added to `Metrics::tokens_generated` at an earlier
    /// hibernation — a resumed session must not re-count them at its next
    /// retirement
    counted: usize,
    /// round this session last advanced a token — the aging key the capped
    /// decode selection rotates on within a priority class
    last_step_round: u64,
}

impl Session {
    fn is_prefilling(&self) -> bool {
        matches!(self.phase, Phase::Prefilling { .. })
    }

    fn is_hibernated(&self) -> bool {
        matches!(self.phase, Phase::Hibernated { .. })
    }
}

/// Why a session leaves the decode loop this round.
enum Retire {
    /// stream finished (stop token / max_new / max_seq) — `next_token`
    /// already committed
    Done,
    /// client cancelled — `next_token` still pending
    Cancelled,
    /// the request's deadline passed — `next_token` still pending; the
    /// group replies `deadline_expired` and its budget frees this round
    Expired,
    /// page fault or backend failure: the whole group replies this error
    Failed(String),
}

/// Per-request state shared by its candidate sessions; the reply is sent
/// when the last candidate retires.
struct Group {
    job: Job,
    n_prompt: usize,
    outputs: Vec<Option<String>>,
    n_generated_primary: usize,
    kv_ratio: f64,
    prefix_hit: bool,
    remaining: usize,
    t0: Instant,
    ttft_ms: f64,
    /// a candidate failed (e.g. corrupt page fault): the reply is this
    /// error instead of the outputs
    error: Option<String>,
    /// resumed sessions have no prefill, so no TTFT sample is recorded
    resumed: bool,
    /// scheduler-clock time the job entered the queue (TTFT-rush ages
    /// against this — a deterministic input under a manual clock)
    enqueue_ms: f64,
    /// scheduler-clock time the job expires (`f64::INFINITY` = none)
    deadline_at: f64,
    /// set at round top when `deadline_at` passes; every candidate retires
    /// with [`Retire::Expired`] the same round
    expired: bool,
}

/// A queued job plus the scheduling facts stamped at enqueue: its arrival
/// sequence number (the deterministic FIFO key within a priority class)
/// and its scheduler-clock arrival time (the deadline/aging origin).
struct QueuedJob {
    job: Job,
    seq: u64,
    enqueue_ms: f64,
}

impl QueuedJob {
    fn deadline_at(&self) -> f64 {
        if self.job.request.deadline_ms == 0 {
            f64::INFINITY
        } else {
            self.enqueue_ms + self.job.request.deadline_ms as f64
        }
    }

    fn slot(&self) -> sched::QueueSlot {
        sched::QueueSlot {
            seq: self.seq,
            priority: self.job.request.priority,
            sheddable: self.job.request.verb == SessionVerb::Generate,
        }
    }
}

/// What one admission attempt did, steering the pass loop in
/// [`Batcher::admit`].
enum Admit {
    /// the queue (or budget state) changed — restart the pass so the
    /// admission order is recomputed over the new queue
    Progress,
    /// this job cannot admit right now for a reason private to it (tenant
    /// over quota, waiting on an in-flight shared prefill, a deferred
    /// resume) — other queued jobs may still admit past it
    Skip,
    /// a global resource (seats, KV budget) is exhausted until a session
    /// retires — end the pass; admitting anything lower-priority past this
    /// point would invert the priority order
    Stall,
}

// ---------------------------------------------------------------------------
// The batcher
// ---------------------------------------------------------------------------

/// The scheduling state, factored as a struct so admission control is unit
/// testable without threads: `enqueue` jobs, call [`Batcher::round`] until
/// done. [`run`] wraps it in the channel-driven serving loop.
pub struct Batcher {
    engine: Arc<Engine>,
    ctx: CacheContext,
    cfg: BatcherConfig,
    metrics: Arc<Mutex<Metrics>>,
    pending: VecDeque<QueuedJob>,
    active: Vec<Session>,
    groups: HashMap<usize, Group>,
    next_gid: usize,
    prefix: PrefixCache,
    stop: u32,
    max_seq: usize,
    /// The worker pool the whole serving path runs on (shared with the
    /// engine): prefill and decode GEMMs, per-session cache fan-out inside
    /// `decode_batch`, and the batched-OMP overflow compression of every
    /// cache this batcher builds. Deterministic at any thread count.
    pool: Arc<ExecPool>,
    /// tiered-residency page store (None = spill disabled); every cache
    /// this batcher builds is attached to it
    spill: Option<Arc<SpillStore>>,
    /// scheduling-round counter — the LRU clock for hibernated sessions
    round_no: u64,
    /// arrival-sequence stamp for the next enqueued job (the deterministic
    /// FIFO key the priority order falls back on)
    next_seq: u64,
    /// the scheduler's time source: wall in production, manual under test
    /// so deadline/aging decisions replay bitwise
    clock: Clock,
    /// TPOT governor over the per-round prefill chunk budget
    chunk_gov: ChunkGovernor,
    /// TPOT governor over the decode batch cap
    batch_gov: BatchGovernor,
    /// smoothed decode-round latency (the `retry_after_ms` hint scale)
    round_ms_ema: f64,
}

impl Batcher {
    pub fn new(
        engine: Arc<Engine>,
        dicts: Option<Arc<DictionarySet>>,
        cfg: BatcherConfig,
        metrics: Arc<Mutex<Metrics>>,
    ) -> Self {
        let max_seq = engine.weights.cfg.max_seq;
        let prefix = PrefixCache::new(cfg.prefix_entries);
        let pool = engine.pool().clone();
        let spill = cfg.spill_dir.as_ref().and_then(|dir| match SpillStore::open(dir) {
            Ok(s) => Some(Arc::new(s)),
            Err(e) => {
                // serve without spill rather than refuse to start
                eprintln!("warning: spill store at {} unavailable ({e}); spill disabled", dir.display());
                None
            }
        });
        // the one runtime every cache this batcher builds is constructed
        // under — forks (prefix hits, fan-out candidates) inherit it
        let mut ctx = CacheContext::new(engine.shape(), dicts);
        ctx.runtime = ctx.runtime.with_pool(pool.clone());
        if let Some(store) = &spill {
            ctx.runtime = ctx.runtime.with_spill(store.clone());
        }
        if let Some(mode) = cfg.coef_mode {
            ctx.runtime = ctx.runtime.with_coef_mode(mode);
        }
        let chunk_gov = ChunkGovernor::new(cfg.prefill_chunk);
        Batcher {
            engine,
            ctx,
            cfg,
            metrics,
            pending: VecDeque::new(),
            active: Vec::new(),
            groups: HashMap::new(),
            next_gid: 0,
            prefix,
            stop: tasks::newline_id(),
            max_seq,
            pool,
            spill,
            round_no: 0,
            next_seq: 0,
            clock: Clock::wall(),
            chunk_gov,
            batch_gov: BatchGovernor::new(),
            round_ms_ema: sched::DEFAULT_ROUND_MS,
        }
    }

    /// Pin the scheduler clock to a fixed time (tests): every deadline and
    /// aging decision becomes a pure function of queue state + this value.
    pub fn set_manual_time(&mut self, ms: f64) {
        self.clock = Clock::Manual(ms);
    }

    /// Poison-tolerant metrics lock (see [`lock_tolerant`]): one panicking
    /// request thread must not poison every later scheduling round.
    fn lock_metrics(&self) -> MutexGuard<'_, Metrics> {
        lock_tolerant(&self.metrics)
    }

    /// The pool this batcher schedules onto.
    pub fn pool(&self) -> &Arc<ExecPool> {
        &self.pool
    }

    pub fn enqueue(&mut self, job: Job) {
        self.lock_metrics().requests += 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back(QueuedJob { job, seq, enqueue_ms: self.clock.now_ms() });
        self.shed_overflow();
    }

    /// Graceful overload: while the queue exceeds its bound, shed the
    /// lowest-priority (newest within its class) queued generate request
    /// with a structured `overloaded` reply carrying a deterministic
    /// backoff hint. Save/resume verbs are never shed.
    fn shed_overflow(&mut self) {
        while self.pending.len() > self.cfg.max_queue {
            let slots: Vec<sched::QueueSlot> = self.pending.iter().map(|q| q.slot()).collect();
            let Some(vi) = sched::shed_victim(&slots) else { break };
            let q = self.pending.remove(vi).unwrap();
            let retry = sched::retry_after_ms(
                self.pending.len(),
                self.cfg.max_sessions,
                self.round_ms_ema,
            );
            self.lock_metrics().shed_prefills += 1;
            let _ = q.job.reply.send(Response::overloaded(q.job.request.id, retry));
        }
    }

    /// Round-top deadline sweep: queued jobs past their deadline reply
    /// `deadline_expired` and leave the queue; active groups past theirs
    /// are flagged so every candidate retires (freeing its budget) in this
    /// round's [`Batcher::decode_round`] — the same same-round reclamation
    /// cancellation gets. Decisions read only the scheduler clock.
    fn expire_deadlines(&mut self) {
        let now = self.clock.now_ms();
        let mut qi = 0;
        while qi < self.pending.len() {
            if now >= self.pending[qi].deadline_at() {
                let q = self.pending.remove(qi).unwrap();
                self.lock_metrics().deadline_expired += 1;
                let _ = q.job.reply.send(Response::failed(
                    q.job.request.id,
                    0,
                    "deadline_expired".into(),
                ));
            } else {
                qi += 1;
            }
        }
        let metrics = &self.metrics;
        for g in self.groups.values_mut() {
            if !g.expired && now >= g.deadline_at {
                g.expired = true;
                lock_tolerant(metrics).deadline_expired += 1;
            }
        }
    }

    /// Per-tenant live usage: seats held (fan-out candidates included, like
    /// [`Batcher::seats_used`]) and KV bytes charged, keyed by tenant name.
    /// Hibernated sessions hold no seat and no tenant attribution.
    fn tenant_usage(&self) -> BTreeMap<String, (usize, f64)> {
        let mut usage: BTreeMap<String, (usize, f64)> = BTreeMap::new();
        for s in &self.active {
            if s.is_hibernated() {
                continue;
            }
            let Some(g) = self.groups.get(&s.group) else { continue };
            let seats = match &s.phase {
                Phase::Prefilling { fanout, .. } => *fanout,
                _ => 1,
            };
            let bytes = if s.charges_shared {
                s.cache.mem_bytes()
            } else {
                (s.cache.mem_bytes() - s.cache.shared_prefix_bytes()).max(0.0)
            };
            let e = usage.entry(g.job.request.tenant.clone()).or_insert((0, 0.0));
            e.0 += seats;
            e.1 += bytes;
        }
        usage
    }

    /// One tenant's live (seats, charged bytes) — the admission quota gate.
    fn tenant_load(&self, tenant: &str) -> (usize, f64) {
        self.tenant_usage().remove(tenant).unwrap_or((0, 0.0))
    }

    /// Whether a scheduling round would make progress. Hibernated sessions
    /// don't count: they sit parked (possibly for days) and must not keep
    /// the serving loop spinning while the queue is empty.
    pub fn has_work(&self) -> bool {
        self.has_schedulable() || !self.pending.is_empty()
    }

    fn has_schedulable(&self) -> bool {
        self.active.iter().any(|s| !s.is_hibernated())
    }

    /// Sessions currently prefilling or decoding (hibernated excluded).
    pub fn n_active(&self) -> usize {
        self.active.iter().filter(|s| !s.is_hibernated()).count()
    }

    /// Named sessions parked for a later `resume`.
    pub fn n_hibernated(&self) -> usize {
        self.active.iter().filter(|s| s.is_hibernated()).count()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_prefix_entries(&self) -> usize {
        self.prefix.entries.len()
    }

    /// Sessions currently consuming prompt chunks (not yet decoding).
    pub fn n_prefilling(&self) -> usize {
        self.active.iter().filter(|s| s.is_prefilling()).count()
    }

    /// Seats the session cap must account for: live sessions plus the
    /// fan-out candidates a prefilling session will seat on completion.
    /// Hibernated sessions hold no seat — parking is what frees it.
    fn seats_used(&self) -> usize {
        self.active
            .iter()
            .map(|s| match &s.phase {
                Phase::Prefilling { fanout, .. } => *fanout,
                Phase::Decoding => 1,
                Phase::Hibernated { .. } => 0,
            })
            .sum()
    }

    /// Bytes the admission gate must hold against in-flight prefills: the
    /// worst-case full-precision cost of prompt tokens admitted sessions
    /// have *not yet* materialized (their remaining chunks), plus the
    /// dense f32 rows of the chunks that *have* landed — those stay
    /// resident in the session's [`PrefixState`] until the prompt
    /// completes, on top of whatever compressed bytes
    /// [`Batcher::kv_used_bytes`] already sees in the cache. Subtracting
    /// this keeps peak resident memory inside the configured budget while
    /// a long admission is mid-flight.
    fn reserved_prompt_bytes(&self) -> f64 {
        let shape = self.engine.shape();
        let tb = shape.n_layers as f64 * shape.full_token_bytes();
        self.active
            .iter()
            .map(|s| match &s.phase {
                Phase::Prefilling { ids, state, .. } => {
                    tb * (ids.len() - state.len()) as f64 + state.bytes()
                }
                Phase::Decoding | Phase::Hibernated { .. } => 0.0,
            })
            .sum()
    }

    /// Budget usage right now: each prefix-cache entry charged once (its
    /// prototype owns the shared pages) and each session charged only the
    /// bytes it does not share with a charging owner.
    pub fn kv_used_bytes(&self) -> f64 {
        self.prefix.resident_bytes()
            + self
                .active
                .iter()
                .map(|s| {
                    if s.charges_shared {
                        s.cache.mem_bytes()
                    } else {
                        (s.cache.mem_bytes() - s.cache.shared_prefix_bytes()).max(0.0)
                    }
                })
                .sum::<f64>()
    }

    /// One scheduling round: admit while the budget allows, advance every
    /// prefilling session by one budgeted chunk, advance every decoding
    /// session one token, retire finished sessions — and if any retired,
    /// run admission again so freed budget seats a waiting job in the same
    /// round.
    pub fn round(&mut self) {
        self.round_no += 1;
        self.expire_deadlines();
        self.admit();
        self.advance_prefills();
        if self.decode_round() > 0 && !self.pending.is_empty() {
            self.admit();
        }
        if self.cfg.dict_refresh > 0 && self.round_no % self.cfg.dict_refresh == 0 {
            // online dictionary refresh: fold each adaptive session's
            // overlay atoms into its universal dictionaries between
            // rounds. Decode output is bitwise unchanged (the folded
            // atoms keep their coefficients); the payoff is a re-armed
            // overlay budget and a rotated dictionary generation, so any
            // Gram cache realized afterwards sees the folded atoms.
            let mut folded = 0u64;
            for sess in &mut self.active {
                if sess.cache.caps().dict_refresh {
                    if let Ok(n) = sess.cache.refresh_dicts() {
                        folded += n as u64;
                    }
                }
            }
            if folded > 0 {
                self.lock_metrics().dict_refresh_atoms += folded;
            }
        }
        self.enforce_residency();
        self.debug_budget_invariant();
        let kv_used = self.kv_used_bytes();
        let n_hib = self.n_hibernated() as u64;
        let tenants: Vec<(String, u64, f64)> = self
            .tenant_usage()
            .into_iter()
            .filter(|(t, _)| !t.is_empty())
            .map(|(t, (seats, bytes))| (t, seats as u64, bytes))
            .collect();
        let queue_depth = self.pending.len() as u64;
        let mut m = self.lock_metrics();
        m.active_sessions = self.n_active() as u64;
        m.prefilling_sessions = self.n_prefilling() as u64;
        m.queue_depth = queue_depth;
        m.tenants = tenants;
        m.kv_used_bytes = kv_used;
        m.gram_bytes =
            self.ctx.dicts.as_ref().map(|d| d.gram_bytes() as f64).unwrap_or(0.0);
        m.hibernated_sessions = n_hib;
        if let Some(store) = &self.spill {
            let (spilled_pages, spill_bytes, faults, _) = store.counters();
            m.spilled_pages = spilled_pages;
            m.spill_bytes = spill_bytes as f64;
            m.faults = faults;
        }
    }

    /// Evict cold hibernated sessions' sealed pages until resident KV
    /// bytes fit the residency target — LRU by last-touch round, never a
    /// session in the current decode batch (those are by definition not
    /// hibernated). Eviction is cheap: pages already mirrored to the spill
    /// store drop their RAM copy with zero I/O.
    fn enforce_residency(&mut self) {
        if self.spill.is_none() {
            return;
        }
        let budget = if self.cfg.resident_budget_bytes > 0.0 {
            self.cfg.resident_budget_bytes
        } else {
            self.cfg.kv_budget_bytes
        };
        while self.kv_used_bytes() > budget {
            if self.spill_coldest_hibernated_except(None) == 0.0 {
                break; // nothing left that spilling would free
            }
        }
    }

    /// Spill the least-recently-touched hibernated session that still has
    /// sole-owned resident pages, skipping `except` (the session being
    /// woken must not churn through the store it is about to fault from).
    /// Returns the bytes freed (0.0 = nothing could be spilled).
    fn spill_coldest_hibernated_except(&mut self, except: Option<usize>) -> f64 {
        let mut order: Vec<(u64, usize)> = self
            .active
            .iter()
            .enumerate()
            .filter_map(|(si, s)| match &s.phase {
                Phase::Hibernated { last_touch, .. } if Some(si) != except => {
                    Some((*last_touch, si))
                }
                _ => None,
            })
            .collect();
        order.sort_unstable();
        for (_, si) in order {
            // an I/O error here only means this session's pages stay
            // resident; eviction moves on to the next candidate
            if let Ok((n, freed)) = self.active[si].cache.spill_cold() {
                if n > 0 {
                    return freed;
                }
            }
        }
        0.0
    }

    /// Accounting-drift tripwire (debug builds only): resident KV usage
    /// must stay within the configured budget, allowing for the two
    /// legitimate carve-outs — the bootstrap admission (one request larger
    /// than the whole budget is admitted when nothing else runs, rather
    /// than deadlocking the queue) and hibernated residency (parked
    /// sessions hold no seat but their un-spillable tail/buffer bytes stay
    /// resident). Catches double-charging or unreturned bytes in tests
    /// instead of as mystery over-admission in production.
    fn debug_budget_invariant(&self) {
        if !cfg!(debug_assertions) {
            return;
        }
        let hibernated: f64 = self
            .active
            .iter()
            .filter(|s| s.is_hibernated())
            .map(|s| s.cache.mem_bytes())
            .sum();
        let max_single = self
            .active
            .iter()
            .filter(|s| !s.is_hibernated())
            .map(|s| s.cache.mem_bytes())
            .fold(0.0f64, f64::max);
        let limit = self.cfg.kv_budget_bytes.max(max_single) + hibernated + 1024.0;
        debug_assert!(
            self.kv_used_bytes() <= limit,
            "KV accounting drift: used {} B > limit {} B (budget {} B, hibernated {} B)",
            self.kv_used_bytes(),
            limit,
            self.cfg.kv_budget_bytes,
            hibernated
        );
    }

    fn reject(&mut self, job: Job, n_prompt: usize, error: String) {
        self.lock_metrics().rejected += 1;
        let _ = job.reply.send(Response::failed(job.request.id, n_prompt, error));
    }

    /// Insert a prefix entry, pre-evicting (with owner promotion) so shared
    /// pages never lose their charging owner to a capacity eviction.
    fn insert_prefix(
        &mut self,
        method: String,
        state: PrefixState,
        proto: Box<dyn KvCache>,
    ) -> Option<u64> {
        if self.cfg.prefix_entries == 0 {
            return None;
        }
        while self.prefix.entries.len() >= self.cfg.prefix_entries {
            match self.prefix.evict_lru_except(None) {
                Some(id) => self.promote_entry_owner(id),
                None => return None,
            }
        }
        self.prefix.insert(method, state, proto)
    }

    /// After the entry owning shared pages disappears, hand the
    /// charging-owner role to one surviving fork: with ≥2 forks still
    /// sharing the pages, `mem − shared` on every fork would charge the
    /// pages zero times; promoting exactly one restores charge-once. (With
    /// a single surviving fork the pages become private automatically —
    /// `Arc::strong_count` drops to 1 — and the flag is a no-op.)
    fn promote_entry_owner(&mut self, entry_id: u64) {
        if let Some(s) = self
            .active
            .iter_mut()
            .find(|s| s.from_entry == Some(entry_id) && !s.charges_shared)
        {
            s.charges_shared = true;
        }
    }

    /// Admission pass: seat pending requests in priority order (highest
    /// first, FIFO within a class — with all-default priorities this is
    /// exactly the old FIFO) while the session cap, tenant quotas and KV
    /// budget allow. Admission does **zero transformer work** — it
    /// validates, resolves the prefix cache, builds (or forks) the
    /// session's KV cache and seats the session in [`Phase::Prefilling`];
    /// the prompt itself lands one budgeted chunk per round in
    /// [`Batcher::advance_prefills`], charging the budget incrementally as
    /// chunks materialize bytes.
    pub fn admit(&mut self) {
        'pass: loop {
            if self.pending.is_empty() {
                break;
            }
            let slots: Vec<sched::QueueSlot> = self.pending.iter().map(|q| q.slot()).collect();
            for qi in sched::admission_order(&slots) {
                match self.admit_one(qi) {
                    // the queue (or reclaimable budget) changed under the
                    // ordering: recompute it before the next attempt
                    Admit::Progress => continue 'pass,
                    Admit::Skip => continue,
                    Admit::Stall => break 'pass,
                }
            }
            break; // every queued job skipped: nothing admissible now
        }
    }

    /// One admission attempt for the queued job at index `qi`: validation,
    /// per-tenant quota gate, global seat/budget gates, seating. See
    /// [`Admit`] for what each outcome tells the pass loop.
    fn admit_one(&mut self, qi: usize) -> Admit {
        let front = &self.pending[qi].job;
        if front.cancelled() {
            // the client vanished while the job was still queued
            let q = self.pending.remove(qi).unwrap();
            self.lock_metrics().cancelled += 1;
            let _ = q.job.reply.send(Response::failed(
                q.job.request.id,
                0,
                "cancelled: client disconnected".into(),
            ));
            return Admit::Progress;
        }
        match front.request.verb {
            SessionVerb::Save => {
                let q = self.pending.remove(qi).unwrap();
                self.handle_save(q.job);
                return Admit::Progress;
            }
            SessionVerb::Resume => return self.try_resume_at(qi),
            SessionVerb::Generate => {}
        }
        if self.seats_used() >= self.cfg.max_sessions {
            return Admit::Stall;
        }
        let prompt = front.request.prompt.clone();
        let max_new = front.request.max_new;
        let req_fanout = front.request.fanout;
        let tenant = front.request.tenant.clone();
        let session_name = front.request.session.clone();
        if !session_name.is_empty() {
            if !valid_session_name(&session_name) {
                let q = self.pending.remove(qi).unwrap();
                self.reject(q.job, 0, format!("invalid session name {session_name:?}"));
                return Admit::Progress;
            }
            if req_fanout > 1 {
                let q = self.pending.remove(qi).unwrap();
                self.reject(q.job, 0, "named sessions cannot fan out".into());
                return Admit::Progress;
            }
        }

        // ---- validate ---------------------------------------------
        let ids = match tasks::try_encode(&prompt) {
            Ok(body) => {
                let mut ids = vec![tasks::BOS];
                ids.extend(body);
                ids
            }
            Err(e) => {
                let q = self.pending.remove(qi).unwrap();
                self.reject(q.job, 0, format!("bad prompt: {e}"));
                return Admit::Progress;
            }
        };
        if ids.len() + 2 > self.max_seq {
            let q = self.pending.remove(qi).unwrap();
            self.reject(q.job, ids.len(), "prompt too long".into());
            return Admit::Progress;
        }
        let fanout = req_fanout.clamp(1, self.cfg.max_fanout.min(self.cfg.max_sessions));
        if self.seats_used() + fanout > self.cfg.max_sessions && self.has_schedulable() {
            return Admit::Stall; // wait for seats
        }
        let method = if front.request.method.is_empty() {
            self.cfg.default_method.clone()
        } else {
            front.request.method.clone()
        };

        // ---- budget gate ------------------------------------------
        let hit = self.prefix.lookup(&method, &ids);
        if hit.is_none() {
            // a session is mid-prefill on a prefix of this prompt and
            // will insert it into the prefix cache on completion: wait
            // (skipped in place, other jobs admit past it) instead of
            // duplicating the whole cold prefill — the
            // shared-system-prompt burst case
            let inflight = self.active.iter().any(|s| match &s.phase {
                Phase::Prefilling { ids: in_ids, method: in_m, insert_on_done, .. } => {
                    *insert_on_done
                        && *in_m == method
                        && in_ids.len() <= ids.len()
                        && in_ids[..] == ids[..in_ids.len()]
                }
                _ => false,
            });
            if inflight {
                return Admit::Skip;
            }
        }
        let cold_tokens = match hit {
            Some(ei) => ids.len() - self.prefix.entries[ei].state.len(),
            None => ids.len(),
        };
        // Worst-case estimate: full-precision KV for the tokens this
        // admission will materialize. Extra fan-out candidates are
        // estimated at their generated tokens only (the copy-on-write
        // case). A suffix-bearing prefix hit also clones the entry's
        // dense f32 rows for the chunked resume — resident until the
        // suffix lands, so the gate must hold them too. Prompt tokens
        // still waiting in other sessions' unprefilled chunks are
        // counted via `reserved_prompt_bytes`; the true footprint
        // feeds back through `kv_used_bytes` as chunks land.
        let shape = self.engine.shape();
        let hit_state_bytes = match hit {
            Some(ei) if cold_tokens > 0 => self.prefix.entries[ei].state.bytes(),
            _ => 0.0,
        };
        let est = shape.n_layers as f64
            * shape.full_token_bytes()
            * ((cold_tokens + max_new) as f64 + ((fanout - 1) * max_new) as f64)
            + hit_state_bytes;

        // ---- per-tenant quota gate --------------------------------
        if let Some(quota) = self.cfg.tenant_quotas.get(&tenant) {
            let (seats, bytes) = self.tenant_load(&tenant);
            if (quota.seats > 0 && seats + fanout > quota.seats)
                || (quota.kv_bytes > 0.0 && bytes + est > quota.kv_bytes)
            {
                // over quota: stays queued (pressure resolves as this
                // tenant's sessions retire); other tenants admit past it
                return Admit::Skip;
            }
        }

        // Clamped at zero: right after a hibernated session wakes, its
        // faulted pages can push usage transiently past the budget —
        // a negative headroom here would wrap the comparison instead
        // of just deferring admission.
        let budget_left = (self.cfg.kv_budget_bytes
            - self.kv_used_bytes()
            - self.reserved_prompt_bytes())
        .max(0.0);
        if est > budget_left {
            // hibernated sessions' resident pages are the coldest
            // bytes in the process: page them out before deferring
            // admission or evicting prefix entries
            if self.spill_coldest_hibernated_except(None) > 0.0 {
                return Admit::Progress;
            }
            if self.has_schedulable() {
                return Admit::Stall; // wait for a session to retire
            }
            // free prefix residency (never the entry just matched) and
            // re-evaluate; a surviving fork inherits the page charge
            if let Some(evicted) = self.prefix.evict_lru_except(hit) {
                self.promote_entry_owner(evicted);
                return Admit::Progress;
            }
        }

        // ---- seat the session (cold cache, or fork on a hit) ------
        let q = self.pending.remove(qi).unwrap();
        let enqueue_ms = q.enqueue_ms;
        let deadline_at = q.deadline_at();
        let job = q.job;
        let t0 = Instant::now();
        let (cache, state, prefix_hit, charges_shared, from_entry, insert_on_done) = match hit {
            Some(ei) => {
                let entry = &self.prefix.entries[ei];
                let entry_id = entry.id;
                // the prototype was built under this batcher's runtime
                // (pool, spill store, coefficient mode) — the fork
                // inherits all of it
                let cache = entry.proto.fork();
                let suffix_len = ids.len() - entry.state.len();
                let state = if suffix_len == 0 {
                    // exact hit: no chunk will ever run, so only the
                    // length and logits are needed — skip the dense
                    // K/V row copy entirely
                    PrefixState {
                        tokens: entry.state.tokens.clone(),
                        ks: vec![Vec::new(); entry.state.ks.len()],
                        vs: vec![Vec::new(); entry.state.vs.len()],
                        logits: entry.state.logits.clone(),
                    }
                } else {
                    // the session owns its copy of the prefix rows
                    // (the entry may be evicted while chunks are still
                    // landing); the memcpy costs less than even one
                    // suffix token's attention over those same rows
                    entry.state.clone()
                };
                let mut m = self.lock_metrics();
                m.prefix_hits += 1;
                m.prefill_tokens_total += ids.len() as u64;
                m.shared_bytes += cache.shared_prefix_bytes();
                drop(m);
                let longer = suffix_len >= self.cfg.prefix_min_tokens;
                (cache, state, true, false, Some(entry_id), longer)
            }
            None => match build_cache(&method, &self.ctx) {
                // `ctx.runtime` carries the pool and spill store, so
                // every cache this batcher builds can page out to disk;
                // forks (prefix hits, fan-out candidates) inherit the
                // attachment
                Ok(cache) => {
                    let cacheable = self.cfg.prefix_entries > 0
                        && cache.caps().split_prefill_exact
                        && ids.len() >= self.cfg.prefix_min_tokens;
                    let mut m = self.lock_metrics();
                    m.prefix_misses += 1;
                    m.prefill_tokens_total += ids.len() as u64;
                    drop(m);
                    // until a prototype enters the prefix cache, the
                    // session is sole owner of its bytes and charges
                    // them (flipped when the entry is inserted)
                    let state = PrefixState::empty(shape.n_layers);
                    (cache, state, false, true, None, cacheable)
                }
                Err(e) => {
                    self.reject(job, ids.len(), format!("bad method '{method}': {e}"));
                    return Admit::Progress;
                }
            },
        };

        let pos = state.len();
        let gid = self.next_gid;
        self.next_gid += 1;
        self.groups.insert(gid, Group {
            job,
            n_prompt: ids.len(),
            // sized for the requested fan-out; shrunk at transition if
            // the vocab cannot seat that many distinct first tokens
            outputs: vec![None; fanout],
            n_generated_primary: 0,
            kv_ratio: 0.0,
            prefix_hit,
            // only the primary session exists until the prompt lands
            remaining: 1,
            t0,
            ttft_ms: 0.0,
            error: None,
            resumed: false,
            enqueue_ms,
            deadline_at,
            expired: false,
        });
        self.active.push(Session {
            group: gid,
            cand: 0,
            cache,
            pos,
            next_token: 0,
            generated: Vec::new(),
            charges_shared,
            from_entry,
            max_new,
            phase: Phase::Prefilling { ids, state, method, fanout, insert_on_done },
            skip_commit: false,
            counted: 0,
            last_step_round: self.round_no,
        });
        Admit::Progress
    }

    /// Advance every prefilling session by one budgeted chunk. A session
    /// whose final chunk lands transitions to [`Phase::Decoding`]: TTFT is
    /// recorded, fan-out candidates fork the freshly landed cache, and —
    /// when the prompt qualifies — the dense prefix state is sealed into
    /// the shared-prefix cache. Chunked execution is bitwise identical to
    /// the old monolithic admission prefill (the [`Engine::prefill_chunk`]
    /// contract), so transcripts cannot change with the chunk size.
    // index loop: sessions are re-borrowed piecewise (phase split from
    // cache) and the vec grows at the end — an iterator can't express it
    #[allow(clippy::needless_range_loop)]
    fn advance_prefills(&mut self) {
        if self.active.iter().all(|s| !s.is_prefilling()) {
            return;
        }
        let engine = self.engine.clone();
        // under a TPOT target the governor's AIMD budget replaces the
        // static chunk size (identical to it while the target is unset)
        let chunk_cap = self.chunk_gov.budget();
        let now_ms = self.clock.now_ms();
        let mut round_tokens = 0u64;
        let mut round_chunks = 0u64;
        let mut inserts: Vec<(String, PrefixState, Box<dyn KvCache>)> = Vec::new();
        let mut forks: Vec<Session> = Vec::new();
        let mut extra_candidates = 0u64;
        for si in 0..self.active.len() {
            if !self.active[si].is_prefilling() {
                continue;
            }
            // a cancelled (or deadline-expired) request stops consuming
            // chunks; decode_round retires it (and frees its bytes) this
            // same round
            let g = &self.groups[&self.active[si].group];
            if g.job.cancelled() || g.expired {
                continue;
            }
            // a request past half its TTFT target abandons chunk pacing
            // and rushes its remaining prompt this round
            let rush = sched::ttft_rush(now_ms - g.enqueue_ms, self.cfg.slo.ttft_ms);
            let (logits, complete) = {
                let sess = &mut self.active[si];
                let Phase::Prefilling { ids, state, insert_on_done, .. } = &mut sess.phase else {
                    unreachable!()
                };
                let done = state.len();
                // non-splittable backends must see the whole prompt at once
                let cap = if sess.cache.caps().split_prefill_exact && !rush {
                    chunk_cap
                } else {
                    usize::MAX
                };
                let end = (done + cap.min(ids.len() - done)).min(ids.len());
                let logits = if done == 0 && end == ids.len() && !*insert_on_done {
                    // the whole prompt lands in this one chunk and nothing
                    // will ever read the dense rows (no later chunk, no
                    // prefix-cache insert): plain prefill — byte-identical
                    // compute, minus the per-layer row copies a capture
                    // would make (the monolithic / eviction-backend path)
                    engine.prefill(&ids[..], &mut *sess.cache)
                } else {
                    engine.prefill_chunk(state, &ids[done..end], &mut *sess.cache)
                };
                round_tokens += (end - done) as u64;
                round_chunks += 1;
                sess.pos = end;
                // `end == ids.len()` ⇒ transition below replaces the phase
                // this same iteration, so the fast path's untouched `state`
                // is never observed half-complete
                (logits, end == ids.len())
            };
            if !complete {
                continue;
            }
            // ---- last chunk landed: transition to decoding ------------
            let Phase::Prefilling { ids, state, method, fanout, insert_on_done } =
                std::mem::replace(&mut self.active[si].phase, Phase::Decoding)
            else {
                unreachable!()
            };
            let n_prompt = ids.len();
            let firsts = top_tokens(&logits, fanout);
            let gid = self.active[si].group;
            {
                let sess = &mut self.active[si];
                sess.next_token = firsts[0];
                sess.pos = n_prompt;
            }
            if insert_on_done {
                if self.active[si].charges_shared {
                    // the prototype about to enter the prefix cache takes
                    // over the charge for the (soon shared) pages
                    self.active[si].charges_shared = false;
                }
                inserts.push((method, state, self.active[si].cache.fork()));
            }
            let (from_entry, max_new) = (self.active[si].from_entry, self.active[si].max_new);
            for (cand, &tok) in firsts.iter().enumerate().skip(1) {
                forks.push(Session {
                    group: gid,
                    cand,
                    cache: self.active[si].cache.fork(),
                    pos: n_prompt,
                    next_token: tok,
                    generated: Vec::new(),
                    charges_shared: false,
                    from_entry,
                    max_new,
                    phase: Phase::Decoding,
                    skip_commit: false,
                    counted: 0,
                    last_step_round: self.round_no,
                });
            }
            extra_candidates += (firsts.len() - 1) as u64;
            let g = self.groups.get_mut(&gid).expect("session without group");
            g.ttft_ms = g.t0.elapsed().as_secs_f64() * 1e3;
            g.outputs = vec![None; firsts.len()];
            g.remaining = firsts.len();
        }
        for (method, state, proto) in inserts {
            self.insert_prefix(method, state, proto);
        }
        self.active.extend(forks);
        if round_tokens > 0 || extra_candidates > 0 {
            let mut m = self.lock_metrics();
            m.prefill_tokens += round_tokens;
            m.prefill_chunks += round_chunks;
            m.max_round_prefill_tokens = m.max_round_prefill_tokens.max(round_tokens);
            m.fanout_sessions += extra_candidates;
        }
    }

    /// One batched decode round for ALL active sessions, then retirement.
    /// Returns how many sessions retired.
    ///
    /// Layer-major continuous batching: commit each session's pending
    /// token, retire finished sessions, then advance every remaining
    /// session together through one `decode_batch` call so each weight
    /// matrix streams once per layer per round instead of once per session
    /// (the batch-first pipeline; token-identical to per-session
    /// `decode_step` calls).
    ///
    /// Inside that call the engine also batches the *dictionary* work:
    /// sessions whose caches share an `Arc<DictionarySet>` get their
    /// qᵀD_k projection computed in one per-layer GEMM and their base
    /// value reconstruction in one shared per-atom pass (the round-level
    /// shared-qd path). The batcher needs no awareness of this — the
    /// grouping happens per round over whatever mix of backends the
    /// admission policy produced, and is bitwise-identical to the
    /// per-session path.
    pub fn decode_round(&mut self) -> usize {
        let mut retire: Vec<(usize, Retire)> = Vec::new();
        let mut streamed = 0u64;
        let mut clamped = 0u64;
        let round_no = self.round_no;

        // ---- pass 1: candidates + cancellation/expiry retirement ------
        // Batch composition is decided over the decodable set BEFORE any
        // token commits, so a session deferred by the TPOT batch cap does
        // not advance this round — the cap changes which round a token
        // lands in, never the token stream itself.
        let mut candidates: Vec<usize> = Vec::new();
        let mut slots: Vec<sched::DecodeSlot> = Vec::new();
        for (si, sess) in self.active.iter().enumerate() {
            if sess.is_hibernated() {
                continue; // parked; its group is long gone
            }
            let g = self.groups.get(&sess.group).expect("session without group");
            if g.job.cancelled() {
                // abandoned mid-stream (or mid-prefill): retire before
                // committing a token so the bytes return to the budget
                // this round
                retire.push((si, Retire::Cancelled));
                continue;
            }
            if g.expired {
                // past its deadline: the same same-round reclamation as
                // cancellation
                retire.push((si, Retire::Expired));
                continue;
            }
            if sess.is_prefilling() {
                continue; // still consuming prompt chunks
            }
            slots.push(sched::DecodeSlot {
                priority: g.job.request.priority,
                last_step_round: sess.last_step_round,
                seat: si as u64,
            });
            candidates.push(si);
        }
        let cfg_cap =
            if self.cfg.max_decode_batch == 0 { usize::MAX } else { self.cfg.max_decode_batch };
        let cap = cfg_cap.min(self.batch_gov.cap());
        let selected: Vec<usize> = if candidates.len() > cap {
            sched::decode_selection(&slots, cap).into_iter().map(|i| candidates[i]).collect()
        } else {
            candidates
        };
        let mut in_sel = vec![false; self.active.len()];
        for &si in &selected {
            in_sel[si] = true;
        }

        // ---- pass 2: commit + stream + batch the selected sessions ----
        let mut round_observed: Option<(f64, usize)> = None;
        {
            let mut toks: Vec<u32> = Vec::new();
            let mut poss: Vec<usize> = Vec::new();
            let mut decoding: Vec<usize> = Vec::new();
            let mut caches: Vec<&mut dyn KvCache> = Vec::new();
            let groups = &self.groups;
            for (si, sess) in self.active.iter_mut().enumerate() {
                if !in_sel[si] {
                    continue;
                }
                let g = groups.get(&sess.group).expect("session without group");
                if sess.skip_commit {
                    // first round after a resume whose `next_token` was
                    // already committed before hibernation: feed it to
                    // decode_batch without re-appending it
                    sess.skip_commit = false;
                } else {
                    sess.generated.push(sess.next_token);
                    if sess.cand == 0 {
                        if let Some(tx) = &g.job.stream {
                            let delta = StreamDelta {
                                id: g.job.request.id,
                                token: tasks::decode(&[sess.next_token]),
                                i: sess.generated.len() - 1,
                            };
                            match tx.try_send(delta) {
                                Ok(()) => streamed += 1,
                                // slow reader: the bounded channel is full,
                                // so the delta is dropped (clamped) instead
                                // of stalling the round or buffering
                                // without limit — the final reply still
                                // carries the full text
                                Err(TrySendError::Full(_)) => clamped += 1,
                                Err(TrySendError::Disconnected(_)) => {
                                    // the front end is gone — cancel; the
                                    // session retires next round
                                    g.job.cancel.store(true, Ordering::SeqCst);
                                }
                            }
                        }
                    }
                    let done = sess.next_token == self.stop
                        || sess.generated.len() >= sess.max_new
                        || sess.pos + 1 >= self.max_seq;
                    if done {
                        retire.push((si, Retire::Done));
                        continue;
                    }
                }
                // fault spilled pages back before attention reads them
                // (a freshly resumed session, or one evicted while queued)
                if sess.cache.spilled_bytes() > 0.0 {
                    if let Err(e) = sess.cache.fault_resident() {
                        retire.push((si, Retire::Failed(format!("page fault failed: {e}"))));
                        continue;
                    }
                }
                toks.push(sess.next_token);
                poss.push(sess.pos);
                decoding.push(si);
                caches.push(&mut *sess.cache);
            }
            if !decoding.is_empty() {
                let step_t0 = Instant::now();
                let logits = self.engine.decode_batch(&toks, &poss, &mut caches);
                drop(caches);
                let round_ms = step_t0.elapsed().as_secs_f64() * 1e3;
                let per_token = round_ms / decoding.len() as f64;
                for (bi, &si) in decoding.iter().enumerate() {
                    let sess = &mut self.active[si];
                    sess.next_token = argmax(&logits[bi]) as u32;
                    sess.pos += 1;
                    sess.last_step_round = round_no;
                }
                round_observed = Some((round_ms, decoding.len()));
                // one sample per round (amortized ms/token at that round's
                // batch size) — duplicating it per session would flatten
                // the percentile summary into the mean
                let mut m = self.lock_metrics();
                m.per_token_ms.push(per_token);
                m.decode_round_ms.push(round_ms);
            }
        }
        if let Some((round_ms, batch)) = round_observed {
            // production-path latency feedback: the retry_after hint scale
            // and the TPOT governors. Decision paths pinned by tests run
            // under a manual clock with targets unset, so this wall-clock
            // read never reaches them.
            self.round_ms_ema = 0.8 * self.round_ms_ema + 0.2 * round_ms;
            self.chunk_gov.observe(round_ms, self.cfg.slo.tpot_ms);
            self.batch_gov.observe(round_ms, self.cfg.slo.tpot_ms, batch);
        }
        if streamed > 0 || clamped > 0 {
            let mut m = self.lock_metrics();
            m.streamed_tokens += streamed;
            m.stream_clamped += clamped;
        }
        // the retirement loop swap_removes by descending index; the two
        // passes above each push ascending, so re-sort the combined list
        retire.sort_by_key(|&(si, _)| si);
        let n_retired = retire.len();
        for (si, why) in retire.into_iter().rev() {
            let mut sess = self.active.swap_remove(si);
            let gid = sess.group;
            let (name, method, n_prompt) = {
                let g = &self.groups[&gid];
                let m = if g.job.request.method.is_empty() {
                    self.cfg.default_method.clone()
                } else {
                    g.job.request.method.clone()
                };
                (g.job.request.session.clone(), m, g.n_prompt)
            };
            // a named session parks for a later `resume` instead of
            // dropping its cache — unless the candidate failed, never got
            // past prefill, or spill is disabled
            let will_hibernate = !name.is_empty()
                && !matches!(why, Retire::Failed(_))
                && !sess.is_prefilling()
                && self.spill.is_some();
            if sess.charges_shared && !will_hibernate {
                // the retiring session was the charging owner of pages
                // shared with siblings — hand the role to a survivor so
                // the pages stay charged exactly once (no-op when nothing
                // is shared: shared_prefix_bytes is 0 for a lone holder)
                let heir = sess
                    .from_entry
                    .and_then(|id| {
                        self.active
                            .iter()
                            .position(|s| s.from_entry == Some(id) && !s.charges_shared)
                    })
                    .or_else(|| {
                        self.active.iter().position(|s| s.group == gid && !s.charges_shared)
                    });
                if let Some(i) = heir {
                    self.active[i].charges_shared = true;
                }
            }
            {
                let mut m = self.lock_metrics();
                m.tokens_generated += (sess.generated.len() - sess.counted) as u64;
            }
            let g = self.groups.get_mut(&gid).expect("session without group");
            if let Retire::Failed(e) = &why {
                g.error = Some(e.clone());
            }
            g.outputs[sess.cand] = Some(tasks::decode(&sess.generated));
            if sess.cand == 0 {
                g.kv_ratio = sess.cache.kv_ratio();
                g.n_generated_primary = sess.generated.len();
            }
            g.remaining -= 1;
            let group_done = g.remaining == 0;
            if will_hibernate {
                let committed = matches!(why, Retire::Done);
                sess.counted = sess.generated.len();
                if let Err(e) = self.hibernate_session(sess, name, method, n_prompt, committed) {
                    // best effort: the reply below still goes out; only the
                    // resume capability is lost
                    eprintln!("warning: session hibernation failed ({e}); state dropped");
                }
            }
            if group_done {
                let g = self.groups.remove(&gid).unwrap();
                if let Some(err) = g.error {
                    let _ =
                        g.job.reply.send(Response::failed(g.job.request.id, g.n_prompt, err));
                } else if g.job.cancelled() {
                    self.lock_metrics().cancelled += 1;
                    let _ = g.job.reply.send(Response::failed(
                        g.job.request.id,
                        g.n_prompt,
                        "cancelled: client disconnected".into(),
                    ));
                } else if g.expired {
                    // counted in deadline_expired when the flag was set
                    let _ = g.job.reply.send(Response::failed(
                        g.job.request.id,
                        g.n_prompt,
                        "deadline_expired".into(),
                    ));
                } else {
                    let mut m = self.lock_metrics();
                    m.completed += 1;
                    if !g.resumed {
                        // a resume has no prefill; a 0 ms sample would
                        // skew the TTFT percentiles
                        m.ttft_ms.push(g.ttft_ms);
                    }
                    m.kv_ratios.push(g.kv_ratio);
                    drop(m);
                    let mut outputs: Vec<String> =
                        g.outputs.into_iter().map(Option::unwrap_or_default).collect();
                    let text = std::mem::take(&mut outputs[0]);
                    let _ = g.job.reply.send(Response {
                        id: g.job.request.id,
                        text,
                        alts: outputs.split_off(1),
                        n_prompt: g.n_prompt,
                        n_generated: g.n_generated_primary,
                        ttft_ms: g.ttft_ms,
                        total_ms: g.t0.elapsed().as_secs_f64() * 1e3,
                        kv_ratio: g.kv_ratio,
                        prefix_hit: g.prefix_hit,
                        error: None,
                        retry_after_ms: None,
                    });
                }
            }
        }
        n_retired
    }

    // -----------------------------------------------------------------
    // Session hibernation: park / save / resume
    // -----------------------------------------------------------------

    fn hibernated_index(&self, name: &str) -> Option<usize> {
        self.active
            .iter()
            .position(|s| matches!(&s.phase, Phase::Hibernated { name: n, .. } if n == name))
    }

    /// Park a finished (or abandoned) named session: snapshot it to the
    /// spill store — so a `resume` survives a batcher restart — then keep
    /// it in `active` as [`Phase::Hibernated`], holding no seat.
    fn hibernate_session(
        &mut self,
        mut sess: Session,
        name: String,
        method: String,
        n_prompt: usize,
        committed: bool,
    ) -> Result<(), String> {
        let store = self
            .spill
            .clone()
            .ok_or_else(|| "hibernation requires a spill store (--spill-dir)".to_string())?;
        let cache_blob = sess.cache.hibernate_state()?;
        let snap = encode_session_snapshot(&method, n_prompt, &sess, committed, &cache_blob);
        store.save_snapshot(&name, &snap).map_err(|e| e.to_string())?;
        let old = self.hibernated_index(&name);
        sess.group = usize::MAX; // no group while parked
        sess.skip_commit = false;
        sess.phase =
            Phase::Hibernated { name, method, n_prompt, committed, last_touch: self.round_no };
        match old {
            // latest wins — replace in place: the caller (the retirement
            // loop) still holds indices into `active`, so the slot must not
            // shift other elements the way a swap_remove would
            Some(i) => self.active[i] = sess,
            None => self.active.push(sess),
        }
        Ok(())
    }

    /// `{"cmd":"save"}`: the named session's snapshot is already on disk
    /// (written at hibernation); saving evicts its RAM pages so a client
    /// can detach knowing the parked session costs almost nothing to keep.
    fn handle_save(&mut self, job: Job) {
        let name = job.request.session.clone();
        let id = job.request.id;
        if !valid_session_name(&name) {
            self.reject(job, 0, format!("save requires a valid session name, got {name:?}"));
            return;
        }
        let Some(si) = self.hibernated_index(&name) else {
            let msg = if self.session_is_live(&name) {
                format!("session '{name}' is still running")
            } else {
                format!("unknown session '{name}'")
            };
            self.reject(job, 0, msg);
            return;
        };
        match self.active[si].cache.spill_cold() {
            Ok(_) => {
                let sess = &self.active[si];
                let n_prompt = match &sess.phase {
                    Phase::Hibernated { n_prompt, .. } => *n_prompt,
                    _ => 0,
                };
                let _ = job.reply.send(Response {
                    id,
                    text: String::new(),
                    alts: Vec::new(),
                    n_prompt,
                    n_generated: sess.generated.len(),
                    ttft_ms: 0.0,
                    total_ms: 0.0,
                    kv_ratio: sess.cache.kv_ratio(),
                    prefix_hit: false,
                    error: None,
                    retry_after_ms: None,
                });
            }
            Err(e) => self.reject(job, 0, format!("save failed: {e}")),
        }
    }

    /// Whether a non-hibernated session with this name is active.
    fn session_is_live(&self, name: &str) -> bool {
        self.active.iter().any(|s| {
            !s.is_hibernated()
                && self
                    .groups
                    .get(&s.group)
                    .is_some_and(|g| g.job.request.session == name)
        })
    }

    /// A queued `{"cmd":"resume"}`: wake the named session (in RAM, or
    /// rebuilt from its on-disk snapshot after a restart) and seat it
    /// decoding for `max_new` more tokens. Returns [`Admit::Skip`] to
    /// defer the job in place — seats or budget are tight but other
    /// sessions can still retire (and other queued jobs admit past it).
    fn try_resume_at(&mut self, qi: usize) -> Admit {
        let front = &self.pending[qi].job;
        let name = front.request.session.clone();
        let max_new = front.request.max_new;
        if !valid_session_name(&name) {
            let q = self.pending.remove(qi).unwrap();
            self.reject(q.job, 0, format!("resume requires a valid session name, got {name:?}"));
            return Admit::Progress;
        }
        if self.session_is_live(&name) {
            let q = self.pending.remove(qi).unwrap();
            self.reject(q.job, 0, format!("session '{name}' is still running"));
            return Admit::Progress;
        }
        let si = match self.hibernated_index(&name) {
            Some(si) => si,
            None => match self.revive_from_disk(&name) {
                Ok(Some(si)) => si,
                Ok(None) => {
                    let q = self.pending.remove(qi).unwrap();
                    self.reject(q.job, 0, format!("unknown session '{name}'"));
                    return Admit::Progress;
                }
                Err(e) => {
                    let q = self.pending.remove(qi).unwrap();
                    self.reject(q.job, 0, format!("resume failed: {e}"));
                    return Admit::Progress;
                }
            },
        };
        if self.seats_used() + 1 > self.cfg.max_sessions {
            return Admit::Skip;
        }
        let shape = self.engine.shape();
        let est = self.active[si].cache.spilled_bytes()
            + shape.n_layers as f64 * shape.full_token_bytes() * max_new as f64;
        loop {
            let budget_left = (self.cfg.kv_budget_bytes
                - self.kv_used_bytes()
                - self.reserved_prompt_bytes())
            .max(0.0);
            if est <= budget_left {
                break;
            }
            if self.spill_coldest_hibernated_except(Some(si)) > 0.0 {
                continue;
            }
            if self.has_schedulable() {
                return Admit::Skip;
            }
            break; // bootstrap: wake anyway rather than deadlock the queue
        }
        let q = self.pending.remove(qi).unwrap();
        let enqueue_ms = q.enqueue_ms;
        let deadline_at = q.deadline_at();
        let job = q.job;
        let Phase::Hibernated { name, method, n_prompt, committed, .. } =
            std::mem::replace(&mut self.active[si].phase, Phase::Decoding)
        else {
            unreachable!()
        };
        let ended_on_stop =
            committed && self.active[si].generated.last() == Some(&self.stop);
        if ended_on_stop || self.active[si].pos + 1 >= self.max_seq {
            // the stream already ended (stop token / context limit): reply
            // the full transcript unchanged and park again
            let sess = &mut self.active[si];
            let resp = Response {
                id: job.request.id,
                text: tasks::decode(&sess.generated),
                alts: Vec::new(),
                n_prompt,
                n_generated: sess.generated.len(),
                ttft_ms: 0.0,
                total_ms: 0.0,
                kv_ratio: sess.cache.kv_ratio(),
                prefix_hit: false,
                error: None,
                retry_after_ms: None,
            };
            sess.phase = Phase::Hibernated {
                name,
                method,
                n_prompt,
                committed,
                last_touch: self.round_no,
            };
            let _ = job.reply.send(resp);
            return Admit::Progress;
        }
        let gid = self.next_gid;
        self.next_gid += 1;
        self.groups.insert(gid, Group {
            job,
            n_prompt,
            outputs: vec![None],
            n_generated_primary: 0,
            kv_ratio: 0.0,
            prefix_hit: false,
            remaining: 1,
            t0: Instant::now(),
            ttft_ms: 0.0,
            error: None,
            resumed: true,
            enqueue_ms,
            deadline_at,
            expired: false,
        });
        let sess = &mut self.active[si];
        sess.group = gid;
        sess.cand = 0;
        // `max_new` more tokens on top of what the session already holds
        sess.max_new = sess.generated.len() + max_new;
        sess.skip_commit = committed;
        sess.last_step_round = self.round_no;
        self.lock_metrics().resumed += 1;
        Admit::Progress
    }

    /// Rebuild a hibernated session from its on-disk snapshot (the
    /// post-restart resume path). The revived session enters `active` as
    /// [`Phase::Hibernated`] with every sealed page spilled; the first
    /// decode round faults them back.
    fn revive_from_disk(&mut self, name: &str) -> Result<Option<usize>, String> {
        let Some(store) = self.spill.clone() else { return Ok(None) };
        let Some(blob) = store.load_snapshot(name).map_err(|e| e.to_string())? else {
            return Ok(None);
        };
        let snap = decode_session_snapshot(&blob)?;
        // `ctx.runtime` re-attaches the pool and spill store; the restore
        // below keeps whatever coefficient mode the snapshot was recorded
        // under (build_cache only retargets *empty* caches)
        let mut cache = build_cache(&snap.method, &self.ctx)
            .map_err(|e| format!("snapshot method '{}': {e}", snap.method))?;
        cache.restore_hibernated(&snap.cache_blob)?;
        if cache.tokens() != snap.pos {
            return Err(format!(
                "snapshot inconsistent: cache holds {} tokens, session position is {}",
                cache.tokens(),
                snap.pos
            ));
        }
        let si = self.active.len();
        // the hibernating batcher already counted these tokens
        let counted = snap.generated.len();
        self.active.push(Session {
            group: usize::MAX,
            cand: 0,
            cache,
            pos: snap.pos,
            next_token: snap.next_token,
            generated: snap.generated,
            charges_shared: true,
            from_entry: None,
            max_new: 0,
            phase: Phase::Hibernated {
                name: name.to_string(),
                method: snap.method,
                n_prompt: snap.n_prompt,
                committed: snap.committed,
                last_touch: self.round_no,
            },
            skip_commit: false,
            counted,
            last_step_round: self.round_no,
        });
        Ok(Some(si))
    }
}

// ---------------------------------------------------------------------------
// Session snapshots (scheduler state riding alongside the cache blob)
// ---------------------------------------------------------------------------

/// Magic ("LXSE") + version of the `sess_<name>.lxs` snapshot blob.
const SESS_MAGIC: u32 = 0x4c58_5345;
const SESS_VERSION: u16 = 1;

/// Session names travel in JSON and become file names in the spill dir:
/// restrict to a filesystem-safe alphabet up front.
fn valid_session_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
}

struct SessionSnapshot {
    method: String,
    n_prompt: usize,
    pos: usize,
    next_token: u32,
    committed: bool,
    generated: Vec<u32>,
    cache_blob: Vec<u8>,
}

fn encode_session_snapshot(
    method: &str,
    n_prompt: usize,
    sess: &Session,
    committed: bool,
    cache_blob: &[u8],
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + cache_blob.len() + 4 * sess.generated.len());
    wire::put_u32(&mut buf, SESS_MAGIC);
    wire::put_u16(&mut buf, SESS_VERSION);
    wire::put_str(&mut buf, method);
    wire::put_u32(&mut buf, n_prompt as u32);
    wire::put_u64(&mut buf, sess.pos as u64);
    wire::put_u32(&mut buf, sess.next_token);
    buf.push(committed as u8);
    wire::put_u32s(&mut buf, &sess.generated);
    wire::put_bytes(&mut buf, cache_blob);
    buf
}

fn decode_session_snapshot(blob: &[u8]) -> Result<SessionSnapshot, String> {
    let mut r = wire::Reader::new(blob);
    if r.take_u32()? != SESS_MAGIC {
        return Err("not a session snapshot (bad magic)".into());
    }
    let v = r.take_u16()?;
    if v != SESS_VERSION {
        return Err(format!("unsupported session snapshot version {v}"));
    }
    let method = r.take_str()?;
    let n_prompt = r.take_u32()? as usize;
    let pos = r.take_u64()? as usize;
    let next_token = r.take_u32()?;
    let committed = match r.take_u8()? {
        0 => false,
        1 => true,
        x => return Err(format!("bad committed flag {x}")),
    };
    let generated = r.take_u32s()?;
    let cache_blob = r.take_bytes()?;
    if !r.is_empty() {
        return Err("trailing bytes after session snapshot".into());
    }
    Ok(SessionSnapshot { method, n_prompt, pos, next_token, committed, generated, cache_blob })
}

/// The `n` most likely tokens, descending (ties to the lower id, so index
/// 0 is exactly `argmax` — fan-out candidate 0 is the greedy stream).
fn top_tokens(logits: &[f32], n: usize) -> Vec<u32> {
    let n = n.min(logits.len()).max(1);
    let mut picked = Vec::with_capacity(n);
    let mut used = vec![false; logits.len()];
    for _ in 0..n {
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            if !used[i] && l > bv {
                bv = l;
                best = i;
            }
        }
        used[best] = true;
        picked.push(best as u32);
    }
    picked
}

/// The channel-driven scheduling loop. Runs until the job channel
/// disconnects and all work has drained.
pub fn run(
    engine: Arc<Engine>,
    dicts: Option<Arc<DictionarySet>>,
    cfg: BatcherConfig,
    jobs: Receiver<Job>,
    metrics: Arc<Mutex<Metrics>>,
) -> Result<()> {
    let mut b = Batcher::new(engine, dicts, cfg, metrics);
    'outer: loop {
        // ---- intake ---------------------------------------------------
        loop {
            match if b.has_work() {
                jobs.recv_timeout(Duration::from_millis(0))
            } else {
                jobs.recv().map_err(|_| RecvTimeoutError::Disconnected)
            } {
                Ok(job) => b.enqueue(job),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if !b.has_work() {
                        break 'outer;
                    }
                    break;
                }
            }
        }
        b.round();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheShape;
    use crate::dict::{Dictionary, DictionarySet};
    use crate::model::testutil::tiny_weights;
    use crate::server::Request;
    use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};

    fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
        Arc::new(DictionarySet {
            keys: (0..shape.n_layers)
                .map(|i| Dictionary::random(shape.head_dim, n_atoms, 500 + i as u64))
                .collect(),
            values: (0..shape.n_layers)
                .map(|i| Dictionary::random(shape.head_dim, n_atoms, 700 + i as u64))
                .collect(),
        })
    }

    fn mk_batcher(cfg: BatcherConfig, with_dicts: bool) -> (Batcher, Arc<Mutex<Metrics>>) {
        let engine = Arc::new(Engine::new(tiny_weights(13)));
        let dicts = with_dicts.then(|| tiny_dicts(engine.shape(), 64));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        (Batcher::new(engine, dicts, cfg, metrics.clone()), metrics)
    }

    fn job(id: u64, prompt: &str, max_new: usize) -> (Job, Receiver<Response>) {
        job_with(Request::greedy(id, prompt, max_new, ""))
    }

    fn job_with(request: Request) -> (Job, Receiver<Response>) {
        let (rtx, rrx) = channel();
        (Job::new(request, rtx), rrx)
    }

    fn run_to_completion(b: &mut Batcher, max_rounds: usize) {
        for _ in 0..max_rounds {
            if !b.has_work() {
                return;
            }
            b.round();
        }
        panic!("batcher did not drain in {max_rounds} rounds");
    }

    fn spawn_batcher(cfg: BatcherConfig) -> (Sender<Job>, Arc<Mutex<Metrics>>) {
        let engine = Arc::new(Engine::new(tiny_weights(13)));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (tx, rx) = channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || run(engine, None, cfg, rx, m2));
        (tx, metrics)
    }

    #[test]
    fn serves_concurrent_requests() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, metrics) = spawn_batcher(cfg);
        let mut replies = Vec::new();
        for i in 0..4 {
            let (job, rrx) = job(i, "1+2=", 5);
            tx.send(job).unwrap();
            replies.push(rrx);
        }
        for (i, r) in replies.into_iter().enumerate() {
            let resp = r.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert!(resp.n_generated >= 1);
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.completed, 4);
        assert!(m.tokens_generated >= 4);
    }

    #[test]
    fn served_streams_identical_with_round_shared_qd_on_and_off() {
        // The round-level shared-qd path must be invisible at the serving
        // layer: same mixed-method requests, same continuations, whether
        // the engine groups shared-dictionary caches per round or falls
        // back to per-session attend.
        let serve = |shared_qd: bool| -> Vec<String> {
            let mut engine = Engine::new(tiny_weights(13));
            engine.set_round_shared_qd(shared_qd);
            let engine = Arc::new(engine);
            let dicts = Some(tiny_dicts(engine.shape(), 64));
            let cfg = BatcherConfig {
                default_method: "lexico:s=2,nb=8".into(),
                prefix_entries: 0,
                ..Default::default()
            };
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            let mut b = Batcher::new(engine, dicts, cfg, metrics);
            // mix lexico sessions (shared-qd eligible) with a full-cache
            // session (fallback) in the same rounds
            let specs: [(&str, &str); 4] =
                [("1+2=", ""), ("9*9=", "full"), ("a=3;b=a+4;b?", ""), ("5-2=", "")];
            let mut replies = Vec::new();
            for (i, (p, method)) in specs.iter().enumerate() {
                let (job, rrx) = job_with(Request::greedy(i as u64, *p, 6, *method));
                b.enqueue(job);
                replies.push(rrx);
            }
            run_to_completion(&mut b, 300);
            replies
                .into_iter()
                .map(|r| {
                    let resp = r.recv_timeout(Duration::from_secs(30)).unwrap();
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    resp.text
                })
                .collect()
        };
        assert_eq!(serve(true), serve(false));
    }

    #[test]
    fn dict_refresh_folds_adaptive_overlays_without_changing_streams() {
        // `--dict-refresh 1` folds every adaptive session's overlay atoms
        // into its universal dictionaries each round. The fold keeps the
        // atoms in selection order, so the served token streams must be
        // identical to a run that never refreshes — the only observable
        // difference is the metrics counter. The overlay cap is set high
        // enough that it never binds within the horizon; otherwise a
        // re-armed budget could legitimately change later encodes.
        let run = |refresh: u64| -> (Vec<String>, u64) {
            let engine = Arc::new(Engine::new(tiny_weights(13)));
            // tiny universal dictionaries → residuals routinely exceed the
            // threshold and the overlays actually grow
            let dicts = Some(tiny_dicts(engine.shape(), 8));
            let cfg = BatcherConfig {
                default_method: "lexico:s=2,nb=4,adaptive=4096:0.05".into(),
                dict_refresh: refresh,
                prefix_entries: 0,
                ..Default::default()
            };
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            let mut b = Batcher::new(engine, dicts, cfg, metrics.clone());
            let mut replies = Vec::new();
            for (i, p) in ["1+2=", "a=3;b=a+4;b?", "9*9="].iter().enumerate() {
                let (job, rrx) = job_with(Request::greedy(i as u64, p, 8, ""));
                b.enqueue(job);
                replies.push(rrx);
            }
            run_to_completion(&mut b, 300);
            let texts = replies
                .into_iter()
                .map(|r| {
                    let resp = r.recv_timeout(Duration::from_secs(30)).unwrap();
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    resp.text
                })
                .collect();
            (texts, metrics.lock().unwrap().dict_refresh_atoms)
        };
        let (base, folded_off) = run(0);
        assert_eq!(folded_off, 0, "refresh disabled must fold nothing");
        let (refreshed, folded) = run(1);
        assert_eq!(refreshed, base, "online dictionary refresh changed decode output");
        assert!(folded > 0, "refresh pass never folded an overlay atom");
    }

    #[test]
    fn rejects_too_long_prompt() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, _metrics) = spawn_batcher(cfg);
        let (job, rrx) = job(0, &"a".repeat(4000), 4);
        tx.send(job).unwrap();
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_some());
    }

    #[test]
    fn rejects_oov_prompt_without_crashing() {
        // satellite: a malformed request must become an error reply, not a
        // panic in the batcher thread — and the batcher must keep serving.
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, metrics) = spawn_batcher(cfg);
        let (bad, bad_rx) = job(1, "caf\u{e9} au lait", 4);
        tx.send(bad).unwrap();
        let resp = bad_rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let err = resp.error.expect("OOV prompt must error");
        assert!(err.contains("unsupported character"), "{err}");
        // still alive: a valid request completes afterwards
        let (ok, ok_rx) = job(2, "1+2=", 3);
        tx.send(ok).unwrap();
        let resp = ok_rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(metrics.lock().unwrap().rejected, 1);
    }

    #[test]
    fn per_request_method_override() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, _m) = spawn_batcher(cfg);
        let (job, rrx) =
            job_with(Request::greedy(7, "abc", 3, "pertoken:bits=4,g=8"));
        tx.send(job).unwrap();
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.kv_ratio < 1.0);
    }

    #[test]
    fn budget_exhaustion_defers_admission() {
        // tiny model: full_token_bytes = 2·kvd·2 = 32 B, 2 layers. A
        // "7,3,1>"-ish prompt is ~8 ids; est ≈ 2·(8+6)·32 ≈ 900 B. Budget
        // fits one session but not two.
        let cfg = BatcherConfig {
            default_method: "full".into(),
            kv_budget_bytes: 1000.0,
            prefix_entries: 0,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let (j1, r1) = job(1, "7,3,1>", 6);
        let (j2, r2) = job(2, "2,4,8>", 6);
        b.enqueue(j1);
        b.enqueue(j2);
        b.admit();
        assert_eq!(b.n_active(), 1, "budget admits exactly one");
        assert_eq!(b.n_pending(), 1, "second defers, not rejected");
        // admission charges incrementally: the un-prefilled prompt holds a
        // reservation until its chunks land as real cache bytes
        assert!(b.reserved_prompt_bytes() > 0.0);
        b.advance_prefills();
        assert!(b.kv_used_bytes() > 0.0);
        run_to_completion(&mut b, 64);
        assert!(r1.try_recv().unwrap().error.is_none());
        assert!(r2.try_recv().unwrap().error.is_none());
        assert_eq!(metrics.lock().unwrap().completed, 2);
        assert_eq!(metrics.lock().unwrap().rejected, 0);
    }

    #[test]
    fn max_sessions_cap_holds() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            max_sessions: 2,
            ..Default::default()
        };
        let (mut b, _m) = mk_batcher(cfg, false);
        let mut replies = Vec::new();
        for i in 0..3 {
            let (j, r) = job(i, "1+2=", 4);
            b.enqueue(j);
            replies.push(r);
        }
        b.admit();
        assert_eq!(b.n_active(), 2, "cap must hold");
        assert_eq!(b.n_pending(), 1);
        run_to_completion(&mut b, 64);
        for r in replies {
            assert!(r.try_recv().unwrap().error.is_none());
        }
    }

    #[test]
    fn retirement_frees_budget_that_admits_same_round() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            kv_budget_bytes: 1000.0,
            prefix_entries: 0,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let (j1, _r1) = job(1, "7,3,1>", 3);
        let (j2, _r2) = job(2, "2,4,8>", 3);
        b.enqueue(j1);
        b.enqueue(j2);
        for _ in 0..64 {
            b.round();
            let done = metrics.lock().unwrap().completed;
            if done == 1 {
                // the round that retired job 1 must have re-admitted job 2
                assert_eq!(b.n_pending(), 0, "freed budget must seat the waiter");
                assert_eq!(b.n_active(), 1);
                return;
            }
        }
        panic!("first job never completed");
    }

    #[test]
    fn ttft_and_tpot_metrics_populate() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let prompts = ["1+2=", "k01=v42;k01?", "2,7>", "abc#"];
        let mut replies = Vec::new();
        for (i, p) in prompts.into_iter().enumerate() {
            let (j, r) = job(i as u64, p, 5);
            b.enqueue(j);
            replies.push(r);
        }
        run_to_completion(&mut b, 64);
        for r in replies {
            assert!(r.try_recv().unwrap().error.is_none());
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.ttft_ms.len(), 4, "one TTFT sample per completed request");
        assert!(m.ttft_ms.iter().all(|&t| t >= 0.0));
        assert!(!m.per_token_ms.is_empty(), "TPOT samples from decode rounds");
        assert!(m.tpot().is_some() && m.ttft().is_some());
    }

    #[test]
    fn prefix_hit_prefills_suffix_only_and_charges_shared_once() {
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=2".into(),
            prefix_min_tokens: 4,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg.clone(), true);
        // a long shared prefix (> PAGE_TOKENS compressed tokens) + suffixes
        let prefix: String =
            "k01=v11;k02=v22;k03=v33;k04=v44;k05=v55;k06=v66;k07=v77;k08=v88;".into();
        let (j1, r1) = job(1, &prefix, 2);
        b.enqueue(j1);
        run_to_completion(&mut b, 32);
        let resp1 = r1.try_recv().unwrap();
        assert!(resp1.error.is_none(), "{:?}", resp1.error);
        assert!(!resp1.prefix_hit);
        assert_eq!(b.n_prefix_entries(), 1, "cold prefill inserted the prefix");
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.prefix_misses, 1);
            assert_eq!(m.prefill_tokens, 1 + prefix.chars().count() as u64);
        }

        // second request extends the cached prefix — admission must fork
        // and prefill the suffix only
        let full = format!("{prefix}k03?");
        let (j2, r2) = job(2, &full, 3);
        b.enqueue(j2);
        b.admit();
        assert_eq!(b.n_active(), 1);
        assert_eq!(b.n_prefilling(), 1, "admission only seats; chunks land later");
        b.advance_prefills();
        assert_eq!(b.n_prefilling(), 0, "short suffix lands in one chunk");
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.prefix_hits, 1, "second request must hit");
            let expect = 1 + prefix.chars().count() as u64 + 4; // cold + "k03?"
            assert_eq!(m.prefill_tokens, expect, "suffix-only prefill work");
            assert!(m.shared_bytes > 0.0, "lexico fork shares CSR pages");
        }
        // shared bytes charged once: the session's charge excludes what the
        // prototype already charges
        let sess = &b.active[0];
        assert!(!sess.charges_shared);
        let shared = sess.cache.shared_prefix_bytes();
        assert!(shared > 0.0);
        let naive = b.prefix.resident_bytes() + sess.cache.mem_bytes();
        assert!(
            (b.kv_used_bytes() - (naive - shared)).abs() < 1e-6,
            "shared prefix bytes must be charged exactly once"
        );
        run_to_completion(&mut b, 64);
        let resp2 = r2.try_recv().unwrap();
        assert!(resp2.error.is_none(), "{:?}", resp2.error);
        assert!(resp2.prefix_hit);

        // fork parity end-to-end: a cold batcher (prefix cache disabled)
        // must produce the identical continuation for the same request
        let (mut cold, _m2) = mk_batcher(
            BatcherConfig { prefix_entries: 0, ..cfg },
            true,
        );
        let (j3, r3) = job(3, &full, 3);
        cold.enqueue(j3);
        run_to_completion(&mut cold, 64);
        let resp3 = r3.try_recv().unwrap();
        assert_eq!(resp2.text, resp3.text, "prefix-cache hit altered tokens");
    }

    #[test]
    fn exact_prefix_hit_does_zero_prefill_work() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            prefix_min_tokens: 4,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let (j1, _r1) = job(1, "1+2=3;4+5=", 2);
        b.enqueue(j1);
        run_to_completion(&mut b, 32);
        let cold_tokens = metrics.lock().unwrap().prefill_tokens;
        let (j2, r2) = job(2, "1+2=3;4+5=", 2);
        b.enqueue(j2);
        run_to_completion(&mut b, 32);
        let m = metrics.lock().unwrap();
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefill_tokens, cold_tokens, "identical prompt → zero new prefill");
        drop(m);
        assert!(r2.try_recv().unwrap().error.is_none());
    }

    #[test]
    fn entry_eviction_promotes_a_surviving_fork_to_charge_shared_pages() {
        // two fan-out candidates fork an entry's pages; when the entry is
        // evicted, exactly one surviving fork must take over the charge so
        // kv_used_bytes counts the shared pages once, not zero times.
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=2".into(),
            prefix_min_tokens: 6, // suffix below this → no second entry
            ..Default::default()
        };
        let (mut b, _m) = mk_batcher(cfg, true);
        let prefix: String =
            "k01=v11;k02=v22;k03=v33;k04=v44;k05=v55;k06=v66;k07=v77;k08=v88;".into();
        let (j1, _r1) = job(1, &prefix, 2);
        b.enqueue(j1);
        run_to_completion(&mut b, 32);
        assert_eq!(b.n_prefix_entries(), 1);

        let (j2, _r2) = job_with(Request {
            fanout: 2,
            ..Request::greedy(2, format!("{prefix}k05?"), 8, "")
        });
        b.enqueue(j2);
        b.admit();
        b.advance_prefills();
        assert_eq!(b.n_active(), 2);
        assert_eq!(b.n_prefix_entries(), 1, "short suffix must not insert");
        assert!(b.active.iter().all(|s| !s.charges_shared));

        let evicted = b.prefix.evict_lru_except(None).unwrap();
        b.promote_entry_owner(evicted);
        let owners = b.active.iter().filter(|s| s.charges_shared).count();
        assert_eq!(owners, 1, "exactly one surviving fork takes the charge");
        // the sealed pages are still shared between the two forks...
        let shared = b.active[0].cache.shared_prefix_bytes();
        assert!(shared > 0.0);
        assert_eq!(shared, b.active[1].cache.shared_prefix_bytes());
        // ...and the budget now charges them exactly once
        let total_mem: f64 = b.active.iter().map(|s| s.cache.mem_bytes()).sum();
        assert!(
            (b.kv_used_bytes() - (total_mem - shared)).abs() < 1e-6,
            "pages must be charged once after the entry is gone"
        );
    }

    #[test]
    fn fanout_decodes_candidates_in_one_round_and_returns_alts() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (mut b, metrics) = mk_batcher(cfg.clone(), false);
        let (j, r) = job_with(Request { fanout: 3, ..Request::greedy(9, "2,7,4>", 4, "") });
        b.enqueue(j);
        b.admit();
        b.advance_prefills();
        assert_eq!(b.n_active(), 3, "one prefill seats all candidates");
        run_to_completion(&mut b, 64);
        let resp = r.try_recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.alts.len(), 2);
        assert_eq!(metrics.lock().unwrap().fanout_sessions, 2);
        assert_eq!(metrics.lock().unwrap().completed, 1);

        // the primary stream must be exactly the greedy (fanout = 1) stream
        let (mut b1, _m) = mk_batcher(
            BatcherConfig { default_method: "full".into(), ..Default::default() },
            false,
        );
        let (j1, r1) = job(10, "2,7,4>", 4);
        b1.enqueue(j1);
        run_to_completion(&mut b1, 64);
        assert_eq!(resp.text, r1.try_recv().unwrap().text);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_for_every_chunk_size() {
        // The whole serving path — admission, prefix cache, fan-out,
        // decode rounds — must produce byte-identical responses at every
        // chunk size (the prefill_chunk determinism contract).
        let reqs = || {
            vec![
                Request::greedy(1, "k01=v11;k02=v22;k03=v33;k04=v44;k05=v55;", 6, ""),
                Request::greedy(2, "k01=v11;k02=v22;k03=v33;k04=v44;k05=v55;k02?", 6, ""),
                Request::greedy(3, "1+2=", 5, "full"),
                Request { fanout: 3, ..Request::greedy(4, "2,7,4>", 5, "") },
            ]
        };
        let run = |chunk: usize| -> Vec<Response> {
            let cfg = BatcherConfig {
                default_method: "lexico:s=2,nb=2".into(),
                prefix_min_tokens: 4,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let (mut b, _metrics) = mk_batcher(cfg, true);
            let mut replies = Vec::new();
            for r in reqs() {
                let (j, rx) = job_with(r);
                b.enqueue(j);
                replies.push(rx);
            }
            run_to_completion(&mut b, 256);
            replies.into_iter().map(|r| r.try_recv().expect("reply pending")).collect()
        };
        let reference = run(0); // monolithic: the whole prompt in one chunk
        for chunk in [1usize, 7, 256] {
            let got = run(chunk);
            assert_eq!(got.len(), reference.len());
            for (g, want) in got.iter().zip(&reference) {
                assert!(g.error.is_none(), "C={chunk}: {:?}", g.error);
                assert_eq!(g.text, want.text, "C={chunk}: primary stream diverged");
                assert_eq!(g.alts, want.alts, "C={chunk}: alternates diverged");
                assert_eq!(g.n_generated, want.n_generated, "C={chunk}");
            }
        }
    }

    #[test]
    fn concurrent_identical_prompts_share_one_prefill() {
        // The shared-system-prompt burst: a request whose prompt extends a
        // prompt currently prefilling (and destined for the prefix cache)
        // waits in the FIFO and resumes as a hit — one cold prefill total.
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=2".into(),
            prefix_min_tokens: 4,
            prefill_chunk: 4,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, true);
        let prompt = "k01=v11;k02=v22;k03=v33;k04=v44;";
        let (j1, r1) = job(1, prompt, 2);
        let (j2, r2) = job(2, prompt, 2);
        b.enqueue(j1);
        b.enqueue(j2);
        b.admit();
        assert_eq!(b.n_active(), 1, "follower must wait for the in-flight prefill");
        assert_eq!(b.n_pending(), 1);
        run_to_completion(&mut b, 128);
        {
            let m = metrics.lock().unwrap();
            assert_eq!(m.prefix_misses, 1, "only the first request runs cold");
            assert_eq!(m.prefix_hits, 1, "the follower resumes as a prefix hit");
            // identical prompt → exact hit → zero extra prefill work
            assert_eq!(m.prefill_tokens, 1 + prompt.chars().count() as u64);
        }
        assert_eq!(r1.try_recv().unwrap().text, r2.try_recv().unwrap().text);
    }

    #[test]
    fn snapkv_is_prefilled_monolithically_under_chunking() {
        // Non-split-exact backends (observation-window score state) must
        // see the whole prompt in one ingest regardless of the chunk
        // budget — and therefore produce the monolithic stream.
        let run = |chunk: usize| -> (Response, u64) {
            let cfg = BatcherConfig {
                default_method: "snapkv:cap=24,win=4".into(),
                prefix_entries: 0,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let (mut b, metrics) = mk_batcher(cfg, false);
            let (j, r) = job(1, "k01=v11;k02=v22;k03=v33;k04=v44;k01?", 5);
            b.enqueue(j);
            run_to_completion(&mut b, 64);
            let max_round = metrics.lock().unwrap().max_round_prefill_tokens;
            (r.try_recv().unwrap(), max_round)
        };
        let (mono, _) = run(0);
        let (chunked, max_round) = run(3);
        assert!(mono.error.is_none(), "{:?}", mono.error);
        assert_eq!(mono.text, chunked.text, "snapkv must ignore the chunk budget");
        assert!(max_round > 3, "snapkv prompt must land monolithically, saw {max_round}");
    }

    #[test]
    fn chunked_admission_keeps_decode_rounds_bounded() {
        // The TPOT-cliff guard: a long prompt admitted against active
        // decode sessions must land one budgeted chunk per round, never
        // stalling the decode cadence. Deterministic asserts catch the
        // monolithic regression (chunk budget + window round count); the
        // wall-clock median bounds per-chunk stalls at 2× the steady p50.
        let cfg = BatcherConfig {
            default_method: "full".into(),
            prefill_chunk: 4,
            prefix_entries: 0,
            max_sessions: 16,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let mut replies = Vec::new();
        let short_prompts =
            ["1+2=", "2,7,4>", "k01=v11;k01?", "abc#", "7,3,1>", "4+5=", "k02=v22;k02?", "xyz#"];
        for (i, p) in short_prompts.into_iter().enumerate() {
            let (j, r) = job(i as u64, p, 100);
            b.enqueue(j);
            replies.push(r);
        }
        // full-round wall time: metrics.decode_round_ms times only the
        // decode_batch call, but the stall we bound includes chunk work
        let mut steady_ms = Vec::new();
        for _ in 0..12 {
            let t0 = Instant::now();
            b.round();
            steady_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let p50_before = crate::util::stats::summarize(&steady_ms).p50;

        // one long prompt admitted mid-stream
        let long_prompt = "k01=v11;k02=v22;k03=v33;k04=v44;".repeat(3); // 96 chars
        let (jl, rl) = job(99, &long_prompt, 2);
        b.enqueue(jl);
        b.admit();
        assert_eq!(b.n_prefilling(), 1);
        let mut prefill_rounds = 0usize;
        let mut window_ms = Vec::new();
        while b.n_prefilling() > 0 {
            let t0 = Instant::now();
            b.round();
            window_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            prefill_rounds += 1;
            assert!(prefill_rounds < 64, "prefill never completed");
        }
        {
            let m = metrics.lock().unwrap();
            // the deterministic invariants: no round ever consumed more
            // than one chunk of prompt, so the admission was spread over
            // ceil(97/4) interleaved rounds instead of stalling one (a
            // monolithic regression collapses the window to one round and
            // trips the round-count assert)
            assert!(
                m.max_round_prefill_tokens <= 4,
                "a round exceeded the chunk budget: {}",
                m.max_round_prefill_tokens
            );
            assert!(prefill_rounds >= 97 / 4, "prompt landed too fast: {prefill_rounds} rounds");
        }
        // wall clock: the admission window's TYPICAL round must stay
        // within 2× the no-admission p50 (median, not max — a single
        // scheduler preemption on a loaded CI runner spikes one
        // microsecond-scale round without meaning a stall, while a real
        // per-chunk stall raises every window round and the median with
        // it). The absolute slack absorbs timer noise at this scale.
        let p50_during = crate::util::stats::summarize(&window_ms).p50;
        assert!(
            p50_during <= 2.0 * p50_before + 0.25,
            "decode rounds stalled during chunked admission: window p50 {p50_during:.3} ms \
             vs steady p50 {p50_before:.3} ms"
        );
        run_to_completion(&mut b, 400);
        assert!(rl.try_recv().unwrap().error.is_none());
        for r in replies {
            assert!(r.try_recv().unwrap().error.is_none());
        }
    }

    #[test]
    fn cancelled_job_retires_sessions_and_frees_budget_same_round() {
        // find a prompt whose session survives a few rounds (streams are
        // deterministic, so this is a fixed choice — the loop just avoids
        // hard-coding which prompt decodes long under the tiny weights)
        for prompt in ["k01=v11;k02?", "1+2=", "2,7,4>", "abc#"] {
            let cfg = BatcherConfig {
                default_method: "full".into(),
                prefix_entries: 0,
                ..Default::default()
            };
            let (mut b, metrics) = mk_batcher(cfg, false);
            let (j, r) = job(1, prompt, 50);
            let cancel = j.cancel.clone();
            b.enqueue(j);
            for _ in 0..4 {
                b.round();
            }
            if b.n_active() == 0 {
                continue; // stream stopped early; try the next prompt
            }
            assert!(b.kv_used_bytes() > 0.0);
            cancel.store(true, Ordering::SeqCst);
            b.round();
            assert_eq!(b.n_active(), 0, "cancelled session must retire in one round");
            assert_eq!(b.kv_used_bytes(), 0.0, "bytes must return to the budget");
            assert_eq!(metrics.lock().unwrap().cancelled, 1);
            let resp = r.try_recv().unwrap();
            assert!(resp.error.expect("cancelled reply is an error").contains("cancelled"));
            return;
        }
        panic!("no prompt survived 4 rounds");
    }

    #[test]
    fn streaming_deltas_concatenate_to_the_final_text() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let (rtx, rrx) = channel();
        let (stx, srx) = sync_channel(crate::server::STREAM_BUFFER);
        let mut j = Job::new(Request::greedy(5, "1+2=", 8, ""), rtx);
        j.stream = Some(stx);
        b.enqueue(j);
        run_to_completion(&mut b, 64);
        let resp = rrx.try_recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let deltas: Vec<StreamDelta> = srx.try_iter().collect();
        assert_eq!(deltas.len(), resp.n_generated, "one delta per generated token");
        for (i, d) in deltas.iter().enumerate() {
            assert_eq!(d.i, i, "deltas arrive in stream order");
            assert_eq!(d.id, 5);
        }
        let concat: String = deltas.iter().map(|d| d.token.as_str()).collect();
        assert_eq!(concat, resp.text, "streamed tokens must reproduce the final text");
        assert_eq!(metrics.lock().unwrap().streamed_tokens, resp.n_generated as u64);
    }

    #[test]
    fn top_tokens_orders_by_logit_and_matches_argmax() {
        let logits = [0.1f32, 3.0, 2.0, 3.0, -1.0];
        assert_eq!(top_tokens(&logits, 3), vec![1, 3, 2]);
        assert_eq!(top_tokens(&logits, 1)[0] as usize, argmax(&logits));
        assert_eq!(top_tokens(&logits, 99).len(), 5);
    }

    #[test]
    fn prefix_cache_longest_match_and_lru() {
        let mut pc = PrefixCache::new(2);
        let mk_state = |ids: &[u32]| PrefixState {
            tokens: ids.to_vec(),
            ks: vec![vec![0.0; ids.len()]],
            vs: vec![vec![0.0; ids.len()]],
            logits: vec![0.0; 4],
        };
        let shape = CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 8 };
        let proto = || -> Box<dyn KvCache> { Box::new(crate::cache::full::FullCache::new(shape)) };
        pc.insert("full".into(), mk_state(&[1, 2]), proto());
        pc.insert("full".into(), mk_state(&[1, 2, 3, 4]), proto());
        // longest match wins
        let hit = pc.lookup("full", &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(pc.entries[hit].state.tokens, vec![1, 2, 3, 4]);
        // method must match
        assert!(pc.lookup("kivi:bits=2", &[1, 2, 3]).is_none());
        // non-prefix must miss
        assert!(pc.lookup("full", &[2, 2, 3]).is_none());
        // duplicate insert is a no-op
        pc.insert("full".into(), mk_state(&[1, 2]), proto());
        assert_eq!(pc.entries.len(), 2);
        // capacity evicts the LRU ([1,2] was hit less recently than [1,2,3,4])
        let _ = pc.lookup("full", &[1, 2, 3, 4, 5]);
        pc.insert("full".into(), mk_state(&[9, 9, 9]), proto());
        assert_eq!(pc.entries.len(), 2);
        assert!(pc.lookup("full", &[1, 2]).is_none(), "LRU entry evicted");
        assert!(pc.lookup("full", &[1, 2, 3, 4]).is_some());
    }

    // ---- tiered residency: hibernate / save / resume ---------------------

    /// Long enough (45 chars + BOS, plus generated tokens) that the lexico
    /// cache seals at least one CSR page past its recency buffer — so
    /// hibernation and `save` have pages to actually spill.
    const LONG_PROMPT: &str = "k01=v11;k02=v12;k03=v13;k04=v14;k05=v15;k01?";

    fn tmp_spill(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lexico_batcher_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn spill_cfg(dir: &std::path::Path) -> BatcherConfig {
        BatcherConfig {
            default_method: "lexico:s=2,nb=8".into(),
            spill_dir: Some(dir.to_path_buf()),
            ..Default::default()
        }
    }

    fn named_job(id: u64, prompt: &str, max_new: usize, sess: &str) -> (Job, Receiver<Response>) {
        let mut req = Request::greedy(id, prompt, max_new, "");
        req.session = sess.into();
        job_with(req)
    }

    fn verb_job(id: u64, sess: &str, verb: SessionVerb, max_new: usize) -> (Job, Receiver<Response>) {
        let mut req = Request::greedy(id, "", max_new, "");
        req.session = sess.into();
        req.verb = verb;
        job_with(req)
    }

    #[test]
    fn named_session_save_resume_matches_uninterrupted_run() {
        // uninterrupted reference: one request for the full token budget
        let dir_ref = tmp_spill("resume_ref");
        let (mut b, _) = mk_batcher(spill_cfg(&dir_ref), true);
        let (j, r) = job(1, LONG_PROMPT, 10);
        b.enqueue(j);
        run_to_completion(&mut b, 400);
        let full = r.recv().unwrap();
        assert!(full.error.is_none(), "{:?}", full.error);

        // the same stream split: 2 tokens under a session name, save
        // (evict to disk), resume for 8 more
        let dir = tmp_spill("resume_split");
        let (mut b2, m2) = mk_batcher(spill_cfg(&dir), true);
        let (j, r) = named_job(2, LONG_PROMPT, 2, "chat-1");
        b2.enqueue(j);
        run_to_completion(&mut b2, 400);
        let part = r.recv().unwrap();
        assert!(part.error.is_none(), "{:?}", part.error);
        assert_eq!(b2.n_hibernated(), 1, "named session must park, not retire");
        assert_eq!(b2.n_active(), 0);

        let (j, r) = verb_job(3, "chat-1", SessionVerb::Save, 0);
        b2.enqueue(j);
        b2.round();
        let saved = r.recv().unwrap();
        assert!(saved.error.is_none(), "{:?}", saved.error);
        assert!(
            lock_tolerant(&m2).spilled_pages > 0,
            "save must evict the parked session's sealed pages"
        );

        let (j, r) = verb_job(4, "chat-1", SessionVerb::Resume, 8);
        b2.enqueue(j);
        run_to_completion(&mut b2, 400);
        let resumed = r.recv().unwrap();
        assert!(resumed.error.is_none(), "{:?}", resumed.error);
        assert_eq!(resumed.text, full.text, "resumed continuation diverged");
        assert_eq!(resumed.n_generated, full.n_generated);
        assert_eq!(b2.n_hibernated(), 1, "the resumed session parks again");
        let m = lock_tolerant(&m2);
        assert_eq!(m.resumed, 1);
        if full.n_generated > 2 {
            assert!(m.faults > 0, "resume past the save must fault pages back");
        }
    }

    #[test]
    fn hibernated_session_survives_a_batcher_restart() {
        let dir_ref = tmp_spill("restart_ref");
        let (mut b, _) = mk_batcher(spill_cfg(&dir_ref), true);
        let (j, r) = job(1, LONG_PROMPT, 10);
        b.enqueue(j);
        run_to_completion(&mut b, 400);
        let full = r.recv().unwrap();
        assert!(full.error.is_none(), "{:?}", full.error);

        let dir = tmp_spill("restart");
        {
            let (mut a, _) = mk_batcher(spill_cfg(&dir), true);
            let (j, r) = named_job(2, LONG_PROMPT, 2, "boot");
            a.enqueue(j);
            run_to_completion(&mut a, 400);
            assert!(r.recv().unwrap().error.is_none());
        } // batcher dropped — only the on-disk snapshot survives

        let (mut b2, m2) = mk_batcher(spill_cfg(&dir), true);
        assert_eq!(b2.n_hibernated(), 0);
        let (j, r) = verb_job(3, "boot", SessionVerb::Resume, 8);
        b2.enqueue(j);
        run_to_completion(&mut b2, 400);
        let resumed = r.recv().unwrap();
        assert!(resumed.error.is_none(), "{:?}", resumed.error);
        assert_eq!(resumed.text, full.text, "post-restart continuation diverged");
        assert_eq!(resumed.n_generated, full.n_generated);
        if full.n_generated > 2 {
            assert!(lock_tolerant(&m2).faults > 0, "revived pages must fault from disk");
        }
    }

    #[test]
    fn resume_of_unknown_or_invalid_sessions_is_rejected() {
        let dir = tmp_spill("unknown");
        let (mut b, _) = mk_batcher(spill_cfg(&dir), true);
        let (j, r) = verb_job(1, "nope", SessionVerb::Resume, 4);
        b.enqueue(j);
        b.round();
        assert!(r.recv().unwrap().error.unwrap().contains("unknown session"));
        let (j, r) = verb_job(2, "../etc/passwd", SessionVerb::Resume, 4);
        b.enqueue(j);
        b.round();
        assert!(r.recv().unwrap().error.unwrap().contains("valid session name"));
        let (j, r) = verb_job(3, "nope", SessionVerb::Save, 0);
        b.enqueue(j);
        b.round();
        assert!(r.recv().unwrap().error.unwrap().contains("unknown session"));
        // fan-out on a named session is rejected up front
        let (j, r) = job_with(Request {
            fanout: 3,
            session: "s".into(),
            ..Request::greedy(4, "1+2=", 4, "")
        });
        b.enqueue(j);
        b.round();
        assert!(r.recv().unwrap().error.unwrap().contains("fan out"));
    }

    #[test]
    fn residency_pressure_spills_hibernated_sessions() {
        let dir = tmp_spill("pressure");
        let mut cfg = spill_cfg(&dir);
        cfg.resident_budget_bytes = 1.0; // practically zero: all cold bytes must go
        let (mut b, m) = mk_batcher(cfg, true);
        let (j, r) = named_job(1, LONG_PROMPT, 2, "cold");
        b.enqueue(j);
        run_to_completion(&mut b, 400);
        assert!(r.recv().unwrap().error.is_none());
        assert_eq!(b.n_hibernated(), 1);
        let m = lock_tolerant(&m);
        assert!(m.spilled_pages > 0, "residency pressure must spill the parked session");
        assert!(m.spill_bytes > 0.0);
        assert_eq!(m.hibernated_sessions, 1);
    }

    #[test]
    fn corrupt_page_file_fails_the_resume_cleanly_and_server_survives() {
        let dir = tmp_spill("corrupt");
        let (mut b, _) = mk_batcher(spill_cfg(&dir), true);
        let (j, r) = named_job(1, LONG_PROMPT, 2, "frag");
        b.enqueue(j);
        run_to_completion(&mut b, 400);
        let first = r.recv().unwrap();
        assert!(first.error.is_none(), "{:?}", first.error);
        if first.text.ends_with('\n') {
            return; // stream already hit the stop token; a resume would not decode
        }
        // evict the pages, then corrupt the page file on disk
        let (j, r) = verb_job(2, "frag", SessionVerb::Save, 0);
        b.enqueue(j);
        b.round();
        assert!(r.recv().unwrap().error.is_none());
        let pages = dir.join("pages.lxp");
        let mut bytes = std::fs::read(&pages).unwrap();
        assert!(!bytes.is_empty(), "save left no pages on disk");
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&pages, &bytes).unwrap();

        let (j, r) = verb_job(3, "frag", SessionVerb::Resume, 4);
        b.enqueue(j);
        run_to_completion(&mut b, 400);
        let resp = r.recv().unwrap();
        let err = resp.error.expect("corrupt pages must fail the resume with an error reply");
        assert!(err.contains("fault"), "{err}");

        // the batcher keeps serving after the failed fault
        let (j, r) = job(4, "1+2=", 3);
        b.enqueue(j);
        run_to_completion(&mut b, 400);
        assert!(r.recv().unwrap().error.is_none());
    }

    // ---- SLO-aware multi-tenant admission + graceful overload ------------

    fn pri_job(id: u64, prompt: &str, max_new: usize, pri: i64) -> (Job, Receiver<Response>) {
        job_with(Request { priority: pri, ..Request::greedy(id, prompt, max_new, "") })
    }

    #[test]
    fn higher_priority_admits_before_an_earlier_low_priority_job() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            max_sessions: 1,
            prefix_entries: 0,
            ..Default::default()
        };
        let (mut b, _m) = mk_batcher(cfg, false);
        let (lo, lo_rx) = pri_job(1, "1+2=", 3, 0);
        let (hi, hi_rx) = pri_job(2, "4+5=", 3, 5);
        b.enqueue(lo);
        b.enqueue(hi);
        b.admit();
        assert_eq!(b.n_active(), 1);
        assert_eq!(b.n_pending(), 1);
        let gid = b.active[0].group;
        assert_eq!(b.groups[&gid].job.request.id, 2, "higher priority takes the seat");
        run_to_completion(&mut b, 64);
        assert!(hi_rx.try_recv().unwrap().error.is_none());
        assert!(lo_rx.try_recv().unwrap().error.is_none(), "low priority still completes");
    }

    #[test]
    fn tenant_seat_quota_defers_without_rejecting() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            prefix_entries: 0,
            tenant_quotas: TenantQuotas::parse("free=seats:1").unwrap(),
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let mk = |id: u64, tenant: &str| {
            job_with(Request { tenant: tenant.into(), ..Request::greedy(id, "1+2=", 3, "") })
        };
        let (j1, r1) = mk(1, "free");
        let (j2, r2) = mk(2, "free");
        let (j3, r3) = mk(3, "pro");
        b.enqueue(j1);
        b.enqueue(j2);
        b.enqueue(j3);
        b.admit();
        assert_eq!(b.n_active(), 2, "one free seat + the unlimited pro tenant");
        assert_eq!(b.n_pending(), 1, "over-quota free job waits, not rejected");
        run_to_completion(&mut b, 128);
        for r in [r1, r2, r3] {
            assert!(r.try_recv().unwrap().error.is_none());
        }
        assert_eq!(lock_tolerant(&metrics).rejected, 0);
    }

    #[test]
    fn queue_overflow_sheds_lowest_priority_newest_first() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            max_queue: 2,
            prefix_entries: 0,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let (j1, r1) = pri_job(1, "1+2=", 3, 5);
        let (j2, r2) = pri_job(2, "4+5=", 3, 0);
        let (j3, r3) = pri_job(3, "2,7>", 3, 0);
        b.enqueue(j1);
        b.enqueue(j2);
        b.enqueue(j3); // overflow: lowest class, newest arrival goes first
        let shed3 = r3.try_recv().unwrap();
        assert_eq!(shed3.error.as_deref(), Some("overloaded"));
        assert!(shed3.retry_after_ms.unwrap() > 0, "shed reply carries a backoff hint");
        let (j4, r4) = pri_job(4, "abc#", 3, 7);
        b.enqueue(j4); // overflow again: j2 is now the lowest class
        let shed2 = r2.try_recv().unwrap();
        assert_eq!(shed2.error.as_deref(), Some("overloaded"));
        assert!(shed2.retry_after_ms.unwrap() > 0);
        assert_eq!(lock_tolerant(&metrics).shed_prefills, 2);
        run_to_completion(&mut b, 64);
        assert!(r1.try_recv().unwrap().error.is_none(), "high priority survives the shed");
        assert!(r4.try_recv().unwrap().error.is_none());
    }

    #[test]
    fn queued_job_past_its_deadline_expires_at_round_top() {
        let cfg = BatcherConfig {
            default_method: "full".into(),
            prefix_entries: 0,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        b.set_manual_time(0.0);
        let (j, r) = job_with(Request { deadline_ms: 10, ..Request::greedy(1, "1+2=", 4, "") });
        b.enqueue(j);
        assert_eq!(b.n_pending(), 1);
        b.set_manual_time(20.0);
        b.round();
        assert_eq!(b.n_pending(), 0, "expired job leaves the queue");
        assert_eq!(b.n_active(), 0, "it must never seat");
        assert_eq!(lock_tolerant(&metrics).deadline_expired, 1);
        let resp = r.try_recv().unwrap();
        assert_eq!(resp.error.as_deref(), Some("deadline_expired"));
    }

    #[test]
    fn active_session_past_its_deadline_frees_budget_same_round() {
        // same prompt-probe loop as the cancellation test: find a stream
        // that survives a few rounds under the tiny weights
        for prompt in ["k01=v11;k02?", "1+2=", "2,7,4>", "abc#"] {
            let cfg = BatcherConfig {
                default_method: "full".into(),
                prefix_entries: 0,
                ..Default::default()
            };
            let (mut b, metrics) = mk_batcher(cfg, false);
            b.set_manual_time(0.0);
            let (j, r) =
                job_with(Request { deadline_ms: 1000, ..Request::greedy(1, prompt, 50, "") });
            b.enqueue(j);
            for _ in 0..4 {
                b.round();
            }
            if b.n_active() == 0 {
                continue; // stream stopped early; try the next prompt
            }
            assert!(b.kv_used_bytes() > 0.0);
            b.set_manual_time(2000.0);
            b.round();
            assert_eq!(b.n_active(), 0, "expired session must retire in one round");
            assert_eq!(b.kv_used_bytes(), 0.0, "bytes must return to the budget");
            assert_eq!(lock_tolerant(&metrics).deadline_expired, 1);
            let resp = r.try_recv().unwrap();
            assert_eq!(resp.error.as_deref(), Some("deadline_expired"));
            return;
        }
        panic!("no prompt survived 4 rounds");
    }

    #[test]
    fn decode_batch_cap_changes_pacing_but_never_tokens() {
        let run = |cap: usize| -> (Vec<String>, u64) {
            let cfg = BatcherConfig {
                default_method: "full".into(),
                prefix_entries: 0,
                max_decode_batch: cap,
                ..Default::default()
            };
            let (mut b, _m) = mk_batcher(cfg, false);
            let (lo, lo_rx) = pri_job(1, "2,7,4>", 6, 0);
            let (hi, hi_rx) = pri_job(2, "1+2=", 6, 5);
            b.enqueue(lo);
            b.enqueue(hi);
            let mut lo_resp = None;
            let mut hi_resp = None;
            let mut first_done = 0u64;
            for _ in 0..256 {
                if !b.has_work() {
                    break;
                }
                b.round();
                if lo_resp.is_none() {
                    if let Ok(resp) = lo_rx.try_recv() {
                        lo_resp = Some(resp);
                        if first_done == 0 {
                            first_done = 1;
                        }
                    }
                }
                if hi_resp.is_none() {
                    if let Ok(resp) = hi_rx.try_recv() {
                        hi_resp = Some(resp);
                        if first_done == 0 {
                            first_done = 2;
                        }
                    }
                }
            }
            let lo_resp = lo_resp.expect("low-priority reply pending");
            let hi_resp = hi_resp.expect("high-priority reply pending");
            assert!(lo_resp.error.is_none() && hi_resp.error.is_none());
            (vec![hi_resp.text, lo_resp.text], first_done)
        };
        let (ref_texts, _) = run(0); // uncapped reference
        let (cap_texts, first_done) = run(1);
        assert_eq!(cap_texts, ref_texts, "the cap must change pacing only, never tokens");
        assert_eq!(first_done, 2, "strict priority: the high-priority stream finishes first");
    }

    #[test]
    fn poisoned_metrics_lock_leaves_rounds_and_report_serving() {
        // regression for the lock_tolerant sweep: a panic while holding the
        // metrics lock (what a crashed round leaves behind) must not take
        // down later rounds or the `{"cmd":"metrics"}` report path
        let cfg = BatcherConfig {
            default_method: "full".into(),
            prefix_entries: 0,
            ..Default::default()
        };
        let (mut b, metrics) = mk_batcher(cfg, false);
        let m2 = metrics.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("deliberate: poison the metrics lock");
        })
        .join();
        assert!(metrics.lock().is_err(), "the lock must actually be poisoned");
        let (j, r) = job(1, "1+2=", 3);
        b.enqueue(j);
        run_to_completion(&mut b, 64);
        assert!(r.try_recv().unwrap().error.is_none());
        let m = lock_tolerant(&metrics);
        assert_eq!(m.completed, 1);
        assert!(m.report().contains("completed=1"), "report still renders");
    }

    #[test]
    fn slow_reader_clamps_its_stream_but_gets_the_full_final_text() {
        for prompt in ["k01=v11;k02?", "1+2=", "2,7,4>", "abc#"] {
            let cfg = BatcherConfig {
                default_method: "full".into(),
                prefix_entries: 0,
                ..Default::default()
            };
            let (mut b, metrics) = mk_batcher(cfg, false);
            let (rtx, rrx) = channel();
            let (stx, srx) = sync_channel(2); // a reader that never drains
            let mut j = Job::new(Request::greedy(9, prompt, 50, ""), rtx);
            j.stream = Some(stx);
            b.enqueue(j);
            run_to_completion(&mut b, 256);
            let resp = rrx.try_recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            if resp.n_generated <= 2 {
                continue; // too short to overflow the buffer; next prompt
            }
            let deltas: Vec<StreamDelta> = srx.try_iter().collect();
            assert_eq!(deltas.len(), 2, "buffer capacity bounds the live stream");
            for (i, d) in deltas.iter().enumerate() {
                assert_eq!(d.i, i, "surviving deltas stay in stream order");
            }
            let concat: String = deltas.iter().map(|d| d.token.as_str()).collect();
            assert!(resp.text.starts_with(&concat));
            let m = lock_tolerant(&metrics);
            assert_eq!(m.streamed_tokens, 2);
            assert_eq!(m.stream_clamped, resp.n_generated as u64 - 2);
            return;
        }
        panic!("no prompt generated more than 2 tokens");
    }
}
