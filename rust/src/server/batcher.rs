//! Iteration-level (continuous) batching with KV-budget admission control.
//!
//! The scheduling loop mirrors Orca/vLLM: each round first *admits* pending
//! requests while the KV-memory budget allows (running their prefill), then
//! advances every active session by exactly one token through a single
//! layer-major [`Engine::decode_batch`] call (weights stream once per layer
//! per round, not once per session), retiring sessions that emit the stop
//! token or exhaust their budget. Lexico's smaller per-token KV footprint
//! directly raises the number of concurrent sessions the budget admits —
//! the paper's memory-bound serving argument — and the batched round is
//! what turns those extra sessions into throughput.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::{Job, Response};
use crate::cache::factory::{build_cache, CacheContext};
use crate::cache::KvCache;
use crate::dict::DictionarySet;
use crate::model::Engine;
use crate::tasks;
use crate::tensor::argmax;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// default cache method for requests that don't specify one
    pub default_method: String,
    /// total KV budget across sessions, bytes (FP16-equivalent accounting)
    pub kv_budget_bytes: f64,
    /// hard cap on concurrently decoding sessions
    pub max_sessions: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            default_method: "lexico:s=8,nb=32".into(),
            kv_budget_bytes: 64.0 * 1024.0 * 1024.0,
            max_sessions: 32,
        }
    }
}

struct Session {
    job: Job,
    cache: Box<dyn KvCache>,
    pos: usize,
    next_token: u32,
    generated: Vec<u32>,
    t0: Instant,
    ttft_ms: f64,
}

/// The scheduling loop. Runs until the job channel disconnects.
pub fn run(
    engine: Arc<Engine>,
    dicts: Option<Arc<DictionarySet>>,
    cfg: BatcherConfig,
    jobs: Receiver<Job>,
    metrics: Arc<Mutex<Metrics>>,
) -> Result<()> {
    let ctx = CacheContext { shape: engine.shape(), dicts };
    let stop = tasks::newline_id();
    let mut pending: VecDeque<Job> = VecDeque::new();
    let mut active: Vec<Session> = Vec::new();
    let max_seq = engine.weights.cfg.max_seq;

    'outer: loop {
        // ---- intake ---------------------------------------------------
        loop {
            match if active.is_empty() && pending.is_empty() {
                jobs.recv().map_err(|_| RecvTimeoutError::Disconnected)
            } else {
                jobs.recv_timeout(Duration::from_millis(0))
            } {
                Ok(job) => {
                    metrics.lock().unwrap().requests += 1;
                    pending.push_back(job);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if active.is_empty() && pending.is_empty() {
                        break 'outer;
                    }
                    break;
                }
            }
        }

        // ---- admission (prefill) --------------------------------------
        let used: f64 = active.iter().map(|s| s.cache.mem_bytes()).sum();
        let mut budget_left = cfg.kv_budget_bytes - used;
        while let Some(job) = pending.front() {
            if active.len() >= cfg.max_sessions {
                break;
            }
            let prompt_ids: Vec<u32> = {
                let mut v = vec![tasks::BOS];
                v.extend(tasks::encode_lossy(&job.request.prompt));
                v
            };
            if prompt_ids.len() + 2 > max_seq {
                let job = pending.pop_front().unwrap();
                metrics.lock().unwrap().rejected += 1;
                let _ = job.reply.send(Response {
                    id: job.request.id,
                    text: String::new(),
                    n_prompt: prompt_ids.len(),
                    n_generated: 0,
                    ttft_ms: 0.0,
                    total_ms: 0.0,
                    kv_ratio: 0.0,
                    error: Some("prompt too long".into()),
                });
                continue;
            }
            // worst-case estimate: full-precision KV for prompt + generation
            let est = engine.shape().n_layers as f64
                * (prompt_ids.len() + job.request.max_new) as f64
                * engine.shape().full_token_bytes();
            if est > budget_left && !active.is_empty() {
                break; // wait for a session to retire
            }
            let job = pending.pop_front().unwrap();
            let method = if job.request.method.is_empty() {
                cfg.default_method.clone()
            } else {
                job.request.method.clone()
            };
            let t0 = Instant::now();
            match build_cache(&method, &ctx) {
                Ok(mut cache) => {
                    let logits = engine.prefill(&prompt_ids, &mut *cache);
                    let ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let next = argmax(&logits) as u32;
                    budget_left -= cache.mem_bytes();
                    active.push(Session {
                        job,
                        cache,
                        pos: prompt_ids.len(),
                        next_token: next,
                        generated: Vec::new(),
                        t0,
                        ttft_ms,
                    });
                }
                Err(e) => {
                    metrics.lock().unwrap().rejected += 1;
                    let _ = job.reply.send(Response {
                        id: job.request.id,
                        text: String::new(),
                        n_prompt: prompt_ids.len(),
                        n_generated: 0,
                        ttft_ms: 0.0,
                        total_ms: 0.0,
                        kv_ratio: 0.0,
                        error: Some(format!("bad method '{method}': {e}")),
                    });
                }
            }
        }

        // ---- one batched decode round for ALL active sessions -----------
        // Layer-major continuous batching: commit each session's pending
        // token, retire finished sessions, then advance every remaining
        // session together through one `decode_batch` call so each weight
        // matrix streams once per layer per round instead of once per
        // session (the batch-first pipeline; token-identical to per-session
        // `decode_step` calls).
        let mut retire = Vec::new();
        {
            let mut toks: Vec<u32> = Vec::new();
            let mut poss: Vec<usize> = Vec::new();
            let mut decoding: Vec<usize> = Vec::new();
            let mut caches: Vec<&mut dyn KvCache> = Vec::new();
            for (si, sess) in active.iter_mut().enumerate() {
                sess.generated.push(sess.next_token);
                let done = sess.next_token == stop
                    || sess.generated.len() >= sess.job.request.max_new
                    || sess.pos + 1 >= max_seq;
                if done {
                    retire.push(si);
                    continue;
                }
                toks.push(sess.next_token);
                poss.push(sess.pos);
                decoding.push(si);
                caches.push(&mut *sess.cache);
            }
            if !decoding.is_empty() {
                let step_t0 = Instant::now();
                let logits = engine.decode_batch(&toks, &poss, &mut caches);
                drop(caches);
                let per_token = step_t0.elapsed().as_secs_f64() * 1e3 / decoding.len() as f64;
                for (bi, &si) in decoding.iter().enumerate() {
                    let sess = &mut active[si];
                    sess.next_token = argmax(&logits[bi]) as u32;
                    sess.pos += 1;
                }
                // one sample per round (amortized ms/token at that round's
                // batch size) — duplicating it per session would flatten
                // the percentile summary into the mean
                metrics.lock().unwrap().per_token_ms.push(per_token);
            }
        }

        // ---- retire ----------------------------------------------------
        for &si in retire.iter().rev() {
            let sess = active.swap_remove(si);
            let mut m = metrics.lock().unwrap();
            m.completed += 1;
            m.tokens_generated += sess.generated.len() as u64;
            m.ttft_ms.push(sess.ttft_ms);
            m.kv_ratios.push(sess.cache.kv_ratio());
            drop(m);
            let _ = sess.job.reply.send(Response {
                id: sess.job.request.id,
                text: tasks::decode(&sess.generated),
                n_prompt: sess.pos,
                n_generated: sess.generated.len(),
                ttft_ms: sess.ttft_ms,
                total_ms: sess.t0.elapsed().as_secs_f64() * 1e3,
                kv_ratio: sess.cache.kv_ratio(),
                error: None,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_weights;
    use std::sync::mpsc::channel;

    fn spawn_batcher(cfg: BatcherConfig) -> (std::sync::mpsc::Sender<Job>, Arc<Mutex<Metrics>>) {
        let engine = Arc::new(Engine::new(tiny_weights(13)));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (tx, rx) = channel();
        let m2 = metrics.clone();
        std::thread::spawn(move || run(engine, None, cfg, rx, m2));
        (tx, metrics)
    }

    #[test]
    fn serves_concurrent_requests() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, metrics) = spawn_batcher(cfg);
        let mut replies = Vec::new();
        for i in 0..4 {
            let (rtx, rrx) = channel();
            tx.send(Job {
                request: crate::server::Request {
                    id: i,
                    prompt: "1+2=".into(),
                    max_new: 5,
                    method: String::new(),
                },
                reply: rtx,
            })
            .unwrap();
            replies.push(rrx);
        }
        for (i, r) in replies.into_iter().enumerate() {
            let resp = r.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert!(resp.n_generated >= 1);
        }
        let m = metrics.lock().unwrap();
        assert_eq!(m.completed, 4);
        assert!(m.tokens_generated >= 4);
    }

    #[test]
    fn rejects_too_long_prompt() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, _metrics) = spawn_batcher(cfg);
        let (rtx, rrx) = channel();
        tx.send(Job {
            request: crate::server::Request {
                id: 0,
                prompt: "a".repeat(4000),
                max_new: 4,
                method: String::new(),
            },
            reply: rtx,
        })
        .unwrap();
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_some());
    }

    #[test]
    fn per_request_method_override() {
        let cfg = BatcherConfig { default_method: "full".into(), ..Default::default() };
        let (tx, _m) = spawn_batcher(cfg);
        let (rtx, rrx) = channel();
        tx.send(Job {
            request: crate::server::Request {
                id: 7,
                prompt: "abc".into(),
                max_new: 3,
                method: "pertoken:bits=4,g=8".into(),
            },
            reply: rtx,
        })
        .unwrap();
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.kv_ratio < 1.0);
    }
}
