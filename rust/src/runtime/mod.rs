//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once at build time by `python/compile/aot.py`) and executes them on the
//! PJRT CPU client. This is the path that proves the three layers compose:
//! the L1 Pallas OMP kernel and the L2 JAX decode graphs run from Rust with
//! no Python anywhere near the request path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

use crate::model::Weights;
use crate::util::json::Json;

pub mod config;
pub use config::{CacheRuntime, EncodeTier};

/// Parsed `artifacts/manifest.json`.
pub struct Manifest {
    pub json: Json,
    pub weight_order: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let weight_order = json
            .get("weight_order")
            .as_arr()
            .context("manifest missing weight_order")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        Ok(Manifest { json, weight_order })
    }

    /// Static dims recorded for a graph (e.g. `tc`, `s`, `n_atoms`).
    pub fn graph_const(&self, graph: &str, key: &str) -> Option<usize> {
        self.json.get("graphs").get(graph).get("const").get(key).as_usize()
    }

    pub fn has_graph(&self, graph: &str) -> bool {
        self.json.get("graphs").get(graph).as_obj().is_some()
    }
}

/// A compiled HLO graph plus the weight literals it is fed with.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Graph {
    /// Execute with `extra` appended after the weight literals; returns the
    /// decomposed output tuple.
    pub fn run(&self, weights: &[xla::Literal], extra: Vec<xla::Literal>) -> Result<Vec<xla::Literal>> {
        let mut args: Vec<&xla::Literal> = weights.iter().collect();
        for e in &extra {
            args.push(e);
        }
        let result = self.exe.execute::<&xla::Literal>(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with no weight prefix (standalone kernels, e.g. the OMP graph).
    pub fn run_raw(&self, args: Vec<xla::Literal>) -> Result<Vec<xla::Literal>> {
        self.run(&[], args)
    }
}

/// PJRT-backed engine: dense-cache decode / prefill graphs + the standalone
/// L1 OMP kernel + the full Lexico decode graph.
pub struct PjrtEngine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    pub weights_lit: Vec<xla::Literal>,
    pub decode: Graph,
    pub prefill: Graph,
    pub omp: Option<Graph>,
    pub lexico_decode: Option<Graph>,
    pub t_max: usize,
    pub cfg: crate::model::ModelConfig,
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

impl PjrtEngine {
    /// Compile one artifact file on the client.
    fn compile(client: &xla::PjRtClient, dir: &Path, file: &str) -> Result<Graph> {
        let path = dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Graph { exe, name: file.to_string() })
    }

    /// Load everything from the artifacts directory. `weights_path` is the
    /// LXMW file matching the exported graphs (model_M.bin).
    pub fn load(dir: &Path, weights_path: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(weights_path)?;
        let cfg = weights.cfg;
        let client = xla::PjRtClient::cpu()?;
        let decode = Self::compile(&client, dir, "model.hlo.txt")?;
        let prefill = Self::compile(&client, dir, "prefill_M.hlo.txt")?;
        let omp = if manifest.has_graph("omp_M.hlo.txt") {
            Some(Self::compile(&client, dir, "omp_M.hlo.txt")?)
        } else {
            None
        };
        let lexico_decode = if manifest.has_graph("lexico_decode_M.hlo.txt") {
            Some(Self::compile(&client, dir, "lexico_decode_M.hlo.txt")?)
        } else {
            None
        };
        // weight literals in manifest order
        let mut weights_lit = Vec::with_capacity(manifest.weight_order.len());
        for name in &manifest.weight_order {
            let (shape, data) = weights
                .by_name
                .get(name)
                .with_context(|| format!("weights missing {name}"))?;
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            weights_lit.push(lit_f32(data, &dims)?);
        }
        let t_max = manifest
            .graph_const("model.hlo.txt", "t_max")
            .unwrap_or(cfg.max_seq);
        Ok(PjrtEngine {
            client,
            manifest,
            weights_lit,
            decode,
            prefill,
            omp,
            lexico_decode,
            t_max,
            cfg,
        })
    }

    /// Logits of the last prompt token through the AOT prefill graph
    /// (numeric cross-check against the native engine).
    pub fn prefill_logits(&self, prompt: &[u32]) -> Result<Vec<f32>> {
        let t = prompt.len();
        if t == 0 || t > self.t_max {
            bail!("prompt length {t} out of range");
        }
        let mut toks = vec![0i32; self.t_max];
        for (i, &p) in prompt.iter().enumerate() {
            toks[i] = p as i32;
        }
        let out = self.prefill.run(
            &self.weights_lit,
            vec![
                lit_i32(&toks, &[1, self.t_max as i64])?,
                lit_i32(&[t as i32], &[1])?,
            ],
        )?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Dense-cache generation through the PJRT decode graph (batch 1).
    /// Returns generated token ids (greedy, stop included).
    pub fn generate(&self, prompt: &[u32], max_new: usize, stop: Option<u32>) -> Result<Vec<u32>> {
        let cfg = &self.cfg;
        let t = prompt.len();
        if t == 0 || t > self.t_max {
            bail!("prompt length {t} out of range");
        }
        // prefill
        let mut toks = vec![0i32; self.t_max];
        for (i, &p) in prompt.iter().enumerate() {
            toks[i] = p as i32;
        }
        let out = self.prefill.run(
            &self.weights_lit,
            vec![
                lit_i32(&toks, &[1, self.t_max as i64])?,
                lit_i32(&[t as i32], &[1])?,
            ],
        )?;
        let (mut logits, mut k_cache, mut v_cache) = {
            let mut it = out.into_iter();
            (
                it.next().context("prefill: missing logits")?,
                it.next().context("prefill: missing k")?,
                it.next().context("prefill: missing v")?,
            )
        };
        let mut generated = Vec::with_capacity(max_new);
        let mut pos = t;
        let mut next = argmax_lit(&logits, cfg.vocab)?;
        for _ in 0..max_new {
            generated.push(next);
            if Some(next) == stop || pos >= self.t_max {
                break;
            }
            let out = self.decode.run(
                &self.weights_lit,
                vec![
                    lit_i32(&[next as i32], &[1])?,
                    lit_i32(&[pos as i32], &[1])?,
                    k_cache,
                    v_cache,
                ],
            )?;
            let mut it = out.into_iter();
            logits = it.next().context("decode: missing logits")?;
            k_cache = it.next().context("decode: missing k")?;
            v_cache = it.next().context("decode: missing v")?;
            next = argmax_lit(&logits, cfg.vocab)?;
            pos += 1;
        }
        Ok(generated)
    }

    /// Run the standalone L1 OMP kernel artifact on a batch of vectors.
    /// `x` is [batch, m] flattened; returns (idx, val, nnz).
    pub fn run_omp(&self, dict: &[f32], x: &[f32]) -> Result<(Vec<i32>, Vec<f32>, Vec<i32>)> {
        let omp = self.omp.as_ref().context("omp artifact not exported")?;
        let m = self.cfg.head_dim;
        let n = self
            .manifest
            .graph_const("omp_M.hlo.txt", "n_atoms")
            .context("omp n_atoms")?;
        let batch = self
            .manifest
            .graph_const("omp_M.hlo.txt", "batch")
            .context("omp batch")?;
        if x.len() != batch * m {
            bail!("omp batch mismatch: got {} want {}", x.len() / m, batch);
        }
        let out = omp.run_raw(vec![
            lit_f32(dict, &[m as i64, n as i64])?,
            lit_f32(x, &[batch as i64, m as i64])?,
        ])?;
        let mut it = out.into_iter();
        let idx = it.next().context("omp: idx")?.to_vec::<i32>()?;
        let val = it.next().context("omp: val")?.to_vec::<f32>()?;
        let nnz = it.next().context("omp: nnz")?.to_vec::<i32>()?;
        Ok((idx, val, nnz))
    }
}

fn argmax_lit(logits: &xla::Literal, vocab: usize) -> Result<u32> {
    let v = logits.to_vec::<f32>()?;
    let row = &v[v.len() - vocab..]; // batch-1 last row
    Ok(crate::tensor::argmax(row) as u32)
}
