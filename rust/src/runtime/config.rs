//! `CacheRuntime`: the one place `LEXICO_*` environment flags and CLI
//! options resolve into cache construction state (DESIGN.md §14).
//!
//! Before this module, runtime wiring was scattered: caches snapshotted
//! `LEXICO_QD_PER_HEAD` / `LEXICO_GRAM_OMP` in their constructors, and the
//! batcher chained post-construction setters (`set_pool`, `set_spill_store`,
//! `set_gram_omp`) that each backend had to remember to propagate through
//! `fork()`. Now a single [`CacheRuntime`] value is resolved once (env
//! defaults via [`CacheRuntime::from_env`], CLI overrides via the builder
//! methods), handed to [`crate::cache::factory::build_cache`], applied by
//! `KvCache::set_runtime`, and inherited wholesale by forks.

use std::sync::Arc;

use crate::exec::ExecPool;
use crate::sparse::CoefMode;
use crate::store::SpillStore;

/// Which OMP pursuit the cache's overflow compression runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EncodeTier {
    /// Residual-space OMP (`omp_encode_batch`) — the always-correct default.
    #[default]
    Canonical,
    /// Precomputed-Gram Batch-OMP (`omp_encode_batch_gram`, PR 8 tier):
    /// tolerance-equal to canonical, opt-in via `--gram-omp` /
    /// `LEXICO_GRAM_OMP=1`.
    Gram,
}

/// Everything a cache needs from its environment, resolved exactly once.
///
/// `Clone` is cheap (two `Arc`s + scalars); a `fork()` inherits the parent's
/// value verbatim, so a forked session can never silently diverge from the
/// runtime its parent was built under.
#[derive(Clone, Default)]
pub struct CacheRuntime {
    /// Worker pool for parallel compression/attend sharding. `None` keeps
    /// each cache's private default pool.
    pub pool: Option<Arc<ExecPool>>,
    /// Disk spill store for the tiered-residency path (DESIGN.md §11).
    pub spill: Option<Arc<SpillStore>>,
    /// Which OMP pursuit overflow compression runs.
    pub encode_tier: EncodeTier,
    /// Coefficient storage mode override for CSR rows. `None` keeps the
    /// backend spec's own precision (e.g. `lexico-fp16`'s FP16); `Some`
    /// forces the mode — how `--coef-mode sign` / `LEXICO_COEF_MODE=sign`
    /// select the 1-bit sign tier.
    pub coef_mode: Option<CoefMode>,
    /// Precompute q·D per head instead of per layer (`LEXICO_QD_PER_HEAD`).
    pub qd_per_head: bool,
}

impl CacheRuntime {
    /// Resolve the `LEXICO_*` environment into a runtime value. This is the
    /// only place those variables are interpreted for cache construction:
    /// `LEXICO_GRAM_OMP` (via the process-wide
    /// [`crate::omp::gram_omp_requested`] snapshot), `LEXICO_COEF_MODE`
    /// (`fp8` / `fp16` / `sign`; unrecognized spellings are ignored rather
    /// than guessed), and `LEXICO_QD_PER_HEAD`.
    pub fn from_env() -> CacheRuntime {
        CacheRuntime {
            pool: None,
            spill: None,
            encode_tier: if crate::omp::gram_omp_requested() {
                EncodeTier::Gram
            } else {
                EncodeTier::Canonical
            },
            coef_mode: std::env::var("LEXICO_COEF_MODE")
                .ok()
                .and_then(|v| CoefMode::parse(&v)),
            qd_per_head: std::env::var_os("LEXICO_QD_PER_HEAD").is_some(),
        }
    }

    pub fn with_pool(mut self, pool: Arc<ExecPool>) -> CacheRuntime {
        self.pool = Some(pool);
        self
    }

    pub fn with_spill(mut self, spill: Arc<SpillStore>) -> CacheRuntime {
        self.spill = Some(spill);
        self
    }

    pub fn with_encode_tier(mut self, tier: EncodeTier) -> CacheRuntime {
        self.encode_tier = tier;
        self
    }

    pub fn with_coef_mode(mut self, mode: CoefMode) -> CacheRuntime {
        self.coef_mode = Some(mode);
        self
    }

    pub fn with_qd_per_head(mut self, on: bool) -> CacheRuntime {
        self.qd_per_head = on;
        self
    }
}

impl std::fmt::Debug for CacheRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheRuntime")
            .field("pool", &self.pool.as_ref().map(|p| p.threads()))
            .field("spill", &self.spill.is_some())
            .field("encode_tier", &self.encode_tier)
            .field("coef_mode", &self.coef_mode)
            .field("qd_per_head", &self.qd_per_head)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose_and_default_is_canonical() {
        let rt = CacheRuntime::default();
        assert!(rt.pool.is_none());
        assert!(rt.spill.is_none());
        assert_eq!(rt.encode_tier, EncodeTier::Canonical);
        assert_eq!(rt.coef_mode, None);
        assert!(!rt.qd_per_head);

        let pool = Arc::new(ExecPool::new(2));
        let rt = CacheRuntime::default()
            .with_pool(pool.clone())
            .with_encode_tier(EncodeTier::Gram)
            .with_coef_mode(CoefMode::Sign)
            .with_qd_per_head(true);
        assert!(Arc::ptr_eq(rt.pool.as_ref().unwrap(), &pool));
        assert_eq!(rt.encode_tier, EncodeTier::Gram);
        assert_eq!(rt.coef_mode, Some(CoefMode::Sign));
        assert!(rt.qd_per_head);
        // a clone (what fork() takes) is the same runtime, Arc-shared
        let c = rt.clone();
        assert!(Arc::ptr_eq(c.pool.as_ref().unwrap(), &pool));
        assert_eq!(c.coef_mode, Some(CoefMode::Sign));
    }
}
