//! Pluggable KV-cache backends.
//!
//! The engine computes Q/K/V per layer and delegates *storage and
//! attention* to a [`KvCache`] implementation. Every compression method in
//! the paper's evaluation is a first-class backend:
//!
//! | backend    | paper method                 | knobs                        |
//! |------------|------------------------------|------------------------------|
//! | `full`     | FP16 full cache              | —                            |
//! | `lexico`   | Lexico (§3)                  | s, δ, n_b, n_a, coef prec., adaptive |
//! | `kivi`     | KIVI (per-channel K / per-token V) | bits, group g, residual n_b |
//! | `pertoken` | HF per-token quantization    | bits, group g, residual n_b  |
//! | `zipcache` | ZipCache salient mixed-prec. | salient frac., bits hi/lo    |
//! | `snapkv`   | SnapKV eviction              | capacity, window, pool       |
//! | `pyramidkv`| PyramidKV eviction           | capacity, window, slope      |
//!
//! Contract (GQA): `append`/`ingest_prefill` receive K/V rows of
//! `[n_kv_heads × head_dim]`; `attend` receives a query of
//! `[n_heads × head_dim]` and must write the attention output in the same
//! layout, attending query head `h` against kv head `h / (H/KV)`.
//! `attend` is called *after* the new token was appended.

pub mod full;
pub mod kivi;
pub mod lexico;
pub mod pertoken;
pub mod pyramidkv;
pub mod snapkv;
pub mod zipcache;

use crate::runtime::CacheRuntime;
use crate::tensor::{dot, softmax};

/// What a backend can do, declared in one descriptor instead of scattered
/// probe methods and `Err`-return sniffing. The batcher consults this once
/// per cache: chunked prefill and the shared-prefix cache require
/// `split_prefill_exact`; the residency manager only spills/hibernates
/// caches that advertise it; the decode-round dictionary-refresh pass only
/// visits caches with `dict_refresh`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheCaps {
    /// `ingest_prefill(prefix)` + `ingest_prefill(suffix)` is bitwise
    /// identical to one whole-prompt call. True for backends whose
    /// compression depends only on token order; false where prefill-time
    /// score state spans the whole prompt (snapkv/pyramidkv eviction,
    /// zipcache salience) or the dictionary mutates per encode (adaptive
    /// lexico).
    pub split_prefill_exact: bool,
    /// [`KvCache::shared_dicts`] returns `Some` — the engine can batch the
    /// query–dictionary GEMM across sessions (DESIGN.md §10).
    pub shared_dicts: bool,
    /// [`KvCache::spill_cold`]/[`KvCache::fault_resident`] actually move
    /// pages (DESIGN.md §11).
    pub spill: bool,
    /// [`KvCache::hibernate_state`]/[`KvCache::restore_hibernated`] are
    /// supported.
    pub hibernate: bool,
    /// [`KvCache::refresh_dicts`] can fold accumulated adaptive atoms back
    /// into the universal dictionary between decode rounds (DESIGN.md §14).
    pub dict_refresh: bool,
}

impl Default for CacheCaps {
    fn default() -> Self {
        CacheCaps {
            split_prefill_exact: true,
            shared_dicts: false,
            spill: false,
            hibernate: false,
            dict_refresh: false,
        }
    }
}

/// Geometry shared by all backends.
#[derive(Clone, Copy, Debug)]
pub struct CacheShape {
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl CacheShape {
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }
    /// FP16 bytes of one token's K+V rows in one layer.
    pub fn full_token_bytes(&self) -> f64 {
        (2 * self.kv_dim() * 2) as f64
    }
}

/// The backend interface (see module docs for the exact contract).
pub trait KvCache: Send {
    /// Bulk-load the prompt's K/V states for one layer (full-precision
    /// prefill attention has already happened inside the engine, per the
    /// paper's protocol). `ks`/`vs` are `[t][kv_dim]` row-major;
    /// `q_win` is `[w][q_dim]`, the *last* `w` prompt queries — observation
    /// window for attention-score-based methods (SnapKV/PyramidKV).
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      q_win: &[f32], w: usize);

    /// Append one decoded token's K/V rows (`[kv_dim]` each).
    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]);

    /// Append `b` decoded tokens' K/V rows in one call (`ks`/`vs` are
    /// `[b][kv_dim]` row-major, oldest first). Must be observationally
    /// identical to `b` sequential [`KvCache::append`] calls; the default
    /// is exactly that loop. Backends override where batching pays —
    /// Lexico compresses the whole overflow with one GEMM-batched OMP
    /// call, KIVI spills once instead of per token.
    fn append_batch(&mut self, layer: usize, ks: &[f32], vs: &[f32], b: usize) {
        if b == 0 {
            return;
        }
        let kvd = ks.len() / b;
        debug_assert_eq!(ks.len(), b * kvd);
        debug_assert_eq!(vs.len(), b * kvd);
        for i in 0..b {
            self.append(layer, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
        }
    }

    /// GQA attention of `q` (`[q_dim]`) over everything stored for `layer`,
    /// writing `[q_dim]` to `out`. `&mut self` so backends may track
    /// attention-mass statistics (ZipCache salience).
    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]);

    /// Attend `b` independent queries (`qs` is `[b][q_dim]` row-major) over
    /// the *same* stored state, writing `[b][q_dim]` to `out`. Must equal
    /// `b` sequential [`KvCache::attend`] calls (the default loop); batched
    /// overrides amortize per-call work — one dequantization pass (KIVI),
    /// one streaming pass over K/V (full) or over the dictionaries (Lexico)
    /// shared by every query.
    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32], b: usize) {
        if b == 0 {
            return;
        }
        let qd = qs.len() / b;
        debug_assert_eq!(qs.len(), b * qd);
        debug_assert_eq!(out.len(), b * qd);
        for i in 0..b {
            self.attend(layer, &qs[i * qd..(i + 1) * qd], &mut out[i * qd..(i + 1) * qd]);
        }
    }

    /// Clone this cache into an independent session. The fork must be
    /// *observationally identical* to the original: continuing either copy
    /// (append/attend/decode) produces bitwise-identical results, and
    /// mutating one copy never affects the other. Backends with immutable
    /// compressed state may share it between forks (Lexico shares its
    /// frozen CSR pages behind an `Arc` — copy-on-write at page
    /// granularity), in which case [`KvCache::shared_prefix_bytes`] reports
    /// the shared portion so admission control can charge it once.
    fn fork(&self) -> Box<dyn KvCache>;

    /// Bytes of [`KvCache::mem_bytes`] that are physically shared with at
    /// least one other live fork of this cache (0 for backends whose fork
    /// is a deep copy). The serving budget charges shared bytes once — at
    /// the owner that created them — and each fork only its private rest.
    fn shared_prefix_bytes(&self) -> f64 {
        0.0
    }

    /// Apply a resolved [`CacheRuntime`] (pool, spill store, encode tier,
    /// coefficient mode, qd layout) in one call — the construction-time
    /// replacement for the old `set_pool`/`set_spill_store`/`set_gram_omp`
    /// setter chain. [`factory::build_cache`] calls this on every cache it
    /// builds, and `fork()` inherits the applied runtime, so a session can
    /// never end up with half-applied wiring. Backends without internal
    /// compute or spillable state ignore it. Runtime fields that change
    /// compression output (encode tier, coefficient mode) only take effect
    /// on an empty cache; applying them later is a caller bug and may be
    /// ignored.
    fn set_runtime(&mut self, rt: &CacheRuntime) {
        let _ = rt;
    }

    /// The capability descriptor — see [`CacheCaps`]. The default is a
    /// plain split-exact backend with no spill/hibernate/shared-dict
    /// support; backends override to advertise more (or less).
    fn caps(&self) -> CacheCaps {
        CacheCaps::default()
    }

    /// The shared dictionary set this cache scores against, if its attend
    /// path factors through a query–dictionary projection that the engine
    /// can batch across sessions (Lexico). Backends return the *same*
    /// `Arc` they were built with, so the engine can group sessions by
    /// `Arc::ptr_eq` and run one `qᵀD` GEMM per (round, layer, dictionary)
    /// instead of one per session. `None` (the default) keeps the backend
    /// on the plain [`KvCache::attend`] fan-out.
    fn shared_dicts(&self) -> Option<std::sync::Arc<crate::dict::DictionarySet>> {
        None
    }

    /// Engine-internal protocol, phase 1 of the round-level shared-qd
    /// attend (see DESIGN.md §10). Called only on caches that returned
    /// `Some` from [`KvCache::shared_dicts`], with `qd_base` =
    /// `[n_heads][n_k]` precomputed `qᵀD_k` rows for this session's query
    /// against the *base* (shared) key dictionary of `layer`. The cache
    /// scores its compressed tokens + buffer, softmaxes, and accumulates
    /// the base-atom value bins into `z_base` (`[n_heads][n_v]`, zeroed
    /// here); softmaxed scores and any adaptive-extension z-bins stay in
    /// internal scratch for [`KvCache::finish_shared_attend`]. The engine
    /// then applies `z_base · D_v` itself in one sharded pass over the
    /// shared value atoms.
    fn begin_shared_attend(&mut self, layer: usize, q: &[f32], qd_base: &[f32], z_base: &mut [f32]) {
        let _ = (layer, q, qd_base, z_base);
        unreachable!("begin_shared_attend called on a backend without shared_dicts()");
    }

    /// Engine-internal protocol, phase 2: after the engine applied the
    /// shared value atoms, add the per-cache remainder to `out`
    /// (`[q_dim]`, already holding the base-atom contribution) — adaptive
    /// dictionary extension atoms, then the uncompressed buffer — in the
    /// same per-element order as [`KvCache::attend`], preserving bitwise
    /// parity with the per-session path.
    fn finish_shared_attend(&mut self, layer: usize, out: &mut [f32]) {
        let _ = (layer, out);
        unreachable!("finish_shared_attend called on a backend without shared_dicts()");
    }

    /// Fold accumulated adaptive-dictionary extra atoms back into the
    /// universal dictionary between decode rounds (DESIGN.md §14): every
    /// layer/side overlay with pending atoms rotates its base
    /// [`crate::dict::Dictionary`] to a refreshed generation (base atoms +
    /// extras appended, fresh Gram), the overlay rebases onto it, and the
    /// cache's `shared_dicts()` Arc changes so round-level grouping
    /// re-forms. Returns the number of atoms folded. Decode output is
    /// bitwise unchanged — extras keep their indices — and the folded
    /// atoms stay charged to this session's KV size. Only meaningful for
    /// backends advertising [`CacheCaps::dict_refresh`]; the default has
    /// no dictionary to refresh.
    fn refresh_dicts(&mut self) -> Result<usize, String> {
        Err(format!("{}: dictionary refresh is not supported by this backend", self.name()))
    }

    /// Evict this cache's sole-owned sealed pages to the spill store,
    /// returning `(pages evicted, resident bytes freed)`. Pages shared
    /// with a live fork stay resident (their memory would not be freed and
    /// is charged to the owner). Requires a spill store from the applied
    /// [`CacheRuntime`]; the default backend has nothing spillable.
    fn spill_cold(&mut self) -> Result<(usize, f64), String> {
        Ok((0, 0.0))
    }

    /// Fault every spilled page back to residency, returning `(pages
    /// faulted, resident bytes restored)`. A corrupt or truncated page
    /// file fails here with a message — the caller turns it into a session
    /// error, never a panic.
    fn fault_resident(&mut self) -> Result<(usize, f64), String> {
        Ok((0, 0.0))
    }

    /// Resident bytes [`KvCache::mem_bytes`] would additionally report if
    /// every spilled page were faulted back in (0 when fully resident).
    fn spilled_bytes(&self) -> f64 {
        0.0
    }

    /// Serialize the full cache state for session hibernation: sealed
    /// pages are mirrored to the spill store's page file and referenced by
    /// offset, everything else (tail slabs, dense buffer, counters) is
    /// embedded. Restoring the blob into a freshly built cache of the same
    /// configuration via [`KvCache::restore_hibernated`] must reproduce
    /// the decode stream bitwise.
    fn hibernate_state(&mut self) -> Result<Vec<u8>, String> {
        Err(format!("{}: hibernation is not supported by this backend", self.name()))
    }

    /// Rebuild state from a [`KvCache::hibernate_state`] blob. The cache
    /// must be freshly built with the same configuration and have the same
    /// spill store attached; pages come back as spilled refs (fault them
    /// via [`KvCache::fault_resident`] before decoding).
    fn restore_hibernated(&mut self, blob: &[u8]) -> Result<(), String> {
        let _ = blob;
        Err(format!("{}: hibernation is not supported by this backend", self.name()))
    }

    /// Logical tokens seen (including evicted ones).
    fn tokens(&self) -> usize;

    /// Current compressed footprint in bytes (FP16-equivalent accounting).
    fn mem_bytes(&self) -> f64;

    /// Baseline: the same tokens held as a full FP16 cache.
    fn full_bytes(&self) -> f64;

    /// "KV size" as the paper reports it.
    fn kv_ratio(&self) -> f64 {
        let fb = self.full_bytes();
        if fb == 0.0 {
            1.0
        } else {
            self.mem_bytes() / fb
        }
    }

    fn name(&self) -> String;
}

/// Dense GQA attention over token-major K/V rows — the shared fallback used
/// by the dense/dequantized backends. `ks`/`vs` are `[t][kv_dim]`.
pub fn dense_attend(
    shape: &CacheShape,
    ks: &[f32],
    vs: &[f32],
    t: usize,
    q: &[f32],
    out: &mut [f32],
    scores_buf: &mut Vec<f32>,
) {
    let m = shape.head_dim;
    let kvd = shape.kv_dim();
    let scale = 1.0 / (m as f32).sqrt();
    out.fill(0.0);
    scores_buf.resize(t, 0.0);
    for h in 0..shape.n_heads {
        let g = h / shape.group();
        let qh = &q[h * m..(h + 1) * m];
        for ti in 0..t {
            scores_buf[ti] = dot(qh, &ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]) * scale;
        }
        softmax(&mut scores_buf[..t]);
        let oh = &mut out[h * m..(h + 1) * m];
        for ti in 0..t {
            crate::tensor::axpy(oh, scores_buf[ti], &vs[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
        }
    }
}

/// Batched dense GQA attention: `b` queries over the same token-major K/V
/// rows. One streaming pass over K computes every query's scores and one
/// pass over V accumulates every output, so the (possibly dequantized) K/V
/// arrays are loaded once per call instead of once per query. Per output
/// element the arithmetic matches [`dense_attend`] operation-for-operation
/// (same dots, same per-row softmax, same ascending-token accumulation), so
/// results are bitwise identical to `b` sequential calls.
#[allow(clippy::too_many_arguments)]
pub fn dense_attend_batch(
    shape: &CacheShape,
    ks: &[f32],
    vs: &[f32],
    t: usize,
    qs: &[f32],
    out: &mut [f32],
    b: usize,
    scores_buf: &mut Vec<f32>,
) {
    let m = shape.head_dim;
    let kvd = shape.kv_dim();
    let qd = shape.q_dim();
    let nh = shape.n_heads;
    let scale = 1.0 / (m as f32).sqrt();
    out.fill(0.0);
    if t == 0 {
        return;
    }
    let rows = b * nh;
    scores_buf.resize(rows * t, 0.0);
    // score pass: stream K once, fill every (query, head) row
    for ti in 0..t {
        for qi in 0..b {
            for h in 0..nh {
                let g = h / shape.group();
                scores_buf[(qi * nh + h) * t + ti] = dot(
                    &qs[qi * qd + h * m..qi * qd + (h + 1) * m],
                    &ks[ti * kvd + g * m..ti * kvd + (g + 1) * m],
                ) * scale;
            }
        }
    }
    for row in scores_buf.chunks_mut(t).take(rows) {
        softmax(row);
    }
    // value pass: stream V once, accumulate every output head
    for ti in 0..t {
        for qi in 0..b {
            for h in 0..nh {
                let g = h / shape.group();
                crate::tensor::axpy(
                    &mut out[qi * qd + h * m..qi * qd + (h + 1) * m],
                    scores_buf[(qi * nh + h) * t + ti],
                    &vs[ti * kvd + g * m..ti * kvd + (g + 1) * m],
                );
            }
        }
    }
}

/// Construct a backend by name + config (used by the CLI / eval sweeps).
pub mod factory;
