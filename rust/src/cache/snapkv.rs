//! SnapKV (Li et al. 2024): prefill-time token eviction.
//!
//! The last `window` prompt queries form an observation window; their
//! softmax attention onto every prompt key is aggregated per kv head
//! (summed over the GQA query group — the head-granularity sharing the
//! paper points to as the reason eviction methods struggle with GQA),
//! max-pooled along the token axis to keep clusters intact, and the top
//! `capacity − window` tokens are retained along with the window itself.
//! Generated tokens are kept in full precision, as in the reference.

use super::{dense_attend, CacheShape, KvCache};
use crate::tensor::{dot, softmax};

#[derive(Clone, Debug)]
pub struct SnapKvConfig {
    /// retained prompt tokens per layer (incl. the observation window)
    pub capacity: usize,
    /// observation window (last w prompt tokens)
    pub window: usize,
    /// max-pool kernel size along tokens
    pub pool: usize,
}

impl Default for SnapKvConfig {
    fn default() -> Self {
        SnapKvConfig { capacity: 64, window: 8, pool: 5 }
    }
}

#[derive(Clone)]
pub(super) struct LayerState {
    pub ks: Vec<f32>, // retained tokens, token-major [t][kv_dim]
    pub vs: Vec<f32>,
    pub kept: usize,
}

#[derive(Clone)]
pub struct SnapKvCache {
    shape: CacheShape,
    cfg: SnapKvConfig,
    layers: Vec<LayerState>,
    tokens: usize,
    /// Σ kept over layers, maintained on ingest/append → O(1) `mem_bytes`
    kept_total: usize,
    scores: Vec<f32>,
}

/// Observation-window importance scores per token (shared with PyramidKV).
/// Returns, for each kv head, the pooled aggregated attention mass of the
/// window queries over the first `t` keys. `ks` is `[t][kv_dim]`, `q_win`
/// is `[w][q_dim]`.
pub(super) fn window_scores(
    shape: &CacheShape,
    ks: &[f32],
    t: usize,
    q_win: &[f32],
    w: usize,
    pool: usize,
) -> Vec<Vec<f32>> {
    let m = shape.head_dim;
    let kvd = shape.kv_dim();
    let scale = 1.0 / (m as f32).sqrt();
    let mut per_head = vec![vec![0.0f32; t]; shape.n_kv_heads];
    let mut row = vec![0.0f32; t];
    for wi in 0..w {
        for h in 0..shape.n_heads {
            let g = h / shape.group();
            let qh = &q_win[wi * shape.q_dim() + h * m..wi * shape.q_dim() + (h + 1) * m];
            for ti in 0..t {
                row[ti] = dot(qh, &ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]) * scale;
            }
            softmax(&mut row[..t]);
            for ti in 0..t {
                per_head[g][ti] += row[ti];
            }
        }
    }
    // 1-D max pool along tokens (cluster preservation)
    if pool > 1 {
        let half = pool / 2;
        for scores in per_head.iter_mut() {
            let orig = scores.clone();
            for ti in 0..t {
                let lo = ti.saturating_sub(half);
                let hi = (ti + half + 1).min(t);
                scores[ti] = orig[lo..hi].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            }
        }
    }
    per_head
}

/// Select which token indices to keep given pooled scores: the window is
/// always kept; the rest fill up to `capacity` by descending score
/// (scores summed across kv heads — token granularity, GQA-shared).
pub(super) fn select_tokens(
    per_head: &[Vec<f32>],
    t: usize,
    w: usize,
    capacity: usize,
) -> Vec<usize> {
    let body = t.saturating_sub(w);
    let keep_body = capacity.saturating_sub(w.min(t)).min(body);
    let mut total = vec![0.0f32; body];
    for scores in per_head {
        for ti in 0..body {
            total[ti] += scores[ti];
        }
    }
    let mut order: Vec<usize> = (0..body).collect();
    order.sort_by(|&a, &b| total[b].partial_cmp(&total[a]).unwrap());
    let mut keep: Vec<usize> = order[..keep_body].to_vec();
    keep.extend(body..t); // the observation window itself
    keep.sort_unstable();
    keep
}

impl SnapKvCache {
    pub fn new(shape: CacheShape, cfg: SnapKvConfig) -> Self {
        let layers = (0..shape.n_layers)
            .map(|_| LayerState { ks: Vec::new(), vs: Vec::new(), kept: 0 })
            .collect();
        SnapKvCache { shape, cfg, layers, tokens: 0, kept_total: 0, scores: Vec::new() }
    }

    pub(super) fn ingest_with_capacity(
        shape: &CacheShape,
        st: &mut LayerState,
        cfg: &SnapKvConfig,
        capacity: usize,
        ks: &[f32],
        vs: &[f32],
        t: usize,
        q_win: &[f32],
        w: usize,
    ) {
        let kvd = shape.kv_dim();
        if t <= capacity || w == 0 {
            st.ks.extend_from_slice(&ks[..t * kvd]);
            st.vs.extend_from_slice(&vs[..t * kvd]);
            st.kept += t;
            return;
        }
        let per_head = window_scores(shape, ks, t, q_win, w, cfg.pool);
        let keep = select_tokens(&per_head, t, w, capacity);
        for &ti in &keep {
            st.ks.extend_from_slice(&ks[ti * kvd..(ti + 1) * kvd]);
            st.vs.extend_from_slice(&vs[ti * kvd..(ti + 1) * kvd]);
        }
        st.kept += keep.len();
    }
}

impl KvCache for SnapKvCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      q_win: &[f32], w: usize) {
        let cfg = self.cfg.clone();
        let before = self.layers[layer].kept;
        Self::ingest_with_capacity(
            &self.shape, &mut self.layers[layer], &cfg, cfg.capacity, ks, vs, t, q_win, w,
        );
        self.kept_total += self.layers[layer].kept - before;
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let st = &mut self.layers[layer];
        st.ks.extend_from_slice(k);
        st.vs.extend_from_slice(v);
        st.kept += 1;
        self.kept_total += 1;
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let st = &self.layers[layer];
        let mut scores = std::mem::take(&mut self.scores);
        dense_attend(&self.shape, &st.ks, &st.vs, st.kept, q, out, &mut scores);
        self.scores = scores;
    }

    /// Forks carry the retained-token state (the eviction outcome) with
    /// them; decode-time appends after the fork stay per-fork.
    fn fork(&self) -> Box<dyn KvCache> {
        Box::new(self.clone())
    }

    /// Eviction selects the top tokens of the *whole* prompt under one
    /// capacity; ingesting the prompt in two pieces applies the budget to
    /// each piece separately, so split prefill is not bitwise-reproducible
    /// once the prompt exceeds capacity.
    fn caps(&self) -> super::CacheCaps {
        super::CacheCaps {
            split_prefill_exact: false,
            ..Default::default()
        }
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    /// O(1): the kept-token count is maintained on ingest/append instead
    /// of being re-summed over layers per call.
    fn mem_bytes(&self) -> f64 {
        self.kept_total as f64 * self.shape.full_token_bytes()
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        format!("snapkv_c{}", self.cfg.capacity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn shape() -> CacheShape {
        CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 8 }
    }

    #[test]
    fn keeps_high_attention_tokens() {
        let sh = shape();
        let kvd = sh.kv_dim();
        let t = 20;
        let mut rng = Rng::new(1);
        // token 3 is the needle: its key equals the window queries' direction
        let needle = 3usize;
        let dir: Vec<f32> = (0..8).map(|i| if i == 0 { 3.0 } else { 0.0 }).collect();
        let mut ks = Vec::new();
        for ti in 0..t {
            if ti == needle {
                ks.extend_from_slice(&dir);
            } else {
                ks.extend(rng.normal_vec(kvd).iter().map(|x| x * 0.1));
            }
        }
        let vs = rng.normal_vec(t * kvd);
        let w = 4;
        let mut q_win = Vec::new();
        for _ in 0..w {
            q_win.extend_from_slice(&dir); // head 0
            q_win.extend_from_slice(&dir); // head 1
        }
        let cfg = SnapKvConfig { capacity: 8, window: w, pool: 1 };
        let mut c = SnapKvCache::new(sh, cfg);
        c.ingest_prefill(0, &ks, &vs, t, &q_win, w);
        assert_eq!(c.layers[0].kept, 8);
        // the needle key must be among the retained rows
        let kept = &c.layers[0].ks;
        let found = (0..8).any(|r| {
            (0..kvd).all(|i| (kept[r * kvd + i] - dir[i]).abs() < 1e-6)
        });
        assert!(found, "needle evicted");
        assert!((c.kv_ratio() - 8.0 / 20.0).abs() < 1e-9);
    }

    #[test]
    fn under_capacity_keeps_everything() {
        let sh = shape();
        let mut rng = Rng::new(2);
        let t = 5;
        let ks = rng.normal_vec(t * sh.kv_dim());
        let vs = rng.normal_vec(t * sh.kv_dim());
        let mut c = SnapKvCache::new(sh, SnapKvConfig { capacity: 16, window: 2, pool: 5 });
        c.ingest_prefill(0, &ks, &vs, t, &[], 0);
        assert_eq!(c.layers[0].kept, 5);
        assert_eq!(c.kv_ratio(), 1.0);
    }

    #[test]
    fn decode_tokens_always_kept() {
        let sh = shape();
        let mut rng = Rng::new(3);
        let mut c = SnapKvCache::new(sh, SnapKvConfig { capacity: 4, window: 2, pool: 1 });
        for _ in 0..6 {
            let k = rng.normal_vec(sh.kv_dim());
            let v = rng.normal_vec(sh.kv_dim());
            c.append(0, &k, &v);
        }
        assert_eq!(c.layers[0].kept, 6);
    }
}
