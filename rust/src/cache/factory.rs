//! Cache-backend construction from a method spec string.
//!
//! Spec grammar (used by the CLI, eval sweeps and the repro drivers):
//!   full
//!   lexico:s=8,nb=32,na=1[,delta=0.3][,fp16|,sign][,adaptive=1024:0.3][,dict=PATH]
//!   kivi:bits=2,g=16,nb=16
//!   pertoken:bits=4,g=16[,nb=0]
//!   zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16
//!   snapkv:cap=64,win=8[,pool=5]
//!   pyramidkv:cap=64,win=8[,pool=5][,slope=3]

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::full::FullCache;
use super::kivi::{KiviCache, KiviConfig};
use super::lexico::{LexicoCache, LexicoConfig};
use super::pertoken::{PerTokenCache, PerTokenConfig};
use super::pyramidkv::{PyramidKvCache, PyramidKvConfig};
use super::snapkv::{SnapKvCache, SnapKvConfig};
use super::zipcache::{ZipCache, ZipCacheConfig};
use super::{CacheShape, KvCache};
use crate::dict::DictionarySet;
use crate::runtime::CacheRuntime;
use crate::sparse::CoefPrecision;

/// Parsed method spec.
#[derive(Clone, Debug)]
pub struct MethodSpec {
    pub kind: String,
    pub opts: BTreeMap<String, String>,
}

impl MethodSpec {
    pub fn parse(spec: &str) -> Result<Self> {
        let (kind, rest) = match spec.split_once(':') {
            Some((k, r)) => (k.to_string(), r),
            None => (spec.to_string(), ""),
        };
        let mut opts = BTreeMap::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            match part.split_once('=') {
                Some((k, v)) => {
                    opts.insert(k.to_string(), v.to_string());
                }
                None => {
                    opts.insert(part.to_string(), "1".to_string());
                }
            }
        }
        Ok(MethodSpec { kind, opts })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for {key}: {v}")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.opts.contains_key(key)
    }
}

/// Everything a factory call may need beyond the spec itself.
pub struct CacheContext {
    pub shape: CacheShape,
    /// Lexico dictionaries (required for lexico:* specs).
    pub dicts: Option<Arc<DictionarySet>>,
    /// Resolved runtime (pool, spill store, encode tier, coefficient-mode
    /// override, qd layout) applied to every cache this context builds and
    /// inherited wholesale by their forks. This is the ONLY place a
    /// `--coef-mode` / `LEXICO_COEF_MODE` override meets a fresh cache;
    /// restore paths deliberately bypass it so snapshots keep the mode they
    /// were recorded under.
    pub runtime: CacheRuntime,
}

impl CacheContext {
    pub fn new(shape: CacheShape, dicts: Option<Arc<DictionarySet>>) -> CacheContext {
        CacheContext { shape, dicts, runtime: CacheRuntime::from_env() }
    }
}

/// Build a cache backend from a spec string, then apply the context's
/// [`CacheRuntime`] to it.
pub fn build_cache(spec: &str, ctx: &CacheContext) -> Result<Box<dyn KvCache>> {
    let ms = MethodSpec::parse(spec)?;
    let shape = ctx.shape;
    // An explicit per-spec mode flag (`fp16` / `sign`) outranks the global
    // coefficient-mode override: `--coef-mode` / `LEXICO_COEF_MODE` retargets
    // only specs that left the mode at its default.
    let mut rt = ctx.runtime.clone();
    if ms.flag("fp16") || ms.flag("sign") {
        rt.coef_mode = None;
    }
    let mut cache: Box<dyn KvCache> = match ms.kind.as_str() {
        "full" => Box::new(FullCache::new(shape)),
        "lexico" => {
            let dicts = ctx
                .dicts
                .clone()
                .context("lexico backend requires dictionaries")?;
            let adaptive = match ms.opts.get("adaptive") {
                None => None,
                Some(v) => {
                    let (n, d) = v
                        .split_once(':')
                        .context("adaptive=<max_atoms>:<delta>")?;
                    Some((n.parse()?, d.parse()?))
                }
            };
            let cfg = LexicoConfig {
                sparsity: ms.get("s", 8usize)?,
                delta: ms.get("delta", 0.0f32)?,
                n_buffer: ms.get("nb", 32usize)?,
                n_approx: ms.get("na", 1usize)?,
                precision: if ms.flag("sign") {
                    CoefPrecision::Sign
                } else if ms.flag("fp16") {
                    CoefPrecision::Fp16
                } else {
                    CoefPrecision::Fp8
                },
                adaptive,
            };
            Box::new(LexicoCache::new(shape, dicts, cfg))
        }
        "kivi" => Box::new(KiviCache::new(shape, KiviConfig {
            bits: ms.get("bits", 2u8)?,
            group: ms.get("g", 16usize)?,
            n_buffer: ms.get("nb", 16usize)?,
        })),
        "pertoken" => Box::new(PerTokenCache::new(shape, PerTokenConfig {
            bits: ms.get("bits", 4u8)?,
            group: ms.get("g", 16usize)?,
            n_buffer: ms.get("nb", 0usize)?,
        })),
        "zipcache" => Box::new(ZipCache::new(shape, ZipCacheConfig {
            bits_hi: ms.get("hi", 4u8)?,
            bits_lo: ms.get("lo", 2u8)?,
            group: ms.get("g", 16usize)?,
            salient_frac: ms.get("frac", 0.2f32)?,
            n_buffer: ms.get("nb", 16usize)?,
        })),
        "snapkv" => Box::new(SnapKvCache::new(shape, SnapKvConfig {
            capacity: ms.get("cap", 64usize)?,
            window: ms.get("win", 8usize)?,
            pool: ms.get("pool", 5usize)?,
        })),
        "pyramidkv" => Box::new(PyramidKvCache::new(shape, PyramidKvConfig {
            capacity: ms.get("cap", 64usize)?,
            window: ms.get("win", 8usize)?,
            pool: ms.get("pool", 5usize)?,
            slope: ms.get("slope", 3.0f32)?,
        })),
        other => bail!("unknown cache method '{other}'"),
    };
    cache.set_runtime(&rt);
    Ok(cache)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> CacheContext {
        let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let dicts = DictionarySet {
            keys: (0..2).map(|i| crate::dict::Dictionary::random(16, 64, i)).collect(),
            values: (0..2).map(|i| crate::dict::Dictionary::random(16, 64, 9 + i)).collect(),
        };
        // a pinned default runtime: factory tests stay deterministic under
        // the LEXICO_* CI matrix jobs
        CacheContext { shape, dicts: Some(Arc::new(dicts)), runtime: CacheRuntime::default() }
    }

    #[test]
    fn builds_every_backend() {
        let c = ctx();
        for spec in [
            "full",
            "lexico:s=4,nb=8",
            "lexico:s=4,nb=8,delta=0.3,fp16",
            "lexico:s=4,nb=8,sign",
            "lexico:s=2,nb=4,adaptive=16:0.3",
            "kivi:bits=2,g=8,nb=4",
            "pertoken:bits=4,g=16",
            "zipcache:hi=4,lo=2,g=16,frac=0.25,nb=4",
            "snapkv:cap=32,win=4",
            "pyramidkv:cap=32,win=4,slope=2",
        ] {
            let cache = build_cache(spec, &c).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(cache.tokens(), 0);
        }
    }

    #[test]
    fn rejects_unknown() {
        assert!(build_cache("h2o", &ctx()).is_err());
        assert!(build_cache("lexico:s=abc", &ctx()).is_err());
    }

    #[test]
    fn runtime_coef_mode_override_matches_spec_flag() {
        // `--coef-mode sign` through the context runtime must produce the
        // same cache as spelling `sign` in the spec: identical storage
        // accounting on an identical stream, and cheaper than FP8.
        let base = ctx();
        let over = CacheContext {
            shape: base.shape,
            dicts: base.dicts.clone(),
            runtime: CacheRuntime::default().with_coef_mode(crate::sparse::CoefMode::Sign),
        };
        let mut via_rt = build_cache("lexico:s=4,nb=4", &over).unwrap();
        let mut via_spec = build_cache("lexico:s=4,nb=4,sign", &base).unwrap();
        let mut fp8 = build_cache("lexico:s=4,nb=4", &base).unwrap();
        // an explicit spec flag outranks the global override
        let mut pinned = build_cache("lexico:s=4,nb=4,fp16", &over).unwrap();
        let mut fp16 = build_cache("lexico:s=4,nb=4,fp16", &base).unwrap();
        let mut rng = crate::util::rng::Rng::new(12);
        let kvd = base.shape.kv_dim();
        for _ in 0..12 {
            let k = rng.normal_vec(kvd);
            let v = rng.normal_vec(kvd);
            for l in 0..base.shape.n_layers {
                via_rt.append(l, &k, &v);
                via_spec.append(l, &k, &v);
                fp8.append(l, &k, &v);
                pinned.append(l, &k, &v);
                fp16.append(l, &k, &v);
            }
        }
        assert_eq!(via_rt.mem_bytes(), via_spec.mem_bytes());
        assert!(via_rt.mem_bytes() < fp8.mem_bytes());
        assert_eq!(pinned.mem_bytes(), fp16.mem_bytes());
        assert!(pinned.mem_bytes() > via_rt.mem_bytes());
    }
}
