//! Per-token KV quantization (the HuggingFace `quanto`-style baseline).
//!
//! Every token's K and V vectors are quantized independently (asymmetric
//! uniform, group size `g` along channels) once they are older than the
//! small residual window; attention dequantizes on the fly.

use super::{dense_attend, CacheShape, KvCache};
use crate::quant::{dequantize_vector, quantize_vector, QuantGroup};

#[derive(Clone)]
pub struct PerTokenConfig {
    pub bits: u8,
    pub group: usize,
    /// residual window kept in full precision (HF default: none → 0)
    pub n_buffer: usize,
}

impl Default for PerTokenConfig {
    fn default() -> Self {
        PerTokenConfig { bits: 4, group: 32, n_buffer: 0 }
    }
}

#[derive(Clone)]
struct LayerState {
    /// quantized tokens, token-major: each entry = groups for K followed by V
    qk: Vec<Vec<QuantGroup>>,
    qv: Vec<Vec<QuantGroup>>,
    /// fp residual, token-major [t][kv_dim]
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

#[derive(Clone)]
pub struct PerTokenCache {
    shape: CacheShape,
    cfg: PerTokenConfig,
    layers: Vec<LayerState>,
    tokens: usize,
    /// incremental compressed-footprint bytes (kept in sync on every
    /// buffer push and quantization spill → `mem_bytes` is O(1))
    mem: f64,
    scores: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

impl PerTokenCache {
    pub fn new(shape: CacheShape, cfg: PerTokenConfig) -> Self {
        let layers = (0..shape.n_layers)
            .map(|_| LayerState {
                qk: Vec::new(),
                qv: Vec::new(),
                k_buf: Vec::new(),
                v_buf: Vec::new(),
                buf_len: 0,
            })
            .collect();
        PerTokenCache {
            shape,
            cfg,
            layers,
            tokens: 0,
            mem: 0.0,
            scores: Vec::new(),
            dk: Vec::new(),
            dv: Vec::new(),
        }
    }

    /// FP16 accounting of one buffered token (K + V rows).
    fn buf_token_bytes(&self) -> f64 {
        (2 * self.shape.kv_dim() * 2) as f64
    }

    fn quantize_oldest(&mut self, layer: usize, n: usize) {
        let kvd = self.shape.kv_dim();
        let buf_bytes = self.buf_token_bytes();
        let st = &mut self.layers[layer];
        let mut dm = 0.0;
        for _ in 0..n {
            if st.buf_len == 0 {
                break;
            }
            let k: Vec<f32> = st.k_buf[..kvd].to_vec();
            let v: Vec<f32> = st.v_buf[..kvd].to_vec();
            st.qk.push(quantize_vector(&k, self.cfg.group, self.cfg.bits));
            st.qv.push(quantize_vector(&v, self.cfg.group, self.cfg.bits));
            dm += st.qk.last().unwrap().iter().map(|g| g.bytes()).sum::<f64>();
            dm += st.qv.last().unwrap().iter().map(|g| g.bytes()).sum::<f64>();
            dm -= buf_bytes;
            st.k_buf.drain(..kvd);
            st.v_buf.drain(..kvd);
            st.buf_len -= 1;
        }
        self.mem += dm;
    }

    /// Materialize the dequantized K/V (token-major) into self.dk/self.dv.
    fn materialize(&mut self, layer: usize) -> usize {
        let kvd = self.shape.kv_dim();
        let st = &self.layers[layer];
        let tq = st.qk.len();
        let t = tq + st.buf_len;
        self.dk.resize(t * kvd, 0.0);
        self.dv.resize(t * kvd, 0.0);
        for ti in 0..tq {
            dequantize_vector(&st.qk[ti], &mut self.dk[ti * kvd..(ti + 1) * kvd]);
            dequantize_vector(&st.qv[ti], &mut self.dv[ti * kvd..(ti + 1) * kvd]);
        }
        self.dk[tq * kvd..t * kvd].copy_from_slice(&st.k_buf[..st.buf_len * kvd]);
        self.dv[tq * kvd..t * kvd].copy_from_slice(&st.v_buf[..st.buf_len * kvd]);
        t
    }
}

impl KvCache for PerTokenCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        let st = &mut self.layers[layer];
        st.k_buf.extend_from_slice(ks);
        st.v_buf.extend_from_slice(vs);
        st.buf_len += t;
        self.mem += t as f64 * self.buf_token_bytes();
        let over = self.layers[layer].buf_len.saturating_sub(self.cfg.n_buffer);
        self.quantize_oldest(layer, over);
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let st = &mut self.layers[layer];
        st.k_buf.extend_from_slice(k);
        st.v_buf.extend_from_slice(v);
        st.buf_len += 1;
        self.mem += self.buf_token_bytes();
        if self.layers[layer].buf_len > self.cfg.n_buffer {
            self.quantize_oldest(layer, 1);
        }
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let t = self.materialize(layer);
        let mut scores = std::mem::take(&mut self.scores);
        let dk = std::mem::take(&mut self.dk);
        let dv = std::mem::take(&mut self.dv);
        dense_attend(&self.shape, &dk, &dv, t, q, out, &mut scores);
        self.scores = scores;
        self.dk = dk;
        self.dv = dv;
    }

    fn fork(&self) -> Box<dyn KvCache> {
        Box::new(self.clone())
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    /// O(1): maintained incrementally on push/spill instead of re-walking
    /// every quant group per call (the batcher admission loop calls this
    /// every round for every session).
    fn mem_bytes(&self) -> f64 {
        self.mem
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        format!("pertoken_int{}", self.cfg.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::full::FullCache;
    use crate::util::rng::Rng;

    fn shape() -> CacheShape {
        CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 16 }
    }

    #[test]
    fn int8_attention_close_to_full() {
        let mut c = PerTokenCache::new(shape(), PerTokenConfig { bits: 8, group: 16, n_buffer: 0 });
        let mut f = FullCache::new(shape());
        let mut rng = Rng::new(2);
        for _ in 0..12 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
            f.append(0, &k, &v);
        }
        let q = rng.normal_vec(32);
        let mut o1 = vec![0.0; 32];
        let mut o2 = vec![0.0; 32];
        c.attend(0, &q, &mut o1);
        f.attend(0, &q, &mut o2);
        crate::util::prop::assert_close(&o1, &o2, 0.05, "int8≈full").unwrap();
    }

    #[test]
    fn incremental_mem_equals_walked_groups() {
        // the O(1) counter vs the full walk (the pre-PR formula), exactly
        let mut c = PerTokenCache::new(shape(), PerTokenConfig { bits: 2, group: 8, n_buffer: 3 });
        let mut rng = Rng::new(12);
        let walk = |c: &PerTokenCache| -> f64 {
            let mut bytes = 0.0;
            for st in &c.layers {
                for groups in st.qk.iter().chain(&st.qv) {
                    bytes += groups.iter().map(|g| g.bytes()).sum::<f64>();
                }
                bytes += (st.buf_len * 2 * c.shape.kv_dim() * 2) as f64;
            }
            bytes
        };
        let t = 5;
        let ks = rng.normal_vec(t * 16);
        let vs = rng.normal_vec(t * 16);
        c.ingest_prefill(0, &ks, &vs, t, &[], 0);
        assert_eq!(c.mem_bytes(), walk(&c), "after prefill");
        for _ in 0..9 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
            assert_eq!(c.mem_bytes(), walk(&c), "after append");
        }
        let f = c.fork();
        assert_eq!(f.mem_bytes(), c.mem_bytes(), "fork accounting");
    }

    #[test]
    fn ratio_matches_bits() {
        // 2-bit, group 16, m=16: per vector 16*2/8 + 4 = 8 B vs 32 B fp16.
        let mut c = PerTokenCache::new(shape(), PerTokenConfig { bits: 2, group: 16, n_buffer: 0 });
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
        }
        assert!((c.kv_ratio() - 0.25).abs() < 1e-9, "{}", c.kv_ratio());
    }
}
