//! PyramidKV (Cai et al. 2024): SnapKV-style eviction with *pyramidal*
//! per-layer budgets — lower layers (which funnel information broadly)
//! keep more tokens, upper layers fewer, on a linear schedule whose mean
//! equals the nominal capacity.

use super::snapkv::{LayerState, SnapKvCache, SnapKvConfig};
use super::{dense_attend, CacheShape, KvCache};

#[derive(Clone, Debug)]
pub struct PyramidKvConfig {
    /// mean retained prompt tokens per layer
    pub capacity: usize,
    pub window: usize,
    pub pool: usize,
    /// budget ratio between the bottom and top layer (reference uses ~
    /// arithmetic decay; 3.0 means bottom gets 1.5×mean, top 0.5×mean)
    pub slope: f32,
}

impl Default for PyramidKvConfig {
    fn default() -> Self {
        PyramidKvConfig { capacity: 64, window: 8, pool: 5, slope: 3.0 }
    }
}

#[derive(Clone)]
pub struct PyramidKvCache {
    shape: CacheShape,
    cfg: PyramidKvConfig,
    layers: Vec<LayerState>,
    tokens: usize,
    /// Σ kept over layers, maintained on ingest/append → O(1) `mem_bytes`
    kept_total: usize,
    scores: Vec<f32>,
}

impl PyramidKvCache {
    pub fn new(shape: CacheShape, cfg: PyramidKvConfig) -> Self {
        let layers = (0..shape.n_layers)
            .map(|_| LayerState { ks: Vec::new(), vs: Vec::new(), kept: 0 })
            .collect();
        PyramidKvCache { shape, cfg, layers, tokens: 0, kept_total: 0, scores: Vec::new() }
    }

    /// Linear budget schedule: layer 0 gets `hi`, last layer `lo`, with
    /// mean = capacity and hi/lo = slope.
    pub fn capacity_for_layer(&self, layer: usize) -> usize {
        let ll = self.shape.n_layers.max(1) as f32;
        let c = self.cfg.capacity as f32;
        let s = self.cfg.slope.max(1.0);
        let hi = 2.0 * c * s / (s + 1.0);
        let lo = 2.0 * c / (s + 1.0);
        let frac = if ll <= 1.0 { 0.0 } else { layer as f32 / (ll - 1.0) };
        let b = hi + (lo - hi) * frac;
        (b.round() as usize).max(self.cfg.window + 1)
    }
}

impl KvCache for PyramidKvCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      q_win: &[f32], w: usize) {
        let cap = self.capacity_for_layer(layer);
        let snap_cfg = SnapKvConfig {
            capacity: cap,
            window: self.cfg.window,
            pool: self.cfg.pool,
        };
        let before = self.layers[layer].kept;
        SnapKvCache::ingest_with_capacity(
            &self.shape, &mut self.layers[layer], &snap_cfg, cap, ks, vs, t, q_win, w,
        );
        self.kept_total += self.layers[layer].kept - before;
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let st = &mut self.layers[layer];
        st.ks.extend_from_slice(k);
        st.vs.extend_from_slice(v);
        st.kept += 1;
        self.kept_total += 1;
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let st = &self.layers[layer];
        let mut scores = std::mem::take(&mut self.scores);
        dense_attend(&self.shape, &st.ks, &st.vs, st.kept, q, out, &mut scores);
        self.scores = scores;
    }

    fn fork(&self) -> Box<dyn KvCache> {
        Box::new(self.clone())
    }

    /// Same reasoning as SnapKV: per-layer eviction budgets apply to the
    /// whole prompt at once.
    fn caps(&self) -> super::CacheCaps {
        super::CacheCaps {
            split_prefill_exact: false,
            ..Default::default()
        }
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    /// O(1): the kept-token count is maintained on ingest/append instead
    /// of being re-summed over layers per call.
    fn mem_bytes(&self) -> f64 {
        self.kept_total as f64 * self.shape.full_token_bytes()
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        format!("pyramidkv_c{}", self.cfg.capacity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pyramid_schedule_mean_is_capacity() {
        let shape = CacheShape { n_layers: 8, n_heads: 4, n_kv_heads: 2, head_dim: 8 };
        let c = PyramidKvCache::new(shape, PyramidKvConfig {
            capacity: 64, window: 4, pool: 5, slope: 3.0,
        });
        let budgets: Vec<usize> = (0..8).map(|l| c.capacity_for_layer(l)).collect();
        assert!(budgets[0] > budgets[7], "{budgets:?}");
        let mean: f32 = budgets.iter().sum::<usize>() as f32 / 8.0;
        assert!((mean - 64.0).abs() < 2.0, "mean {mean} budgets {budgets:?}");
    }

    #[test]
    fn lower_layers_keep_more() {
        let shape = CacheShape { n_layers: 4, n_heads: 2, n_kv_heads: 1, head_dim: 8 };
        let mut c = PyramidKvCache::new(shape, PyramidKvConfig {
            capacity: 10, window: 2, pool: 1, slope: 3.0,
        });
        let mut rng = Rng::new(1);
        let t = 30;
        let ks = rng.normal_vec(t * shape.kv_dim());
        let vs = rng.normal_vec(t * shape.kv_dim());
        let q_win = rng.normal_vec(2 * shape.q_dim());
        for l in 0..4 {
            c.ingest_prefill(l, &ks, &vs, t, &q_win, 2);
        }
        assert!(c.layers[0].kept > c.layers[3].kept);
        assert!(c.kv_ratio() < 1.0);
    }
}
