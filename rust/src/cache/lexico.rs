//! The Lexico KV-cache backend (paper §3.4, Algorithm 2, Eq. 7).
//!
//! Per layer and kv head the cache holds
//!   * `K_csr`/`V_csr` — OMP sparse codes (u16 indices + FP8/FP16 coefs);
//!   * a full-precision recency buffer of up to `n_b` tokens.
//! When the buffer exceeds `n_b`, the oldest `n_a` tokens are OMP-compressed
//! (the paper runs this in parallel with the forward pass; here it is the
//! same computation on the same thread, measured separately by the latency
//! bench).
//!
//! Decode attention follows the paper's split computation: the query is
//! first multiplied by the dictionary (`q·D_k`, O(N·m)), then contracted
//! against the sparse codes (O(T·s)); buffer tokens take the dense path;
//! one softmax spans both. The value side accumulates coefficients into a
//! dictionary-bin vector `z` and finishes with atoms·z — the same
//! O(N·m + T·s) complexity the paper reports.
//!
//! Compressed tokens live in struct-of-arrays [`CsrSlab`]s (DESIGN.md §8):
//! sealed pages and the unsealed tail each hold one flat index array, one
//! flat coefficient array and a row-offset array, so the O(T·s) score and
//! z-bin passes are linear sweeps over contiguous memory. Long compressed
//! contexts additionally shard the score sweep over the cache's
//! [`ExecPool`] (disjoint score ranges, per-element order unchanged —
//! bitwise identical at every thread count).

use super::{CacheCaps, CacheShape, KvCache};
use crate::dict::adaptive::AdaptiveDict;
use crate::dict::DictionarySet;
use crate::exec::{self, ExecPool, SendPtr};
use crate::omp::{
    omp_encode, omp_encode_batch, omp_encode_batch_gram, BatchOmpWorkspace, OmpWorkspace,
    SparseCode,
};
use crate::runtime::{CacheRuntime, EncodeTier};
use crate::sparse::memory::csr_row_bytes;
use crate::sparse::{CoefPrecision, CsrRow, CsrSlab};
use crate::store::{self, wire, PageRef, SpillStore};
use crate::tensor::{axpy, dot, softmax};
use std::sync::Arc;

/// Session-snapshot magic (`"LXSS"`) / version for
/// [`KvCache::hibernate_state`] blobs. v2 replaced the FP16 flag byte with
/// a coefficient-mode byte (0 = FP8, 1 = FP16, 2 = sign) so sign-tier
/// sessions hibernate and restore with their mode checked, mirroring the
/// page-format v2 header.
const SNAP_MAGIC: u32 = 0x4c58_5353;
const SNAP_VERSION: u16 = 2;

/// Lexico knobs (paper defaults in comments).
#[derive(Clone, Debug)]
pub struct LexicoConfig {
    /// sparsity per vector (s); with `delta > 0` this is the max sparsity
    pub sparsity: usize,
    /// relative-error early-termination threshold δ (0 ⇒ fixed sparsity)
    pub delta: f32,
    /// full-precision recency buffer length n_b (paper: 128)
    pub n_buffer: usize,
    /// approximation window n_a — tokens compressed per overflow (paper: 1)
    pub n_approx: usize,
    /// CSR coefficient precision (paper main: FP8; ablations: FP16)
    pub precision: CoefPrecision,
    /// adaptive dictionary learning (§4.2.4): (max added atoms, δ_adapt)
    pub adaptive: Option<(usize, f32)>,
}

impl Default for LexicoConfig {
    fn default() -> Self {
        LexicoConfig {
            sparsity: 8,
            delta: 0.0,
            n_buffer: 32,
            n_approx: 1,
            precision: CoefPrecision::Fp8,
            adaptive: None,
        }
    }
}

/// Tokens per frozen CSR page. Compressed rows are immutable once written,
/// so they are grouped into fixed-size pages behind an `Arc`: `fork()`
/// clones the `Arc`s (copy-on-write at page granularity — forks share the
/// compressed prefix physically) and only the unsealed tail plus the
/// full-precision recency buffer are deep-copied per fork.
const PAGE_TOKENS: usize = 32;

/// Compressed contexts at or above this many tokens shard the per-token
/// score sweep over the exec pool; below it a parallel launch costs more
/// than the sweep itself (the O(T·s) pass is ~sparsity MACs per token).
/// Shards are kept to at least a quarter of this (so claim overhead stays
/// negligible); tests lower the cache's `par_score_min` to exercise the
/// sharded path on small contexts.
const PAR_SCORE_MIN_TOKENS: usize = 1024;

/// Attend scratch is allowed to retain up to this many × the current
/// single-query footprint before `attend_batch` releases the excess: small
/// round-to-round batch-size jitter keeps its buffers, a one-off wide round
/// gives the memory back.
const SCRATCH_SHRINK_FACTOR: usize = 4;

/// Shrink a scratch vector back to `keep` elements when its capacity has
/// grown past [`SCRATCH_SHRINK_FACTOR`]× that. `shrink_to` only promises an
/// upper bound loosely (capacity stays ≥ `keep`), which is all the session
/// footprint accounting needs.
fn shrink_scratch<T>(v: &mut Vec<T>, keep: usize) {
    if v.capacity() > keep.saturating_mul(SCRATCH_SHRINK_FACTOR) {
        v.truncate(keep);
        v.shrink_to(keep);
    }
}

/// One frozen page of compressed tokens: parallel K and V slabs, exactly
/// [`PAGE_TOKENS`] rows each (pages seal only when full). No `Default`:
/// pages are only ever created by sealing the tail (`CsrSlab::take`),
/// which is what carries the cache's coefficient precision through.
#[derive(Clone)]
struct CsrPage {
    k: CsrSlab,
    v: CsrSlab,
}

impl CsrPage {
    fn bytes(&self) -> f64 {
        (self.k.bytes() + self.v.bytes()) as f64
    }
}

/// Residency state of one sealed page (DESIGN.md §11). The slot keeps its
/// position in `HeadState::pages` through every transition, so the pure
/// `t / PAGE_TOKENS` index math of `k_slab_at` is residency-independent.
///
/// Transitions: `Resident → Mirrored` (page written to the spill store's
/// append-only file, RAM copy kept — hibernation persists without losing
/// residency), `Mirrored → Spilled` (eviction: drop the `Arc`, zero I/O —
/// the disk copy already exists), `Spilled → Mirrored` (fault). A page is
/// written to disk at most once per session lifetime; refs stay valid
/// across process restarts.
#[derive(Clone)]
enum PageSlot {
    /// in RAM only
    Resident(Arc<CsrPage>),
    /// in RAM *and* on disk at `at` (disk copy may be cold-recompressed)
    Mirrored { page: Arc<CsrPage>, at: PageRef },
    /// on disk only; `bytes` = resident bytes this slot frees while evicted
    Spilled { at: PageRef, bytes: f64 },
}

impl PageSlot {
    /// The resident page. Scoring paths only run after
    /// `LexicoCache::ensure_resident`, so a spilled slot here is a protocol
    /// violation, not an I/O condition.
    #[inline]
    fn page(&self) -> &Arc<CsrPage> {
        match self {
            PageSlot::Resident(p) | PageSlot::Mirrored { page: p, .. } => p,
            PageSlot::Spilled { .. } => {
                panic!("lexico: sealed page accessed while spilled (fault before scoring)")
            }
        }
    }

    fn resident(&self) -> Option<&Arc<CsrPage>> {
        match self {
            PageSlot::Resident(p) | PageSlot::Mirrored { page: p, .. } => Some(p),
            PageSlot::Spilled { .. } => None,
        }
    }
}

/// Per-(layer, kv-head) state.
struct HeadState {
    /// sealed compressed pages, oldest first — shared across forks
    pages: Vec<PageSlot>,
    /// unsealed compressed rows (< PAGE_TOKENS of them) — fork-private
    tail_k: CsrSlab,
    tail_v: CsrSlab,
    /// total compressed tokens (pages + tail)
    n_csr: usize,
    /// token-major buffer rows, oldest first: [t][m]
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

impl HeadState {
    fn new(prec: CoefPrecision) -> Self {
        HeadState {
            pages: Vec::new(),
            tail_k: CsrSlab::new(prec),
            tail_v: CsrSlab::new(prec),
            n_csr: 0,
            k_buf: Vec::new(),
            v_buf: Vec::new(),
            buf_len: 0,
        }
    }

    /// Append one compressed token (K and V codes always arrive in pairs),
    /// quantizing through the slab precision and sealing a page whenever
    /// the tail fills.
    fn push_code(&mut self, k_idx: &[u16], k_val: &[f32], v_idx: &[u16], v_val: &[f32]) {
        self.tail_k.push_f32(k_idx, k_val);
        self.tail_v.push_f32(v_idx, v_val);
        self.n_csr += 1;
        if self.tail_k.rows() >= PAGE_TOKENS {
            self.pages.push(PageSlot::Resident(Arc::new(CsrPage {
                k: self.tail_k.take(),
                v: self.tail_v.take(),
            })));
        }
    }

    /// Compressed K slabs in token order (pages, then the unsealed tail).
    /// Requires every page resident (the attend entry points fault first).
    fn k_slabs(&self) -> impl Iterator<Item = &CsrSlab> {
        self.pages.iter().map(|p| &p.page().k).chain(std::iter::once(&self.tail_k))
    }

    /// Compressed V slabs in token order.
    fn v_slabs(&self) -> impl Iterator<Item = &CsrSlab> {
        self.pages.iter().map(|p| &p.page().v).chain(std::iter::once(&self.tail_v))
    }

    /// The K slab holding compressed token `t`, plus `t`'s row within it.
    /// Every sealed page holds exactly [`PAGE_TOKENS`] rows, so this is
    /// pure index math.
    fn k_slab_at(&self, t: usize) -> (&CsrSlab, usize) {
        let p = t / PAGE_TOKENS;
        if p < self.pages.len() {
            (&self.pages[p].page().k, t % PAGE_TOKENS)
        } else {
            (&self.tail_k, t - self.pages.len() * PAGE_TOKENS)
        }
    }

    /// Score compressed tokens `lo..hi` into `out` (`out[0]` = token `lo`):
    /// a linear sweep over the slabs the range touches. Each score is one
    /// independent ascending-order accumulation, so any partition of the
    /// token range composes bitwise.
    fn score_range(&self, lo: usize, hi: usize, qd: &[f32], scale: f32, out: &mut [f32]) {
        let mut t = lo;
        let mut o = 0;
        while t < hi {
            let (slab, row) = self.k_slab_at(t);
            let take = (slab.rows() - row).min(hi - t);
            slab.score_rows(row, row + take, qd, scale, &mut out[o..o + take]);
            t += take;
            o += take;
        }
    }

    /// The compressed-token score pass (`scores[t] = scale·(q·D)·c_t`),
    /// sharded over `pool` when the context is long. Shards own disjoint
    /// score ranges and the per-element operation order never changes, so
    /// the result is bitwise identical at every thread count.
    fn score_compressed(
        &self,
        pool: &ExecPool,
        qd: &[f32],
        scale: f32,
        out: &mut [f32],
        par_min: usize,
    ) {
        let tc = self.n_csr;
        debug_assert_eq!(out.len(), tc);
        if tc == 0 {
            return;
        }
        let shard_min = (par_min / 4).max(1);
        let shards = pool.threads().min(tc / shard_min).max(1);
        if tc < par_min || shards == 1 {
            self.score_range(0, tc, qd, scale, out);
            return;
        }
        let op = SendPtr::new(out.as_mut_ptr());
        pool.parallel_for(shards, move |si| {
            let (lo, hi) = (si * tc / shards, (si + 1) * tc / shards);
            // SAFETY: shard si exclusively owns scores lo..hi.
            let shard = unsafe { std::slice::from_raw_parts_mut(op.get().add(lo), hi - lo) };
            self.score_range(lo, hi, qd, scale, shard);
        });
    }

    /// Value-side z-bin accumulation over every compressed token:
    /// `z[idx] += scores[t]·coef`, as linear slab sweeps in token order.
    fn accumulate_value_bins(&self, scores: &[f32], z: &mut [f32]) {
        let mut t0 = 0;
        for slab in self.v_slabs() {
            slab.accumulate_bins(&scores[t0..t0 + slab.rows()], z);
            t0 += slab.rows();
        }
    }

    /// Compressed K rows in token order — the retained row-iterator
    /// reference view (tests, parity suites, the row-baseline bench).
    fn k_rows(&self) -> Vec<CsrRow> {
        let mut rows = Vec::with_capacity(self.n_csr);
        for slab in self.k_slabs() {
            rows.extend(slab.to_rows());
        }
        rows
    }

    /// Compressed V rows in token order (reference view).
    fn v_rows(&self) -> Vec<CsrRow> {
        let mut rows = Vec::with_capacity(self.n_csr);
        for slab in self.v_slabs() {
            rows.extend(slab.to_rows());
        }
        rows
    }

    /// Fork-private copy: pages shared by `Arc`, tail and buffer cloned.
    fn fork(&self) -> HeadState {
        HeadState {
            pages: self.pages.clone(),
            tail_k: self.tail_k.clone(),
            tail_v: self.tail_v.clone(),
            n_csr: self.n_csr,
            k_buf: self.k_buf.clone(),
            v_buf: self.v_buf.clone(),
            buf_len: self.buf_len,
        }
    }
}

pub struct LexicoCache {
    shape: CacheShape,
    cfg: LexicoConfig,
    dicts: Arc<DictionarySet>,
    /// adaptive overlays (lazily created when cfg.adaptive is set)
    adaptive_k: Vec<Option<AdaptiveDict>>,
    adaptive_v: Vec<Option<AdaptiveDict>>,
    /// atoms folded out of the adaptive overlays by [`KvCache::refresh_dicts`].
    /// They moved into this session's private `Arc<DictionarySet>` rotation
    /// but were paid for by this session, so `mem_bytes` keeps charging them
    /// (FP16 per element) — a refresh must not make KV memory look cheaper.
    folded_extra_atoms: usize,
    /// heads[layer * n_kv_heads + g]
    heads: Vec<HeadState>,
    tokens: usize,
    ws: OmpWorkspace,
    /// batched-OMP workspace (overflow compression of all heads at once)
    bws: BatchOmpWorkspace,
    /// pool the long-context score sweep shards onto (shared with `bws`)
    pool: Arc<ExecPool>,
    /// `LEXICO_QD_PER_HEAD` (the §Perf comparison layout), read once at
    /// construction — the decode hot loop must not issue an env syscall
    /// per layer per step
    qd_per_head: bool,
    /// route batched overflow compression through the precomputed-Gram
    /// Batch-OMP tier (DESIGN.md §12); snapshot of
    /// [`crate::omp::gram_omp_requested`] taken at construction
    gram_omp: bool,
    /// shard threshold for the compressed score sweep (the constant;
    /// overridable in tests to exercise sharding on small contexts)
    par_score_min: usize,
    /// running byte count of every RESIDENT stored CSR row (incremental
    /// `mem_bytes`; spilled pages move their bytes to `spilled_bytes`)
    csr_bytes: f64,
    /// total buffer tokens across all heads (incremental `mem_bytes`)
    buf_tokens: usize,
    /// shared on-disk page store (None ⇒ RAM-only residency)
    spill: Option<Arc<SpillStore>>,
    /// resident bytes currently evicted to the store (Σ `Spilled.bytes`)
    spilled_bytes: f64,
    // overflow-gather scratch: [total][m] K and V rows pending compression
    gather_k: Vec<f32>,
    gather_v: Vec<f32>,
    // attend scratch
    scores: Vec<f32>,
    qd: Vec<f32>,
    z: Vec<f32>,
    /// attend_batch: per-(query, head) offsets into the flat score buffer
    score_off: Vec<usize>,
}

impl LexicoCache {
    pub fn new(shape: CacheShape, dicts: Arc<DictionarySet>, cfg: LexicoConfig) -> Self {
        assert_eq!(dicts.keys.len(), shape.n_layers, "dict layers mismatch");
        let n = dicts.keys[0].n;
        let m = shape.head_dim;
        assert_eq!(dicts.keys[0].m, m, "dict head_dim mismatch");
        let heads = (0..shape.n_layers * shape.n_kv_heads)
            .map(|_| HeadState::new(cfg.precision))
            .collect();
        let (adaptive_k, adaptive_v) = if let Some((max_extra, d)) = cfg.adaptive {
            (
                dicts.keys.iter().map(|b| Some(AdaptiveDict::new(b, max_extra, d))).collect(),
                dicts.values.iter().map(|b| Some(AdaptiveDict::new(b, max_extra, d))).collect(),
            )
        } else {
            (
                (0..shape.n_layers).map(|_| None).collect(),
                (0..shape.n_layers).map(|_| None).collect(),
            )
        };
        let n_cap = n + cfg.adaptive.map(|(e, _)| e).unwrap_or(0);
        let pool = exec::default_pool();
        // Environment defaults resolve through CacheRuntime (the one place
        // LEXICO_* is interpreted); factory-built caches additionally get
        // the full runtime — including any coefficient-mode override —
        // applied via `set_runtime`.
        let rt = CacheRuntime::from_env();
        LexicoCache {
            shape,
            ws: OmpWorkspace::new(n_cap, m, cfg.sparsity.max(1)),
            bws: BatchOmpWorkspace::with_pool(pool.clone()),
            pool,
            qd_per_head: rt.qd_per_head,
            gram_omp: rt.encode_tier == EncodeTier::Gram,
            par_score_min: PAR_SCORE_MIN_TOKENS,
            csr_bytes: 0.0,
            buf_tokens: 0,
            spill: None,
            spilled_bytes: 0.0,
            cfg,
            dicts,
            adaptive_k,
            adaptive_v,
            folded_extra_atoms: 0,
            heads,
            tokens: 0,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
            scores: Vec::new(),
            qd: vec![0.0; n_cap],
            z: vec![0.0; n_cap],
            score_off: Vec::new(),
        }
    }

    #[inline]
    fn head_idx(&self, layer: usize, g: usize) -> usize {
        layer * self.shape.n_kv_heads + g
    }

    /// Compress one vector with the layer's K or V dictionary.
    fn encode(&mut self, layer: usize, is_key: bool, x: &[f32]) -> SparseCode {
        let (s, delta) = (self.cfg.sparsity, self.cfg.delta);
        let adapt = if is_key {
            &mut self.adaptive_k[layer]
        } else {
            &mut self.adaptive_v[layer]
        };
        if let Some(ad) = adapt.as_mut() {
            ad.encode(x, s, &mut self.ws).0
        } else {
            let d = if is_key {
                &self.dicts.keys[layer]
            } else {
                &self.dicts.values[layer]
            };
            omp_encode(&d.atoms, d.n, d.m, x, s, delta, &mut self.ws)
        }
    }

    /// Compress the oldest `n` buffer tokens of every kv head in `layer`.
    ///
    /// Non-adaptive dictionaries take the batch-first path: the pending
    /// K rows of *all* kv heads are gathered into one `[total, m]` matrix
    /// and sparse-coded by [`omp_encode_batch`] (one GEMM correlation step
    /// per pursuit iteration, one dictionary stream for the whole layer),
    /// then the same for V. Per-vector results are bit-identical to the
    /// sequential encoder, so cache contents don't depend on the path.
    /// Under the opt-in gram tier ([`omp_encode_batch_gram`], DESIGN.md
    /// §12) the batch instead runs one α⁰ GEMM total and iterates in
    /// coefficient space against the dictionary's cached Gram matrix —
    /// tolerance-equal to canonical, bitwise self-identical at any thread
    /// count.
    fn compress_oldest(&mut self, layer: usize, n: usize) {
        let m = self.shape.head_dim;
        let mode = self.cfg.precision;
        if self.cfg.adaptive.is_some() {
            // Adaptive growth mutates the dictionary per encoded vector, so
            // results are order-dependent: keep the sequential path.
            for g in 0..self.shape.n_kv_heads {
                let hi = self.head_idx(layer, g);
                for _ in 0..n {
                    if self.heads[hi].buf_len == 0 {
                        break;
                    }
                    let k: Vec<f32> = self.heads[hi].k_buf[..m].to_vec();
                    let v: Vec<f32> = self.heads[hi].v_buf[..m].to_vec();
                    let k_code = self.encode(layer, true, &k);
                    let v_code = self.encode(layer, false, &v);
                    self.csr_bytes += (csr_row_bytes(k_code.nnz(), mode)
                        + csr_row_bytes(v_code.nnz(), mode)) as f64;
                    self.buf_tokens -= 1;
                    let h = &mut self.heads[hi];
                    h.push_code(&k_code.idx, &k_code.val, &v_code.idx, &v_code.val);
                    h.k_buf.drain(..m);
                    h.v_buf.drain(..m);
                    h.buf_len -= 1;
                }
            }
            return;
        }
        // gather the oldest rows of every head into one batch
        self.gather_k.clear();
        self.gather_v.clear();
        let n_kv = self.shape.n_kv_heads;
        let mut takes = vec![0usize; n_kv];
        for (g, take) in takes.iter_mut().enumerate() {
            let hi = self.head_idx(layer, g);
            *take = n.min(self.heads[hi].buf_len);
            self.gather_k.extend_from_slice(&self.heads[hi].k_buf[..*take * m]);
            self.gather_v.extend_from_slice(&self.heads[hi].v_buf[..*take * m]);
        }
        let total: usize = takes.iter().sum();
        if total == 0 {
            return;
        }
        let dicts = self.dicts.clone();
        let (dk, dv) = (&dicts.keys[layer], &dicts.values[layer]);
        let (s, delta) = (self.cfg.sparsity, self.cfg.delta);
        let (k_codes, v_codes) = if self.gram_omp {
            // gram tier: the per-dictionary Gram cache is realized on first
            // touch (par_syrk on this cache's pool) and shared process-wide
            // through the Arc<DictionarySet>
            let gk = dk.gram(&self.pool);
            let gv = dv.gram(&self.pool);
            (
                omp_encode_batch_gram(
                    &dk.atoms, dk.n, dk.m, &gk, &self.gather_k, total, s, delta, &mut self.bws,
                ),
                omp_encode_batch_gram(
                    &dv.atoms, dv.n, dv.m, &gv, &self.gather_v, total, s, delta, &mut self.bws,
                ),
            )
        } else {
            (
                omp_encode_batch(
                    &dk.atoms, dk.n, dk.m, &self.gather_k, total, s, delta, &mut self.bws,
                ),
                omp_encode_batch(
                    &dv.atoms, dv.n, dv.m, &self.gather_v, total, s, delta, &mut self.bws,
                ),
            )
        };
        let mut off = 0;
        for (g, &take) in takes.iter().enumerate() {
            let hi = self.head_idx(layer, g);
            let h = &mut self.heads[hi];
            let mut new_bytes = 0usize;
            for code_i in off..off + take {
                let (kc, vc) = (&k_codes[code_i], &v_codes[code_i]);
                new_bytes += csr_row_bytes(kc.nnz(), mode) + csr_row_bytes(vc.nnz(), mode);
                h.push_code(&kc.idx, &kc.val, &vc.idx, &vc.val);
            }
            h.k_buf.drain(..take * m);
            h.v_buf.drain(..take * m);
            h.buf_len -= take;
            self.csr_bytes += new_bytes as f64;
            self.buf_tokens -= take;
            off += take;
        }
    }

    /// Current atom views per layer (base or adaptive overlay).
    fn atoms(&self, layer: usize, is_key: bool) -> (&[f32], usize) {
        let (ad, base) = if is_key {
            (&self.adaptive_k[layer], &self.dicts.keys[layer])
        } else {
            (&self.adaptive_v[layer], &self.dicts.values[layer])
        };
        match ad {
            Some(a) => (a.atoms(), a.n_atoms()),
            None => (&base.atoms, base.n),
        }
    }

    /// Row-iterator view of one (layer, kv head)'s compressed K/V tokens —
    /// the reference representation for parity tests and the row-baseline
    /// bench. Token order matches the slab sweep exactly.
    pub fn csr_rows(&self, layer: usize, g: usize) -> (Vec<CsrRow>, Vec<CsrRow>) {
        let h = &self.heads[self.head_idx(layer, g)];
        (h.k_rows(), h.v_rows())
    }

    /// One (layer, kv head)'s full-precision recency buffer:
    /// (token-major K rows, token-major V rows, token count).
    pub fn buffer(&self, layer: usize, g: usize) -> (&[f32], &[f32], usize) {
        let m = self.shape.head_dim;
        let h = &self.heads[self.head_idx(layer, g)];
        (&h.k_buf[..h.buf_len * m], &h.v_buf[..h.buf_len * m], h.buf_len)
    }

    /// Make every sealed page resident before a scoring pass. O(1) when
    /// nothing is spilled (the decode-hot case). The batcher faults
    /// explicitly via [`KvCache::fault_resident`] — where a corrupt page
    /// file becomes a clean session error — before scheduling a session, so
    /// this in-attend fallback only fires for direct cache users (tests,
    /// benches, eval sweeps), for whom a panic on a corrupt file is the
    /// right failure mode.
    fn ensure_resident(&mut self) {
        if self.spilled_bytes == 0.0 {
            return;
        }
        if let Err(e) = self.fault_all() {
            panic!("lexico: page fault during attend failed: {e}");
        }
    }

    /// Evict every sole-owned sealed page: `Resident` pages are written to
    /// the spill store first (`Mirrored`), already-mirrored pages drop
    /// their RAM copy with zero I/O. Pages whose `Arc` is shared with a
    /// live fork stay resident — their memory would not actually be freed,
    /// and the serving budget charges them to the owner. Returns
    /// `(pages evicted, resident bytes freed)`.
    fn spill_all(&mut self) -> Result<(usize, f64), String> {
        let Some(store) = self.spill.clone() else {
            return Ok((0, 0.0));
        };
        let mut n_pages = 0usize;
        let mut freed = 0.0f64;
        for h in &mut self.heads {
            for slot in &mut h.pages {
                let (at, bytes) = match slot {
                    PageSlot::Resident(p) if Arc::strong_count(p) == 1 => {
                        let at = store.spill(&p.k, &p.v).map_err(|e| e.to_string())?;
                        (at, p.bytes())
                    }
                    PageSlot::Mirrored { page, at } if Arc::strong_count(page) == 1 => {
                        (*at, page.bytes())
                    }
                    _ => continue,
                };
                *slot = PageSlot::Spilled { at, bytes };
                n_pages += 1;
                freed += bytes;
                self.csr_bytes -= bytes;
                self.spilled_bytes += bytes;
            }
        }
        Ok((n_pages, freed))
    }

    /// Fault every spilled page back to `Mirrored` residency, restoring
    /// resident-byte accounting from the page actually read (under a cold
    /// tier the faulted page is smaller than what was evicted). Returns
    /// `(pages faulted, resident bytes restored)`.
    fn fault_all(&mut self) -> Result<(usize, f64), String> {
        if self.spilled_bytes == 0.0 {
            return Ok((0, 0.0));
        }
        let store = self
            .spill
            .clone()
            .ok_or_else(|| "lexico: spilled pages but no spill store attached".to_string())?;
        let mut n_pages = 0usize;
        let mut restored = 0.0f64;
        for h in &mut self.heads {
            for slot in &mut h.pages {
                if let PageSlot::Spilled { at, bytes } = *slot {
                    let (k, v) = store.fault(at).map_err(|e| e.to_string())?;
                    if k.rows() != PAGE_TOKENS {
                        return Err(format!(
                            "lexico: faulted page at offset {} has {} rows (want {PAGE_TOKENS})",
                            at.offset,
                            k.rows()
                        ));
                    }
                    let page = Arc::new(CsrPage { k, v });
                    let nb = page.bytes();
                    *slot = PageSlot::Mirrored { page, at };
                    n_pages += 1;
                    restored += nb;
                    self.csr_bytes += nb;
                    self.spilled_bytes -= bytes;
                }
            }
        }
        Ok((n_pages, restored))
    }

    /// Mirror every `Resident` page to the spill store (keeping residency)
    /// so the session state is serializable by reference. No accounting
    /// changes — mirroring frees nothing.
    fn mirror_pages(&mut self) -> Result<(), String> {
        let store = self
            .spill
            .clone()
            .ok_or_else(|| "lexico: hibernation requires a spill store (--spill-dir)".to_string())?;
        for h in &mut self.heads {
            for slot in &mut h.pages {
                if let PageSlot::Resident(p) = slot {
                    let at = store.spill(&p.k, &p.v).map_err(|e| e.to_string())?;
                    *slot = PageSlot::Mirrored { page: p.clone(), at };
                }
            }
        }
        Ok(())
    }

    #[cfg(test)]
    fn set_par_score_min(&mut self, min: usize) {
        self.par_score_min = min;
    }
}

impl KvCache for LexicoCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        let m = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        // load everything into the buffer, then compress all but the last n_b
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            for ti in 0..t {
                self.heads[hi]
                    .k_buf
                    .extend_from_slice(&ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
                self.heads[hi]
                    .v_buf
                    .extend_from_slice(&vs[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
            }
            self.heads[hi].buf_len += t;
        }
        self.buf_tokens += t * self.shape.n_kv_heads;
        let overflow = self.heads[self.head_idx(layer, 0)]
            .buf_len
            .saturating_sub(self.cfg.n_buffer);
        if overflow > 0 {
            self.compress_oldest(layer, overflow);
        }
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let m = self.shape.head_dim;
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            self.heads[hi].k_buf.extend_from_slice(&k[g * m..(g + 1) * m]);
            self.heads[hi].v_buf.extend_from_slice(&v[g * m..(g + 1) * m]);
            self.heads[hi].buf_len += 1;
        }
        self.buf_tokens += self.shape.n_kv_heads;
        if self.heads[self.head_idx(layer, 0)].buf_len > self.cfg.n_buffer {
            self.compress_oldest(layer, self.cfg.n_approx);
        }
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn append_batch(&mut self, layer: usize, ks: &[f32], vs: &[f32], b: usize) {
        if b == 0 {
            return;
        }
        let m = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            for ti in 0..b {
                self.heads[hi]
                    .k_buf
                    .extend_from_slice(&ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
                self.heads[hi]
                    .v_buf
                    .extend_from_slice(&vs[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
            }
            self.heads[hi].buf_len += b;
        }
        self.buf_tokens += b * self.shape.n_kv_heads;
        // Replay the sequential trigger schedule exactly: each append whose
        // post-append buffer tops n_buffer compresses min(n_a, buf_len)
        // tokens (compress_oldest is bounded by the buffer). The compressed
        // tokens are always the oldest, so the non-adaptive path can run
        // the whole schedule as ONE compress_oldest call — the entire
        // overflow goes through the GEMM-batched OMP at once.
        let len = self.heads[self.head_idx(layer, 0)].buf_len;
        let (nb, na) = (self.cfg.n_buffer, self.cfg.n_approx);
        if na > 0 {
            let adaptive = self.cfg.adaptive.is_some();
            let mut cur = len - b; // pre-append buffer length
            let mut total = 0usize;
            for _ in 0..b {
                cur += 1;
                if cur > nb {
                    let c = na.min(cur);
                    cur -= c;
                    if adaptive {
                        // Adaptive growth is order-dependent and the
                        // dictionary is shared across kv heads, so the
                        // per-trigger head interleave of the sequential
                        // path must be reproduced call-for-call.
                        self.compress_oldest(layer, c);
                    } else {
                        total += c;
                    }
                }
            }
            if total > 0 {
                self.compress_oldest(layer, total);
            }
        }
        if layer == 0 {
            self.tokens += b;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        self.ensure_resident();
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        // Detach the scratch vectors from `self` for the duration of the
        // pass: the dictionary views below hold `&self` borrows, and with
        // the scratch moved out the borrow checker can see that scratch
        // writes never alias the atoms (this used to be papered over with
        // a raw-pointer `from_raw_parts` hack).
        let mut qd = std::mem::take(&mut self.qd);
        let mut scores = std::mem::take(&mut self.scores);
        let mut z = std::mem::take(&mut self.z);
        let (k_atoms, k_n) = self.atoms(layer, true);
        let (v_atoms, v_n) = self.atoms(layer, false);

        // qd[h][n] = q_h · D_k[n] for ALL heads in one streaming pass over
        // the dictionary (perf pass #1, EXPERIMENTS.md §Perf: one load of
        // each atom now serves every query head instead of H separate
        // passes over the N·m array). Set LEXICO_QD_PER_HEAD=1 *at cache
        // construction* to use the pre-optimization per-head layout (kept
        // for the §Perf comparison — the flag is latched into
        // `self.qd_per_head` so the hot loop never touches the env).
        if qd.len() < n_heads * k_n {
            qd.resize(n_heads * k_n, 0.0);
        }
        if self.qd_per_head {
            for h in 0..n_heads {
                let qh = &q[h * m..(h + 1) * m];
                for n in 0..k_n {
                    qd[h * k_n + n] = dot(qh, &k_atoms[n * m..(n + 1) * m]);
                }
            }
        } else {
            for n in 0..k_n {
                let atom = &k_atoms[n * m..(n + 1) * m];
                for h in 0..n_heads {
                    qd[h * k_n + n] = dot(&q[h * m..(h + 1) * m], atom);
                }
            }
        }

        if z.len() < v_n {
            z.resize(v_n, 0.0);
        }
        for h in 0..n_heads {
            let g = h / self.shape.group();
            let hi = self.head_idx(layer, g);
            let head = &self.heads[hi];
            let tc = head.n_csr;
            let tb = head.buf_len;
            let qh = &q[h * m..(h + 1) * m];
            let qdh = &qd[h * k_n..(h + 1) * k_n];
            // compressed scores: O(T·s), one linear sweep over the flat
            // slabs, pool-sharded when the context is long
            scores.resize(tc + tb, 0.0);
            head.score_compressed(&self.pool, qdh, scale, &mut scores[..tc], self.par_score_min);
            // buffer scores: dense
            for ti in 0..tb {
                scores[tc + ti] = dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
            }
            softmax(&mut scores[..tc + tb]);

            // value side: z-bin accumulation, then atoms·z  (O(T·s + N·m))
            let oh = &mut out[h * m..(h + 1) * m];
            let zh = &mut z[..v_n];
            zh.fill(0.0);
            head.accumulate_value_bins(&scores[..tc], zh);
            for (n, &zn) in zh.iter().enumerate() {
                if zn != 0.0 {
                    axpy(oh, zn, &v_atoms[n * m..(n + 1) * m]);
                }
            }
            for ti in 0..tb {
                axpy(oh, scores[tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
            }
        }
        self.qd = qd;
        self.scores = scores;
        self.z = z;
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32], b: usize) {
        if b == 0 {
            return;
        }
        self.ensure_resident();
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let qdim = self.shape.q_dim();
        let group = self.shape.group();
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        // Scratch detached from `self` so the dictionary borrows below can
        // coexist with scratch writes (same pattern as `attend`; replaces
        // the old raw-pointer aliasing hack).
        let mut qd = std::mem::take(&mut self.qd);
        let mut scores = std::mem::take(&mut self.scores);
        let mut z = std::mem::take(&mut self.z);
        let mut score_off = std::mem::take(&mut self.score_off);
        let (k_atoms, k_n) = self.atoms(layer, true);
        let (v_atoms, v_n) = self.atoms(layer, false);
        let rows = b * n_heads;

        // (1) qd[row][n] = q_row · D_k[n]: ONE streaming pass over the key
        // dictionary serves every query's every head (extends perf pass #1
        // across the whole query batch).
        if qd.len() < rows * k_n {
            qd.resize(rows * k_n, 0.0);
        }
        for n in 0..k_n {
            let atom = &k_atoms[n * m..(n + 1) * m];
            for qi in 0..b {
                for h in 0..n_heads {
                    qd[(qi * n_heads + h) * k_n + n] =
                        dot(&qs[qi * qdim + h * m..qi * qdim + (h + 1) * m], atom);
                }
            }
        }

        // (2) per-row scores + softmax + value-bin accumulation (the flat
        // score buffer is kept for phase 4; offsets per row).
        score_off.clear();
        score_off.push(0);
        for _qi in 0..b {
            for h in 0..n_heads {
                let hi = self.head_idx(layer, h / group);
                let len = self.heads[hi].n_csr + self.heads[hi].buf_len;
                let prev = *score_off.last().unwrap();
                score_off.push(prev + len);
            }
        }
        let total_scores = *score_off.last().unwrap();
        if scores.len() < total_scores {
            scores.resize(total_scores, 0.0);
        }
        if z.len() < rows * v_n {
            z.resize(rows * v_n, 0.0);
        }
        z[..rows * v_n].fill(0.0);
        for qi in 0..b {
            for h in 0..n_heads {
                let row = qi * n_heads + h;
                let hi = self.head_idx(layer, h / group);
                let head = &self.heads[hi];
                let tc = head.n_csr;
                let tb = head.buf_len;
                let off = score_off[row];
                let qh = &qs[qi * qdim + h * m..qi * qdim + (h + 1) * m];
                let qdrow = &qd[row * k_n..(row + 1) * k_n];
                head.score_compressed(
                    &self.pool,
                    qdrow,
                    scale,
                    &mut scores[off..off + tc],
                    self.par_score_min,
                );
                for ti in 0..tb {
                    scores[off + tc + ti] = dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
                }
                softmax(&mut scores[off..off + tc + tb]);
                let zrow = &mut z[row * v_n..(row + 1) * v_n];
                head.accumulate_value_bins(&scores[off..off + tc], zrow);
            }
        }

        // (3) ONE streaming pass over the value dictionary finishes the
        // compressed-token term of every (query, head) output. Per output
        // element contributions still arrive in ascending-atom order, so
        // this is bitwise identical to the per-head atoms·z pass.
        for n in 0..v_n {
            let atom = &v_atoms[n * m..(n + 1) * m];
            for row in 0..rows {
                let zn = z[row * v_n + n];
                if zn != 0.0 {
                    let (qi, h) = (row / n_heads, row % n_heads);
                    axpy(&mut out[qi * qdim + h * m..qi * qdim + (h + 1) * m], zn, atom);
                }
            }
        }

        // (4) recency-buffer tokens, dense — after the dictionary term,
        // matching the sequential attend's per-head accumulation order.
        for qi in 0..b {
            for h in 0..n_heads {
                let row = qi * n_heads + h;
                let hi = self.head_idx(layer, h / group);
                let head = &self.heads[hi];
                let tc = head.n_csr;
                let off = score_off[row];
                let oh = &mut out[qi * qdim + h * m..qi * qdim + (h + 1) * m];
                for ti in 0..head.buf_len {
                    axpy(oh, scores[off + tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
                }
            }
        }

        // Release oversized scratch: a one-off wide round (large `b`) would
        // otherwise pin the high-water allocation — and every future fork's
        // clone cost — for the rest of the session. Shrink back towards the
        // single-query footprint whenever the round left >SHRINK_FACTOR×
        // that behind.
        let one_query_scores = score_off[n_heads];
        shrink_scratch(&mut qd, n_heads * k_n);
        shrink_scratch(&mut scores, one_query_scores);
        shrink_scratch(&mut z, v_n);
        self.qd = qd;
        self.scores = scores;
        self.z = z;
        self.score_off = score_off;
    }

    /// Every session built from the same `Arc<DictionarySet>` reports the
    /// same pointer, letting the engine batch the `qᵀD_k` projection of a
    /// whole decode round into one GEMM (DESIGN.md §10). Adaptive sessions
    /// participate too: their *base* atoms are the shared set, and only the
    /// session-private extension atoms are scored locally.
    fn shared_dicts(&self) -> Option<Arc<DictionarySet>> {
        Some(self.dicts.clone())
    }

    /// Round-level attend, phase 1 (engine protocol; see the trait docs).
    /// `qd_base` is `[n_heads][nk_base]` — this session's rows of the
    /// round's `qᵀD_k` GEMM over the shared base key dictionary; the GEMM
    /// computes each element with the same canonical `dot`, so the rows are
    /// bitwise identical to what `attend` would have produced. Scores,
    /// softmax and the value z-bins run exactly as in `attend`; base-atom
    /// bins land in `z_base` (`[n_heads][nv_base]`) for the engine's shared
    /// value pass, while softmaxed scores — and, under adaptive mode, the
    /// full-width z rows covering extension atoms — stay in scratch for
    /// [`Self::finish_shared_attend`].
    fn begin_shared_attend(&mut self, layer: usize, q: &[f32], qd_base: &[f32], z_base: &mut [f32]) {
        self.ensure_resident();
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let scale = 1.0 / (m as f32).sqrt();
        let nk_base = self.dicts.keys[layer].n;
        let nv_base = self.dicts.values[layer].n;
        debug_assert_eq!(qd_base.len(), n_heads * nk_base);
        debug_assert_eq!(z_base.len(), n_heads * nv_base);
        let mut qd = std::mem::take(&mut self.qd);
        let mut scores = std::mem::take(&mut self.scores);
        let mut z = std::mem::take(&mut self.z);
        let mut score_off = std::mem::take(&mut self.score_off);
        let (k_atoms, k_n) = self.atoms(layer, true);
        let (_, v_n) = self.atoms(layer, false);

        // Assemble per-head qd rows: base atoms arrive precomputed from the
        // round GEMM; adaptive extension atoms (indices ≥ nk_base) are
        // session-private and scored here, in ascending-atom order, exactly
        // as `attend`'s per-head projection loop would have reached them.
        if qd.len() < n_heads * k_n {
            qd.resize(n_heads * k_n, 0.0);
        }
        for h in 0..n_heads {
            let row = &mut qd[h * k_n..(h + 1) * k_n];
            row[..nk_base].copy_from_slice(&qd_base[h * nk_base..(h + 1) * nk_base]);
            let qh = &q[h * m..(h + 1) * m];
            for n in nk_base..k_n {
                row[n] = dot(qh, &k_atoms[n * m..(n + 1) * m]);
            }
        }

        // Per-head score offsets into the flat score buffer (kept for
        // finish_shared_attend's buffer pass).
        score_off.clear();
        score_off.push(0);
        for h in 0..n_heads {
            let hi = self.head_idx(layer, h / self.shape.group());
            let len = self.heads[hi].n_csr + self.heads[hi].buf_len;
            let prev = *score_off.last().unwrap();
            score_off.push(prev + len);
        }
        let total_scores = *score_off.last().unwrap();
        if scores.len() < total_scores {
            scores.resize(total_scores, 0.0);
        }
        let has_extras = v_n > nv_base;
        if has_extras {
            if z.len() < n_heads * v_n {
                z.resize(n_heads * v_n, 0.0);
            }
            z[..n_heads * v_n].fill(0.0);
        }
        z_base.fill(0.0);
        for h in 0..n_heads {
            let g = h / self.shape.group();
            let head = &self.heads[self.head_idx(layer, g)];
            let tc = head.n_csr;
            let tb = head.buf_len;
            let off = score_off[h];
            let qh = &q[h * m..(h + 1) * m];
            let qdh = &qd[h * k_n..(h + 1) * k_n];
            head.score_compressed(&self.pool, qdh, scale, &mut scores[off..off + tc], self.par_score_min);
            for ti in 0..tb {
                scores[off + tc + ti] = dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
            }
            softmax(&mut scores[off..off + tc + tb]);
            if has_extras {
                // Adaptive rows may index extension atoms (≥ nv_base):
                // accumulate into a full-width local row, then hand the
                // base prefix to the engine's shared pass.
                let zrow = &mut z[h * v_n..(h + 1) * v_n];
                head.accumulate_value_bins(&scores[off..off + tc], zrow);
                z_base[h * nv_base..(h + 1) * nv_base].copy_from_slice(&zrow[..nv_base]);
            } else {
                head.accumulate_value_bins(
                    &scores[off..off + tc],
                    &mut z_base[h * nv_base..(h + 1) * nv_base],
                );
            }
        }
        self.qd = qd;
        self.scores = scores;
        self.z = z;
        self.score_off = score_off;
    }

    /// Round-level attend, phase 2: `out` already holds the shared
    /// base-atom value contribution (applied by the engine in ascending
    /// atom order); add the adaptive extension atoms (ascending, continuing
    /// where the base left off) and then the recency buffer — the same
    /// per-element order as `attend`, so the round path stays bitwise
    /// identical to the per-session path.
    fn finish_shared_attend(&mut self, layer: usize, out: &mut [f32]) {
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let nv_base = self.dicts.values[layer].n;
        let (v_atoms, v_n) = self.atoms(layer, false);
        for h in 0..n_heads {
            let g = h / self.shape.group();
            let head = &self.heads[self.head_idx(layer, g)];
            let tc = head.n_csr;
            let off = self.score_off[h];
            let oh = &mut out[h * m..(h + 1) * m];
            if v_n > nv_base {
                for n in nv_base..v_n {
                    let zn = self.z[h * v_n + n];
                    if zn != 0.0 {
                        axpy(oh, zn, &v_atoms[n * m..(n + 1) * m]);
                    }
                }
            }
            for ti in 0..head.buf_len {
                axpy(oh, self.scores[off + tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
            }
        }
    }

    /// Copy-on-write fork: sealed CSR pages are shared (`Arc` clone), the
    /// unsealed tail, recency buffer, token counter and adaptive overlays
    /// are deep-copied, and scratch/workspaces start fresh (they carry no
    /// semantic state — OMP results are workspace-independent). Continuing
    /// either copy is bitwise identical to continuing the original.
    fn fork(&self) -> Box<dyn KvCache> {
        // layers may hold different atom counts once a refresh has folded
        // overlays, so the workspace ceiling is the max across both sides
        let n = self
            .dicts
            .keys
            .iter()
            .chain(&self.dicts.values)
            .map(|d| d.n)
            .max()
            .unwrap_or(0);
        let m = self.shape.head_dim;
        let n_cap = n + self.cfg.adaptive.map(|(e, _)| e).unwrap_or(0);
        Box::new(LexicoCache {
            shape: self.shape,
            ws: OmpWorkspace::new(n_cap, m, self.cfg.sparsity.max(1)),
            bws: BatchOmpWorkspace::with_pool(self.pool.clone()),
            pool: self.pool.clone(),
            qd_per_head: self.qd_per_head,
            gram_omp: self.gram_omp,
            par_score_min: self.par_score_min,
            csr_bytes: self.csr_bytes,
            buf_tokens: self.buf_tokens,
            spill: self.spill.clone(),
            spilled_bytes: self.spilled_bytes,
            cfg: self.cfg.clone(),
            dicts: self.dicts.clone(),
            adaptive_k: self.adaptive_k.clone(),
            adaptive_v: self.adaptive_v.clone(),
            folded_extra_atoms: self.folded_extra_atoms,
            heads: self.heads.iter().map(|h| h.fork()).collect(),
            tokens: self.tokens,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
            scores: Vec::new(),
            qd: vec![0.0; n_cap],
            z: vec![0.0; n_cap],
            score_off: Vec::new(),
        })
    }

    /// Bytes living in pages whose `Arc` is held by more than one cache —
    /// the physically shared compressed prefix. Charged once by the page
    /// owner (prefix-cache prototype or primary fan-out candidate).
    fn shared_prefix_bytes(&self) -> f64 {
        self.heads
            .iter()
            .flat_map(|h| &h.pages)
            .filter_map(|s| s.resident())
            .filter(|p| Arc::strong_count(p) > 1)
            .map(|p| p.bytes())
            .sum()
    }

    /// Apply the resolved [`CacheRuntime`] (DESIGN.md §14). The pool (shared
    /// with the batched-OMP workspace — overflow compression and the
    /// long-context score sweep both shard onto it, bitwise independent of
    /// thread count) and the spill store attach whenever present; the encode
    /// tier and qd layout swap freely because neither changes stored bits. A
    /// coefficient-mode override re-seeds the slab storage, which is only
    /// sound while the cache is empty — once tokens exist their coefficients
    /// are already quantized, so a late override is ignored rather than
    /// silently corrupting rows (see the trait docs).
    fn set_runtime(&mut self, rt: &CacheRuntime) {
        if let Some(pool) = &rt.pool {
            self.pool = pool.clone();
            self.bws.set_pool(pool.clone());
        }
        if let Some(store) = &rt.spill {
            self.spill = Some(store.clone());
        }
        self.gram_omp = rt.encode_tier == EncodeTier::Gram;
        self.qd_per_head = rt.qd_per_head;
        if let Some(mode) = rt.coef_mode {
            if mode != self.cfg.precision && self.tokens == 0 {
                self.cfg.precision = mode;
                for h in &mut self.heads {
                    *h = HeadState::new(mode);
                }
            }
        }
    }

    /// Adaptive dictionaries grow per encoded vector, so the encode *order*
    /// matters: split prefill diverges, hibernation cannot capture the
    /// overlay, and dictionary refresh becomes available. The plain
    /// universal-dictionary path compresses vector-by-vector independently.
    fn caps(&self) -> CacheCaps {
        let adaptive = self.cfg.adaptive.is_some();
        CacheCaps {
            split_prefill_exact: !adaptive,
            shared_dicts: true,
            spill: true,
            hibernate: !adaptive,
            dict_refresh: adaptive,
        }
    }

    /// Fold the adaptive overlays back into the universal dictionaries
    /// between decode rounds: each layer/side with pending extension atoms
    /// rotates to a *new* [`crate::dict::Dictionary`] generation via
    /// [`crate::dict::Dictionary::refreshed`] — appended atoms, fresh Gram
    /// cache (the old generation's Gram can never be served against the
    /// grown atom set) — and the overlay rebases so its `max_extra` headroom
    /// reopens. Stored codes are untouched: the refreshed base holds the
    /// folded atoms at the indices the codes already reference, so decode
    /// output is bitwise identical before and after a refresh. Returns the
    /// number of atoms folded (0 when nothing grew since the last refresh).
    fn refresh_dicts(&mut self) -> Result<usize, String> {
        if self.cfg.adaptive.is_none() {
            return Err("lexico: dictionary refresh requires adaptive mode".into());
        }
        let pending: usize = self
            .adaptive_k
            .iter()
            .chain(&self.adaptive_v)
            .flatten()
            .map(|ad| ad.n_extra)
            .sum();
        if pending == 0 {
            return Ok(0);
        }
        let fold = |bases: &[crate::dict::Dictionary], ads: &mut Vec<Option<AdaptiveDict>>| {
            bases
                .iter()
                .zip(ads.iter_mut())
                .map(|(base, ad)| {
                    let ad = ad.as_mut().expect("adaptive cache has an overlay per layer");
                    if ad.n_extra == 0 {
                        return base.clone();
                    }
                    let d = base.refreshed(ad.extra_atoms());
                    ad.rebase();
                    d
                })
                .collect::<Vec<_>>()
        };
        let keys = fold(&self.dicts.keys, &mut self.adaptive_k);
        let values = fold(&self.dicts.values, &mut self.adaptive_v);
        self.dicts = Arc::new(DictionarySet { keys, values });
        self.folded_extra_atoms += pending;
        // The overlays' headroom reopened, so future growth can push the
        // atom count past the original construction-time capacity: regrow
        // the OMP workspace to the new ceiling (attend scratch resizes
        // lazily and needs no help).
        let n_max = self
            .dicts
            .keys
            .iter()
            .chain(&self.dicts.values)
            .map(|d| d.n)
            .max()
            .unwrap_or(0);
        let headroom = self.cfg.adaptive.map(|(e, _)| e).unwrap_or(0);
        self.ws = OmpWorkspace::new(n_max + headroom, self.shape.head_dim,
                                    self.cfg.sparsity.max(1));
        Ok(pending)
    }

    fn spill_cold(&mut self) -> Result<(usize, f64), String> {
        self.spill_all()
    }

    fn fault_resident(&mut self) -> Result<(usize, f64), String> {
        self.fault_all()
    }

    fn spilled_bytes(&self) -> f64 {
        self.spilled_bytes
    }

    /// Serialize the session for hibernation (DESIGN.md §11): every sealed
    /// page is mirrored into the store's page file and written here as a
    /// `(offset, len, resident-bytes)` ref; the unsealed tail travels as
    /// one embedded page blob (ragged row count), the dense recency buffer
    /// as exact f32 bits. Residency and accounting are unchanged — pairing
    /// with [`Self::spill_all`] afterwards frees the page memory for free.
    /// Adaptive sessions are rejected: their dictionary overlay mutates per
    /// encode and is not captured by the page format.
    fn hibernate_state(&mut self) -> Result<Vec<u8>, String> {
        if self.cfg.adaptive.is_some() {
            return Err("lexico: hibernation unsupported with adaptive dictionaries".into());
        }
        self.mirror_pages()?;
        let m = self.shape.head_dim;
        let mut buf = Vec::new();
        wire::put_u32(&mut buf, SNAP_MAGIC);
        wire::put_u16(&mut buf, SNAP_VERSION);
        buf.push(match self.cfg.precision {
            CoefPrecision::Fp8 => 0,
            CoefPrecision::Fp16 => 1,
            CoefPrecision::Sign => 2,
        });
        wire::put_u32(&mut buf, self.shape.n_layers as u32);
        wire::put_u32(&mut buf, self.shape.n_kv_heads as u32);
        wire::put_u32(&mut buf, m as u32);
        wire::put_u64(&mut buf, self.tokens as u64);
        wire::put_u32(&mut buf, self.heads.len() as u32);
        for h in &self.heads {
            wire::put_u32(&mut buf, h.pages.len() as u32);
            for slot in &h.pages {
                let (at, bytes) = match slot {
                    PageSlot::Mirrored { page, at } => (*at, page.bytes()),
                    PageSlot::Spilled { at, bytes } => (*at, *bytes),
                    PageSlot::Resident(_) => unreachable!("mirror_pages left a Resident slot"),
                };
                wire::put_u64(&mut buf, at.offset);
                wire::put_u32(&mut buf, at.len);
                wire::put_u64(&mut buf, bytes.to_bits());
            }
            wire::put_bytes(&mut buf, &store::encode_page(&h.tail_k, &h.tail_v));
            wire::put_u32(&mut buf, h.n_csr as u32);
            wire::put_u32(&mut buf, h.buf_len as u32);
            wire::put_f32s(&mut buf, &h.k_buf[..h.buf_len * m]);
            wire::put_f32s(&mut buf, &h.v_buf[..h.buf_len * m]);
        }
        Ok(buf)
    }

    /// Rebuild from a [`Self::hibernate_state`] blob into a freshly built
    /// cache of the same configuration. Pages come back as `Spilled` refs
    /// (resident bytes stay freed until [`Self::fault_all`]); the tail and
    /// buffer are restored bit-exactly, so the continued decode stream is
    /// bitwise identical to the never-hibernated session.
    fn restore_hibernated(&mut self, blob: &[u8]) -> Result<(), String> {
        if self.tokens != 0 {
            return Err("lexico: restore_hibernated requires a freshly built cache".into());
        }
        if self.cfg.adaptive.is_some() {
            return Err("lexico: hibernation unsupported with adaptive dictionaries".into());
        }
        if self.spill.is_none() {
            return Err("lexico: restore requires a spill store (--spill-dir)".into());
        }
        let m = self.shape.head_dim;
        let mut r = wire::Reader::new(blob);
        if r.take_u32()? != SNAP_MAGIC {
            return Err("lexico snapshot: bad magic".into());
        }
        if r.take_u16()? != SNAP_VERSION {
            return Err("lexico snapshot: unsupported version".into());
        }
        let mode = match r.take_u8()? {
            0 => CoefPrecision::Fp8,
            1 => CoefPrecision::Fp16,
            2 => CoefPrecision::Sign,
            b => return Err(format!("lexico snapshot: bad coefficient-mode byte {b}")),
        };
        if mode != self.cfg.precision {
            return Err("lexico snapshot: coefficient mode mismatch".into());
        }
        let (nl, nkv, sm) = (r.take_u32()?, r.take_u32()?, r.take_u32()?);
        if (nl as usize, nkv as usize, sm as usize)
            != (self.shape.n_layers, self.shape.n_kv_heads, m)
        {
            return Err(format!(
                "lexico snapshot: shape mismatch (snapshot {nl}x{nkv}x{sm}, cache {}x{}x{})",
                self.shape.n_layers, self.shape.n_kv_heads, m
            ));
        }
        let tokens = r.take_u64()? as usize;
        let n_heads = r.take_u32()? as usize;
        if n_heads != self.heads.len() {
            return Err("lexico snapshot: head count mismatch".into());
        }
        let mut heads = Vec::with_capacity(n_heads);
        let mut csr_bytes = 0.0f64;
        let mut spilled_bytes = 0.0f64;
        let mut buf_tokens = 0usize;
        for _ in 0..n_heads {
            let n_pages = r.take_u32()? as usize;
            let mut pages = Vec::with_capacity(n_pages);
            for _ in 0..n_pages {
                let at = PageRef { offset: r.take_u64()?, len: r.take_u32()? };
                let bytes = f64::from_bits(r.take_u64()?);
                if !bytes.is_finite() || bytes < 0.0 {
                    return Err("lexico snapshot: corrupt page byte count".into());
                }
                spilled_bytes += bytes;
                pages.push(PageSlot::Spilled { at, bytes });
            }
            let tail_blob = r.take_bytes()?;
            let (tail_k, tail_v) =
                store::decode_page(&tail_blob, 0).map_err(|e| format!("lexico snapshot: {e}"))?;
            if tail_k.rows() >= PAGE_TOKENS {
                return Err("lexico snapshot: tail at or above page size".into());
            }
            if tail_k.precision() != self.cfg.precision {
                return Err("lexico snapshot: tail precision mismatch".into());
            }
            let n_csr = r.take_u32()? as usize;
            if n_csr != n_pages * PAGE_TOKENS + tail_k.rows() {
                return Err("lexico snapshot: token count inconsistent with pages + tail".into());
            }
            let buf_len = r.take_u32()? as usize;
            let k_buf = r.take_f32s()?;
            let v_buf = r.take_f32s()?;
            if k_buf.len() != buf_len * m || v_buf.len() != buf_len * m {
                return Err("lexico snapshot: buffer length mismatch".into());
            }
            csr_bytes += (tail_k.bytes() + tail_v.bytes()) as f64;
            buf_tokens += buf_len;
            heads.push(HeadState { pages, tail_k, tail_v, n_csr, k_buf, v_buf, buf_len });
        }
        if !r.is_empty() {
            return Err("lexico snapshot: trailing bytes".into());
        }
        self.heads = heads;
        self.tokens = tokens;
        self.csr_bytes = csr_bytes;
        self.spilled_bytes = spilled_bytes;
        self.buf_tokens = buf_tokens;
        Ok(())
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    /// O(1) in context length: CSR bytes accumulate as rows are pushed
    /// (`csr_bytes`, paper §3.4 per-row formula — exact, all summands are
    /// integers) and buffer tokens are counted on push/drain
    /// (`buf_tokens`); only the per-layer adaptive overlays are consulted
    /// per call. The batcher's admission loop calls this every round for
    /// every session, so it must not re-walk the stored rows.
    fn mem_bytes(&self) -> f64 {
        let m = self.shape.head_dim;
        let mut bytes = self.csr_bytes + (self.buf_tokens * 2 * m * 2) as f64; // buffer @ FP16
        // adaptive atoms are session-private → charged to KV size (§4.2.4);
        // atoms a refresh folded into this session's dictionary rotation
        // stay charged — they still exist only because this session grew them
        for ad in self.adaptive_k.iter().chain(&self.adaptive_v).flatten() {
            bytes += ad.extra_bytes() as f64;
        }
        bytes + (self.folded_extra_atoms * m * 2) as f64
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        let mut s = format!("lexico_s{}", self.cfg.sparsity);
        if self.cfg.delta > 0.0 {
            s += &format!("_d{:.2}", self.cfg.delta);
        }
        if self.cfg.adaptive.is_some() {
            s += "_adaptive";
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(n_atoms: usize, cfg: LexicoConfig) -> (CacheShape, LexicoCache) {
        let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let dicts = DictionarySet {
            keys: (0..2).map(|i| crate::dict::Dictionary::random(16, n_atoms, i)).collect(),
            values: (0..2).map(|i| crate::dict::Dictionary::random(16, n_atoms, 100 + i)).collect(),
        };
        let c = LexicoCache::new(shape, Arc::new(dicts), cfg);
        (shape, c)
    }

    /// The cache's *current* state as a runtime value: applying it back is
    /// a no-op, so tests can attach one extra resource (pool, spill store)
    /// without perturbing the tier/mode the cache resolved from its env —
    /// keeping the parity suites valid under every `LEXICO_*` CI job.
    fn rt_of(c: &LexicoCache) -> CacheRuntime {
        CacheRuntime {
            pool: Some(c.pool.clone()),
            spill: c.spill.clone(),
            encode_tier: if c.gram_omp { EncodeTier::Gram } else { EncodeTier::Canonical },
            coef_mode: Some(c.cfg.precision),
            qd_per_head: c.qd_per_head,
        }
    }

    #[test]
    fn buffer_then_compression() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, n_approx: 1, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        // 10 tokens, buffer 4 → 6 compressed per head
        let h = &c.heads[0];
        assert_eq!(h.buf_len, 4);
        assert_eq!(h.n_csr, 6);
        assert!(c.kv_ratio() < 1.0);
        assert_eq!(c.tokens(), 10);
    }

    #[test]
    fn attend_matches_full_cache_when_reconstruction_is_exact() {
        // Identity dictionary (16 atoms = basis) with s=16 reconstructs
        // exactly → Lexico attention must equal full-cache attention.
        let shape = CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 16 };
        let mut atoms = vec![0.0; 16 * 16];
        for i in 0..16 {
            atoms[i * 16 + i] = 1.0;
        }
        let d = crate::dict::Dictionary::new(16, 16, atoms);
        let dicts = DictionarySet { keys: vec![d.clone()], values: vec![d] };
        let cfg = LexicoConfig {
            sparsity: 16,
            n_buffer: 2,
            precision: CoefPrecision::Fp16,
            ..Default::default()
        };
        let mut lex = LexicoCache::new(shape, Arc::new(dicts), cfg);
        let mut full = crate::cache::full::FullCache::new(shape);
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            // keep coordinates modest so fp16 rounding stays negligible
            let k: Vec<f32> = rng.normal_vec(16).iter().map(|x| x * 0.5).collect();
            let v: Vec<f32> = rng.normal_vec(16).iter().map(|x| x * 0.5).collect();
            lex.append(0, &k, &v);
            full.append(0, &k, &v);
        }
        let q = rng.normal_vec(shape.q_dim());
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        lex.attend(0, &q, &mut o1);
        full.attend(0, &q, &mut o2);
        crate::util::prop::assert_close(&o1, &o2, 2e-2, "lexico≈full").unwrap();
    }

    #[test]
    fn batch_entry_points_match_sequential_exactly() {
        // append_batch must leave bit-identical cache state (the batched
        // OMP is bit-equal to sequential OMP and the overflow schedule
        // lands in the same place); attend_batch must be bitwise equal to
        // per-query attends.
        let cfgs = [
            LexicoConfig { sparsity: 4, n_buffer: 5, n_approx: 1, ..Default::default() },
            LexicoConfig { sparsity: 4, n_buffer: 5, n_approx: 3, ..Default::default() },
            // n_a > n_buffer + 1: each sequential trigger compresses only
            // min(n_a, buf_len) — the replayed schedule must match that
            LexicoConfig { sparsity: 4, n_buffer: 2, n_approx: 5, ..Default::default() },
            // adaptive: shared per-layer dictionary mutates per encode, so
            // append_batch must reproduce the sequential head interleave
            LexicoConfig {
                sparsity: 2,
                n_buffer: 5,
                n_approx: 1,
                adaptive: Some((16, 0.2)),
                ..Default::default()
            },
        ];
        for cfg in cfgs {
            let na = cfg.n_approx;
            let (shape, mut seq) = setup(64, cfg.clone());
            let (_, mut bat) = setup(64, cfg);
            let mut rng = Rng::new(31);
            let kvd = shape.kv_dim();
            let n = 11;
            let ks = rng.normal_vec(n * kvd);
            let vs = rng.normal_vec(n * kvd);
            for l in 0..shape.n_layers {
                for i in 0..n {
                    seq.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
                }
                bat.append_batch(l, &ks, &vs, n);
            }
            assert_eq!(seq.tokens(), bat.tokens());
            for (hs, hb) in seq.heads.iter().zip(&bat.heads) {
                assert_eq!(hs.buf_len, hb.buf_len, "na={na}");
                assert_eq!(hs.n_csr, hb.n_csr, "na={na}");
                for (a, b) in hs.k_rows().iter().zip(&hb.k_rows()) {
                    assert_eq!(a.idx, b.idx, "na={na}");
                    assert_eq!(a.coef_bits, b.coef_bits, "na={na}");
                }
                assert_eq!(hs.k_buf, hb.k_buf, "na={na}");
                assert_eq!(hs.v_buf, hb.v_buf, "na={na}");
            }
            assert_eq!(seq.mem_bytes(), bat.mem_bytes(), "na={na}");
            // attention parity over a query batch
            let b = 3;
            let qd = shape.q_dim();
            let qs = rng.normal_vec(b * qd);
            let mut o_seq = vec![0.0; b * qd];
            let mut o_bat = vec![0.0; b * qd];
            for i in 0..b {
                seq.attend(0, &qs[i * qd..(i + 1) * qd], &mut o_seq[i * qd..(i + 1) * qd]);
            }
            bat.attend_batch(0, &qs, &mut o_bat, b);
            assert_eq!(o_seq, o_bat, "na={na}: attend_batch diverged");
        }
    }

    #[test]
    fn gram_tier_cache_parity_across_precisions_and_delta() {
        // The gram encode tier through the real overflow path, across both
        // coefficient precisions and both termination modes: whenever a row
        // compresses to the same support as the canonical tier it must be
        // bit-identical (indices AND quantized coefficient bits — identical
        // selections force identical pursuits); on an argmax near-tie flip
        // the stored reconstruction may differ but can be no worse than
        // canonical beyond the 1e-4 tolerance.
        fn relerr(orig: &[f32], rec: &[f32]) -> f32 {
            let mut e = 0.0f32;
            let mut n = 0.0f32;
            for i in 0..orig.len() {
                let d = orig[i] - rec[i];
                e += d * d;
                n += orig[i] * orig[i];
            }
            e.sqrt() / n.sqrt().max(1e-12)
        }
        for &prec in &[CoefPrecision::Fp8, CoefPrecision::Fp16] {
            for &delta in &[0.0f32, 0.4] {
                let cfg = LexicoConfig {
                    sparsity: 4,
                    delta,
                    n_buffer: 4,
                    n_approx: 2,
                    precision: prec,
                    ..Default::default()
                };
                let (shape, mut canon) = setup(64, cfg.clone());
                let (_, mut gram) = setup(64, cfg);
                // pin the tiers explicitly so the dispatch-proof asserts
                // below hold even under the LEXICO_GRAM_OMP=1 CI job
                canon.set_runtime(&CacheRuntime::default());
                gram.set_runtime(&CacheRuntime::default().with_encode_tier(EncodeTier::Gram));
                let mut rng = Rng::new(97);
                let kvd = shape.kv_dim();
                let m = shape.head_dim;
                let n_tok = 14;
                let ks = rng.normal_vec(n_tok * kvd);
                let vs = rng.normal_vec(n_tok * kvd);
                for i in 0..n_tok {
                    for l in 0..shape.n_layers {
                        canon.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
                        gram.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
                    }
                }
                // dispatch proof: only the gram cache realized Gram caches
                assert_eq!(canon.dicts.gram_bytes(), 0, "canonical cache built a Gram matrix");
                assert!(gram.dicts.gram_bytes() > 0, "gram tier never realized its Gram matrix");
                let (mut rec_c, mut rec_g) = (vec![0.0f32; m], vec![0.0f32; m]);
                for l in 0..shape.n_layers {
                    for g in 0..shape.n_kv_heads {
                        let (kc, vc) = canon.csr_rows(l, g);
                        let (kg, vg) = gram.csr_rows(l, g);
                        assert_eq!(kc.len(), kg.len(), "compressed-token counts diverged");
                        for (is_key, (rows_c, rows_g)) in
                            [(true, (&kc, &kg)), (false, (&vc, &vg))]
                        {
                            let (src, atoms) = if is_key {
                                (&ks, &canon.dicts.keys[l].atoms)
                            } else {
                                (&vs, &canon.dicts.values[l].atoms)
                            };
                            for (t, (rc, rg)) in rows_c.iter().zip(rows_g.iter()).enumerate() {
                                if rc.idx == rg.idx && rc.coef_bits == rg.coef_bits {
                                    continue; // identical row, nothing to bound
                                }
                                let orig = &src[t * kvd + g * m..t * kvd + (g + 1) * m];
                                rc.reconstruct(atoms, m, &mut rec_c);
                                rg.reconstruct(atoms, m, &mut rec_g);
                                let (ec, eg) = (relerr(orig, &rec_c), relerr(orig, &rec_g));
                                assert!(
                                    eg <= ec + 1e-4,
                                    "l={l} g={g} t={t} key={is_key} prec={prec:?} δ={delta}: \
                                     gram {eg} > canon {ec} + 1e-4"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gram_tier_append_batch_and_fork_stay_bitwise_identical() {
        // Under the gram tier the cache's own determinism contract must
        // hold exactly as under canonical: append_batch replays the
        // sequential trigger schedule bit-identically (per-vector pursuits
        // are independent of batch composition), and a fork inherits the
        // tier and stays bitwise aligned with the original.
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 5, n_approx: 2, ..Default::default() };
        let (shape, mut seq) = setup(64, cfg.clone());
        let (_, mut bat) = setup(64, cfg);
        seq.set_runtime(&CacheRuntime::default().with_encode_tier(EncodeTier::Gram));
        bat.set_runtime(&CacheRuntime::default().with_encode_tier(EncodeTier::Gram));
        let mut rng = Rng::new(53);
        let kvd = shape.kv_dim();
        let n = 13;
        let ks = rng.normal_vec(n * kvd);
        let vs = rng.normal_vec(n * kvd);
        for l in 0..shape.n_layers {
            for i in 0..n {
                seq.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
            }
            bat.append_batch(l, &ks, &vs, n);
        }
        for (hs, hb) in seq.heads.iter().zip(&bat.heads) {
            assert_eq!(hs.buf_len, hb.buf_len);
            assert_eq!(hs.n_csr, hb.n_csr);
            for (a, b) in hs.k_rows().iter().zip(&hb.k_rows()) {
                assert_eq!(a.idx, b.idx, "gram tier: append_batch K support diverged");
                assert_eq!(a.coef_bits, b.coef_bits, "gram tier: append_batch K coefs diverged");
            }
            for (a, b) in hs.v_rows().iter().zip(&hb.v_rows()) {
                assert_eq!(a.idx, b.idx, "gram tier: append_batch V support diverged");
                assert_eq!(a.coef_bits, b.coef_bits, "gram tier: append_batch V coefs diverged");
            }
        }
        // fork inherits the tier: continuing both sides stays bit-identical
        let mut f = seq.fork();
        let k = rng.normal_vec(kvd);
        let v = rng.normal_vec(kvd);
        for _ in 0..6 {
            for l in 0..shape.n_layers {
                seq.append(l, &k, &v);
                f.append(l, &k, &v);
            }
        }
        let q = rng.normal_vec(shape.q_dim());
        let (mut o1, mut o2) = (vec![0.0; shape.q_dim()], vec![0.0; shape.q_dim()]);
        seq.attend(0, &q, &mut o1);
        f.attend(0, &q, &mut o2);
        assert_eq!(o1, o2, "gram tier: fork attend diverged after overflow compression");
    }

    #[test]
    fn prefill_compresses_all_but_buffer() {
        let cfg = LexicoConfig { sparsity: 2, n_buffer: 3, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(5);
        let t = 9;
        let ks = rng.normal_vec(t * shape.kv_dim());
        let vs = rng.normal_vec(t * shape.kv_dim());
        for l in 0..shape.n_layers {
            c.ingest_prefill(l, &ks, &vs, t, &[], 0);
        }
        assert_eq!(c.heads[0].buf_len, 3);
        assert_eq!(c.heads[0].n_csr, 6);
        assert_eq!(c.tokens(), t);
    }

    #[test]
    fn memory_accounting_matches_formula() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 2, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(7);
        for _ in 0..6 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        // per head: 4 csr tokens ≤ (3·4+2)·2 rows... plus 2 buffer tokens
        // random vectors are dense: every row has exactly s=4 nnz
        let per_head = 4 * (3 * 4 + 2) * 2 + 2 * 2 * 16 * 2;
        let total = per_head * shape.n_layers * shape.n_kv_heads;
        assert_eq!(c.mem_bytes(), total as f64);
    }

    #[test]
    fn fork_shares_sealed_pages_and_stays_bitwise_identical() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 2, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(17);
        // enough appends to seal at least one PAGE_TOKENS page per head
        for _ in 0..PAGE_TOKENS + 8 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        assert!(!c.heads[0].pages.is_empty());
        assert_eq!(c.shared_prefix_bytes(), 0.0, "no forks yet → nothing shared");

        let mut f = c.fork();
        assert_eq!(f.tokens(), c.tokens());
        assert_eq!(f.mem_bytes(), c.mem_bytes());
        assert!(c.shared_prefix_bytes() > 0.0, "sealed pages now shared");
        assert_eq!(f.shared_prefix_bytes(), c.shared_prefix_bytes());
        assert!(
            f.shared_prefix_bytes() < f.mem_bytes(),
            "tail + buffer stay private"
        );

        // identical continuations must match bitwise
        let q = rng.normal_vec(shape.q_dim());
        let (mut o1, mut o2) = (vec![0.0; shape.q_dim()], vec![0.0; shape.q_dim()]);
        c.attend(0, &q, &mut o1);
        f.attend(0, &q, &mut o2);
        assert_eq!(o1, o2, "fork attend diverged");
        let k = rng.normal_vec(shape.kv_dim());
        let v = rng.normal_vec(shape.kv_dim());
        for l in 0..shape.n_layers {
            c.append(l, &k, &v);
            f.append(l, &k, &v);
        }
        c.attend(1, &q, &mut o1);
        f.attend(1, &q, &mut o2);
        assert_eq!(o1, o2, "fork diverged after post-fork appends");

        // divergent continuation of the fork must not disturb the original
        let before = o1.clone();
        let k2 = rng.normal_vec(shape.kv_dim());
        let v2 = rng.normal_vec(shape.kv_dim());
        f.append(1, &k2, &v2);
        c.attend(1, &q, &mut o1);
        assert_eq!(o1, before, "fork mutation leaked into the original");

        // dropping the fork releases the sharing
        drop(f);
        assert_eq!(c.shared_prefix_bytes(), 0.0);
    }

    #[test]
    fn split_prefill_matches_cold_prefill_bitwise() {
        // ingest(prefix) + ingest(suffix) must equal ingest(prefix++suffix)
        // for the non-adaptive configs (the prefix-cache contract).
        for cfg in [
            LexicoConfig { sparsity: 4, n_buffer: 3, ..Default::default() },
            LexicoConfig {
                sparsity: 4,
                n_buffer: 3,
                precision: CoefPrecision::Fp16,
                ..Default::default()
            },
        ] {
            let (shape, mut cold) = setup(64, cfg.clone());
            assert!(cold.caps().split_prefill_exact);
            let (_, mut split) = setup(64, cfg);
            let mut rng = Rng::new(23);
            let (tp, ts) = (9, 5);
            let ks = rng.normal_vec((tp + ts) * shape.kv_dim());
            let vs = rng.normal_vec((tp + ts) * shape.kv_dim());
            let cut = tp * shape.kv_dim();
            for l in 0..shape.n_layers {
                cold.ingest_prefill(l, &ks, &vs, tp + ts, &[], 0);
                split.ingest_prefill(l, &ks[..cut], &vs[..cut], tp, &[], 0);
                split.ingest_prefill(l, &ks[cut..], &vs[cut..], ts, &[], 0);
            }
            assert_eq!(cold.tokens(), split.tokens());
            assert_eq!(cold.mem_bytes(), split.mem_bytes());
            for (hc, hs) in cold.heads.iter().zip(&split.heads) {
                assert_eq!(hc.n_csr, hs.n_csr);
                for (a, b) in hc.k_rows().iter().zip(&hs.k_rows()) {
                    assert_eq!((&a.idx, &a.coef_bits), (&b.idx, &b.coef_bits));
                }
                for (a, b) in hc.v_rows().iter().zip(&hs.v_rows()) {
                    assert_eq!((&a.idx, &a.coef_bits), (&b.idx, &b.coef_bits));
                }
                assert_eq!(hc.k_buf, hs.k_buf);
                assert_eq!(hc.v_buf, hs.v_buf);
            }
        }
        // adaptive mode must *declare* itself split-inexact — and the rest
        // of its capability surface flips with it: no hibernation (the
        // overlay is not in the page format), refresh available
        let (_, c) = setup(16, LexicoConfig {
            sparsity: 2,
            n_buffer: 2,
            adaptive: Some((8, 0.1)),
            ..Default::default()
        });
        let caps = c.caps();
        assert!(!caps.split_prefill_exact);
        assert!(!caps.hibernate);
        assert!(caps.dict_refresh);
        assert!(caps.shared_dicts && caps.spill);
    }

    /// The retained row-iterator reference: the pre-slab attend, written
    /// against `k_rows()`/`v_rows()` exactly as the old storage walked its
    /// per-token `Vec<CsrRow>`s. Uses the same canonical `dot`/`axpy`
    /// kernels, so the flat-slab attend must match it bit for bit.
    fn reference_attend_rows(c: &LexicoCache, layer: usize, q: &[f32], out: &mut [f32]) {
        let m = c.shape.head_dim;
        let n_heads = c.shape.n_heads;
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        let (k_atoms, k_n) = {
            let (a, n) = c.atoms(layer, true);
            (a.to_vec(), n)
        };
        let (v_atoms, v_n) = {
            let (a, n) = c.atoms(layer, false);
            (a.to_vec(), n)
        };
        let mut qd = vec![0.0f32; n_heads * k_n];
        for n in 0..k_n {
            let atom = &k_atoms[n * m..(n + 1) * m];
            for h in 0..n_heads {
                qd[h * k_n + n] = dot(&q[h * m..(h + 1) * m], atom);
            }
        }
        let mut scores = Vec::new();
        let mut z = vec![0.0f32; v_n];
        for h in 0..n_heads {
            let g = h / c.shape.group();
            let head = &c.heads[c.head_idx(layer, g)];
            let (k_rows, v_rows) = (head.k_rows(), head.v_rows());
            let tc = head.n_csr;
            let tb = head.buf_len;
            let qh = &q[h * m..(h + 1) * m];
            let qdh = &qd[h * k_n..(h + 1) * k_n];
            scores.clear();
            scores.resize(tc + tb, 0.0);
            for (ti, row) in k_rows.iter().enumerate() {
                let mut sc = 0.0;
                for j in 0..row.nnz() {
                    sc += qdh[row.idx[j] as usize] * row.coef(j);
                }
                scores[ti] = sc * scale;
            }
            for ti in 0..tb {
                scores[tc + ti] = dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
            }
            softmax(&mut scores[..tc + tb]);
            let oh = &mut out[h * m..(h + 1) * m];
            z.fill(0.0);
            for (ti, row) in v_rows.iter().enumerate() {
                let w = scores[ti];
                for j in 0..row.nnz() {
                    z[row.idx[j] as usize] += w * row.coef(j);
                }
            }
            for (n, &zn) in z.iter().enumerate() {
                if zn != 0.0 {
                    axpy(oh, zn, &v_atoms[n * m..(n + 1) * m]);
                }
            }
            for ti in 0..tb {
                axpy(oh, scores[tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
            }
        }
    }

    #[test]
    fn flat_slab_attend_matches_row_iterator_reference_bitwise() {
        // The tentpole parity property: the linear slab sweeps must equal
        // the retained row-by-row reference bit for bit — per precision,
        // with sealed pages AND an unsealed tail, and through attend_batch.
        use crate::util::prop::Prop;
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16] {
            Prop::new(6).seed(0x51AB + prec.bytes_per_coef() as u64).check(
                "slab_vs_rows",
                |rng, _| {
                    let cfg = LexicoConfig {
                        sparsity: 4,
                        n_buffer: 3,
                        precision: prec,
                        ..Default::default()
                    };
                    let (shape, mut c) = setup(64, cfg);
                    // enough tokens to seal ≥1 page and leave a ragged tail
                    let n_tok = PAGE_TOKENS + 3 + rng.below(PAGE_TOKENS);
                    for _ in 0..n_tok {
                        let k = rng.normal_vec(shape.kv_dim());
                        let v = rng.normal_vec(shape.kv_dim());
                        for l in 0..shape.n_layers {
                            c.append(l, &k, &v);
                        }
                    }
                    assert!(!c.heads[0].pages.is_empty());
                    let q = rng.normal_vec(shape.q_dim());
                    let mut got = vec![0.0; shape.q_dim()];
                    let mut want = vec![0.0; shape.q_dim()];
                    c.attend(0, &q, &mut got);
                    reference_attend_rows(&c, 0, &q, &mut want);
                    if got != want {
                        return Err(format!("slab attend diverged from row reference ({prec:?})"));
                    }
                    // attend_batch over the same state must agree too
                    let b = 2;
                    let qs = rng.normal_vec(b * shape.q_dim());
                    let mut ob = vec![0.0; b * shape.q_dim()];
                    c.attend_batch(1, &qs, &mut ob, b);
                    for qi in 0..b {
                        let mut w = vec![0.0; shape.q_dim()];
                        reference_attend_rows(
                            &c,
                            1,
                            &qs[qi * shape.q_dim()..(qi + 1) * shape.q_dim()],
                            &mut w,
                        );
                        if ob[qi * shape.q_dim()..(qi + 1) * shape.q_dim()] != w[..] {
                            return Err(format!("attend_batch row {qi} diverged ({prec:?})"));
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    /// Sign-mode reference attend: the row-iterator storage walk with the
    /// sign sweeps' documented op order replicated exactly — per K row
    /// `Σ(±qd)` first, then `·α`, then `·scale`; per V row the magnitude is
    /// folded once (`wrα = w·α`) and added/subtracted per bin. The linear
    /// sign slab sweeps must match this bit for bit.
    fn reference_attend_sign(c: &LexicoCache, layer: usize, q: &[f32], out: &mut [f32]) {
        let m = c.shape.head_dim;
        let n_heads = c.shape.n_heads;
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        let (k_atoms, k_n) = {
            let (a, n) = c.atoms(layer, true);
            (a.to_vec(), n)
        };
        let (v_atoms, v_n) = {
            let (a, n) = c.atoms(layer, false);
            (a.to_vec(), n)
        };
        let mut qd = vec![0.0f32; n_heads * k_n];
        for n in 0..k_n {
            let atom = &k_atoms[n * m..(n + 1) * m];
            for h in 0..n_heads {
                qd[h * k_n + n] = dot(&q[h * m..(h + 1) * m], atom);
            }
        }
        let mut scores = Vec::new();
        let mut z = vec![0.0f32; v_n];
        for h in 0..n_heads {
            let g = h / c.shape.group();
            let head = &c.heads[c.head_idx(layer, g)];
            let (k_rows, v_rows) = (head.k_rows(), head.v_rows());
            let tc = head.n_csr;
            let tb = head.buf_len;
            let qh = &q[h * m..(h + 1) * m];
            let qdh = &qd[h * k_n..(h + 1) * k_n];
            scores.clear();
            scores.resize(tc + tb, 0.0);
            for (ti, row) in k_rows.iter().enumerate() {
                let alpha = if row.nnz() > 0 { row.coef(0).abs() } else { 0.0 };
                let mut sc = 0.0f32;
                for j in 0..row.nnz() {
                    let qv = qdh[row.idx[j] as usize];
                    if row.coef_bits[j] != 0 {
                        sc -= qv;
                    } else {
                        sc += qv;
                    }
                }
                scores[ti] = (sc * alpha) * scale;
            }
            for ti in 0..tb {
                scores[tc + ti] = dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
            }
            softmax(&mut scores[..tc + tb]);
            let oh = &mut out[h * m..(h + 1) * m];
            z.fill(0.0);
            for (ti, row) in v_rows.iter().enumerate() {
                let alpha = if row.nnz() > 0 { row.coef(0).abs() } else { 0.0 };
                let wra = scores[ti] * alpha;
                for j in 0..row.nnz() {
                    let bin = row.idx[j] as usize;
                    if row.coef_bits[j] != 0 {
                        z[bin] -= wra;
                    } else {
                        z[bin] += wra;
                    }
                }
            }
            for (n, &zn) in z.iter().enumerate() {
                if zn != 0.0 {
                    axpy(oh, zn, &v_atoms[n * m..(n + 1) * m]);
                }
            }
            for ti in 0..tb {
                axpy(oh, scores[tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
            }
        }
    }

    #[test]
    fn sign_slab_attend_matches_sign_reference_bitwise() {
        // The sign-tier parity property: ±α slab sweeps vs the row-walk
        // reference with identical op order — sealed pages + ragged tail,
        // through attend AND attend_batch.
        use crate::util::prop::Prop;
        Prop::new(6).seed(0x516e).check("sign_slab_vs_rows", |rng, _| {
            let cfg = LexicoConfig {
                sparsity: 4,
                n_buffer: 3,
                precision: CoefPrecision::Sign,
                ..Default::default()
            };
            let (shape, mut c) = setup(64, cfg);
            let n_tok = PAGE_TOKENS + 3 + rng.below(PAGE_TOKENS);
            for _ in 0..n_tok {
                let k = rng.normal_vec(shape.kv_dim());
                let v = rng.normal_vec(shape.kv_dim());
                for l in 0..shape.n_layers {
                    c.append(l, &k, &v);
                }
            }
            assert!(!c.heads[0].pages.is_empty());
            let q = rng.normal_vec(shape.q_dim());
            let mut got = vec![0.0; shape.q_dim()];
            let mut want = vec![0.0; shape.q_dim()];
            c.attend(0, &q, &mut got);
            reference_attend_sign(&c, 0, &q, &mut want);
            if got != want {
                return Err("sign slab attend diverged from row reference".into());
            }
            let b = 2;
            let qs = rng.normal_vec(b * shape.q_dim());
            let mut ob = vec![0.0; b * shape.q_dim()];
            c.attend_batch(1, &qs, &mut ob, b);
            for qi in 0..b {
                let mut w = vec![0.0; shape.q_dim()];
                reference_attend_sign(
                    &c,
                    1,
                    &qs[qi * shape.q_dim()..(qi + 1) * shape.q_dim()],
                    &mut w,
                );
                if ob[qi * shape.q_dim()..(qi + 1) * shape.q_dim()] != w[..] {
                    return Err(format!("sign attend_batch row {qi} diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sign_mode_sharded_sweep_is_bitwise_deterministic() {
        // The acceptance contract at the cache layer: sign-mode decode is
        // bitwise self-identical at T ∈ {1, 2, 4} and equal to the serial
        // sweep (sharded score ranges are disjoint, per-element order fixed).
        let cfg = LexicoConfig {
            sparsity: 4,
            n_buffer: 4,
            precision: CoefPrecision::Sign,
            ..Default::default()
        };
        let n_tok = 3 * PAGE_TOKENS + 7;
        let mut rng = Rng::new(67);
        let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let ks = rng.normal_vec(n_tok * shape.kv_dim());
        let vs = rng.normal_vec(n_tok * shape.kv_dim());
        let q = rng.normal_vec(shape.q_dim());
        let qs = rng.normal_vec(3 * shape.q_dim());
        let fill = |c: &mut LexicoCache| {
            for i in 0..n_tok {
                for l in 0..shape.n_layers {
                    c.append(
                        l,
                        &ks[i * shape.kv_dim()..(i + 1) * shape.kv_dim()],
                        &vs[i * shape.kv_dim()..(i + 1) * shape.kv_dim()],
                    );
                }
            }
        };
        let (_, mut serial) = setup(64, cfg.clone());
        fill(&mut serial);
        let mut want = vec![0.0; shape.q_dim()];
        serial.attend(0, &q, &mut want);
        let mut want_b = vec![0.0; 3 * shape.q_dim()];
        serial.attend_batch(1, &qs, &mut want_b, 3);
        for threads in [1usize, 2, 4] {
            let (_, mut c) = setup(64, cfg.clone());
            let rt = rt_of(&c).with_pool(Arc::new(crate::exec::ExecPool::new(threads)));
            c.set_runtime(&rt);
            c.set_par_score_min(16);
            fill(&mut c);
            let mut got = vec![0.0; shape.q_dim()];
            c.attend(0, &q, &mut got);
            assert_eq!(got, want, "sign sharded attend diverged at T={threads}");
            let mut got_b = vec![0.0; 3 * shape.q_dim()];
            c.attend_batch(1, &qs, &mut got_b, 3);
            assert_eq!(got_b, want_b, "sign sharded attend_batch diverged at T={threads}");
        }
    }

    #[test]
    fn fork_inherits_applied_runtime() {
        // fork() must carry the applied CacheRuntime wholesale: the fork of
        // a gram-tier FP16-mode cache compresses exactly like a cache that
        // was explicitly configured that way.
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 3, ..Default::default() };
        let (shape, mut c) = setup(64, cfg.clone());
        let rt = rt_of(&c)
            .with_pool(Arc::new(crate::exec::ExecPool::new(3)))
            .with_encode_tier(EncodeTier::Gram)
            .with_coef_mode(CoefPrecision::Fp16)
            .with_qd_per_head(true);
        c.set_runtime(&rt);
        assert_eq!(c.cfg.precision, CoefPrecision::Fp16, "empty cache takes the mode override");
        assert_eq!(c.dicts.gram_bytes(), 0);
        let mut f = c.fork();

        // reference: a cache explicitly built under the same runtime
        // (setup() is seed-deterministic, so the dictionaries are equal)
        let (_, mut want) = setup(64, cfg);
        want.set_runtime(&rt);

        let mut rng = Rng::new(171);
        let kvd = shape.kv_dim();
        for _ in 0..14 {
            let k = rng.normal_vec(kvd);
            let v = rng.normal_vec(kvd);
            for l in 0..shape.n_layers {
                f.append(l, &k, &v);
                want.append(l, &k, &v);
            }
        }
        // only the fork touched the parent's shared dictionaries — a
        // realized Gram proves the fork inherited the encode tier
        assert_eq!(c.tokens(), 0);
        assert!(c.dicts.gram_bytes() > 0, "fork did not inherit the gram tier");
        // inherited FP16 mode: identical accounting, bitwise-equal decode
        assert_eq!(f.mem_bytes(), want.mem_bytes());
        let q = rng.normal_vec(shape.q_dim());
        let (mut o1, mut o2) = (vec![0.0; shape.q_dim()], vec![0.0; shape.q_dim()]);
        f.attend(0, &q, &mut o1);
        want.attend(0, &q, &mut o2);
        assert_eq!(o1, o2, "forked runtime diverged from the explicitly configured cache");

        // a late mode override is ignored: the stored rows are already
        // quantized, so the cache keeps its mode once tokens exist
        let rt2 = rt_of(&want).with_coef_mode(CoefPrecision::Sign);
        want.set_runtime(&rt2);
        assert_eq!(want.cfg.precision, CoefPrecision::Fp16);
    }

    #[test]
    fn dict_refresh_folds_overlays_rotates_generation_and_keeps_decode_bitwise() {
        // capability gate: the plain universal-dictionary path has nothing
        // to refresh and must say so
        let (_, mut plain) = setup(64, LexicoConfig { sparsity: 4, n_buffer: 3, ..Default::default() });
        assert!(!plain.caps().dict_refresh);
        assert!(plain.refresh_dicts().is_err());

        let cfg = LexicoConfig {
            sparsity: 2,
            n_buffer: 2,
            adaptive: Some((8, 0.05)),
            ..Default::default()
        };
        let (shape, mut c) = setup(16, cfg); // tiny dict → growth certain
        let mut rng = Rng::new(181);
        for _ in 0..10 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let extra: usize = c
            .adaptive_k
            .iter()
            .chain(&c.adaptive_v)
            .flatten()
            .map(|a| a.n_extra)
            .sum();
        assert!(extra > 0, "adaptive dict never grew");
        let q = rng.normal_vec(shape.q_dim());
        let mut before = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut before);
        let mem_before = c.mem_bytes();
        let old_dicts = c.shared_dicts().unwrap();
        let old_atoms: usize = old_dicts.keys.iter().chain(&old_dicts.values).map(|d| d.n).sum();

        let folded = c.refresh_dicts().unwrap();
        assert_eq!(folded, extra, "refresh must fold every pending overlay atom");

        // rotation: a NEW DictionarySet whose refreshed layers moved to the
        // next generation and absorbed the overlay atoms at their indices
        let new_dicts = c.shared_dicts().unwrap();
        assert!(!Arc::ptr_eq(&old_dicts, &new_dicts), "refresh must rotate the dict set");
        let new_atoms: usize = new_dicts.keys.iter().chain(&new_dicts.values).map(|d| d.n).sum();
        assert_eq!(new_atoms, old_atoms + folded);
        for (old, new) in old_dicts
            .keys
            .iter()
            .chain(&old_dicts.values)
            .zip(new_dicts.keys.iter().chain(&new_dicts.values))
        {
            if new.n > old.n {
                assert_eq!(new.generation(), old.generation() + 1);
            }
        }
        // overlays drained → full headroom reopened
        let left: usize = c
            .adaptive_k
            .iter()
            .chain(&c.adaptive_v)
            .flatten()
            .map(|a| a.n_extra)
            .sum();
        assert_eq!(left, 0);

        // the determinism contract across a refresh: decode is bitwise
        // unchanged (codes reference the same atom values at the same
        // indices) and the folded atoms stay charged to this session
        let mut after = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut after);
        assert_eq!(before, after, "refresh changed decode bits");
        assert_eq!(c.mem_bytes(), mem_before, "refresh must not un-charge folded atoms");

        // nothing pending → Ok(0), no rotation
        let unchanged = c.shared_dicts().unwrap();
        assert_eq!(c.refresh_dicts().unwrap(), 0);
        assert!(Arc::ptr_eq(&unchanged, &c.shared_dicts().unwrap()));

        // the session keeps serving and can grow into the reopened headroom
        for _ in 0..10 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let mut o = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut o);
        assert!(o.iter().all(|x| x.is_finite()));
        // a fork carries the folded-atom charge with it
        let f = c.fork();
        assert_eq!(f.mem_bytes(), c.mem_bytes());
    }

    #[test]
    fn pool_sharded_score_sweep_is_bitwise_identical_at_every_thread_count() {
        // Lower the shard threshold so a ~3-page context exercises the
        // sharded path, then compare attend outputs across pool sizes —
        // and against the unsharded sweep — bitwise. Each pool size gets
        // its own cache fed the identical token stream (OMP codes are
        // bitwise pool-independent, so the stored state is identical too).
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, ..Default::default() };
        let n_tok = 3 * PAGE_TOKENS + 7;
        let mut rng = Rng::new(61);
        let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let ks = rng.normal_vec(n_tok * shape.kv_dim());
        let vs = rng.normal_vec(n_tok * shape.kv_dim());
        let q = rng.normal_vec(shape.q_dim());
        let qs = rng.normal_vec(3 * shape.q_dim());
        let fill = |c: &mut LexicoCache| {
            for i in 0..n_tok {
                for l in 0..shape.n_layers {
                    c.append(
                        l,
                        &ks[i * shape.kv_dim()..(i + 1) * shape.kv_dim()],
                        &vs[i * shape.kv_dim()..(i + 1) * shape.kv_dim()],
                    );
                }
            }
        };
        // reference: default threshold → the serial sweep
        let (_, mut serial) = setup(64, cfg.clone());
        fill(&mut serial);
        let mut want = vec![0.0; shape.q_dim()];
        serial.attend(0, &q, &mut want);
        let mut want_b = vec![0.0; 3 * shape.q_dim()];
        serial.attend_batch(1, &qs, &mut want_b, 3);
        for threads in [1usize, 2, 4] {
            let (_, mut c) = setup(64, cfg.clone());
            let rt = rt_of(&c).with_pool(Arc::new(crate::exec::ExecPool::new(threads)));
            c.set_runtime(&rt);
            c.set_par_score_min(16);
            fill(&mut c);
            assert!(c.heads[0].n_csr >= 16, "context long enough to shard");
            let mut got = vec![0.0; shape.q_dim()];
            c.attend(0, &q, &mut got);
            assert_eq!(got, want, "sharded attend diverged at T={threads}");
            let mut got_b = vec![0.0; 3 * shape.q_dim()];
            c.attend_batch(1, &qs, &mut got_b, 3);
            assert_eq!(got_b, want_b, "sharded attend_batch diverged at T={threads}");
        }
    }

    #[test]
    fn incremental_mem_bytes_equals_walked_row_bytes() {
        // The O(1) accounting must equal the full walk (the pre-PR
        // formula) exactly — after appends, prefill, batch appends, and
        // across a fork.
        let walk = |c: &LexicoCache| -> f64 {
            let m = c.shape.head_dim;
            let mut bytes = 0.0;
            for head in &c.heads {
                let mut rows = head.k_rows();
                rows.extend(head.v_rows());
                for row in &rows {
                    bytes += row.bytes() as f64;
                }
                bytes += (head.buf_len * 2 * m * 2) as f64;
            }
            for ad in c.adaptive_k.iter().chain(&c.adaptive_v).flatten() {
                bytes += ad.extra_bytes() as f64;
            }
            bytes
        };
        for cfg in [
            LexicoConfig { sparsity: 4, n_buffer: 3, ..Default::default() },
            LexicoConfig {
                sparsity: 3,
                n_buffer: 2,
                precision: CoefPrecision::Fp16,
                ..Default::default()
            },
            LexicoConfig { sparsity: 2, n_buffer: 2, adaptive: Some((8, 0.1)), ..Default::default() },
        ] {
            let (shape, mut c) = setup(32, cfg);
            let mut rng = Rng::new(43);
            let t = 7;
            let ks = rng.normal_vec(t * shape.kv_dim());
            let vs = rng.normal_vec(t * shape.kv_dim());
            for l in 0..shape.n_layers {
                c.ingest_prefill(l, &ks, &vs, t, &[], 0);
            }
            assert_eq!(c.mem_bytes(), walk(&c), "after prefill");
            for _ in 0..PAGE_TOKENS + 5 {
                let k = rng.normal_vec(shape.kv_dim());
                let v = rng.normal_vec(shape.kv_dim());
                for l in 0..shape.n_layers {
                    c.append(l, &k, &v);
                }
            }
            assert_eq!(c.mem_bytes(), walk(&c), "after appends");
            let kb = rng.normal_vec(4 * shape.kv_dim());
            let vb = rng.normal_vec(4 * shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append_batch(l, &kb, &vb, 4);
            }
            assert_eq!(c.mem_bytes(), walk(&c), "after append_batch");
            let f = c.fork();
            assert_eq!(f.mem_bytes(), c.mem_bytes(), "fork accounting");
        }
    }

    /// The tentpole parity property at the cache layer: driving the
    /// engine's round protocol by hand — `par_matmul_bt` over the shared
    /// base key dictionary, `begin_shared_attend`, the engine's
    /// ascending-atom shared value pass, `finish_shared_attend` — must be
    /// bitwise identical to per-session `attend`, per precision, with
    /// sealed pages + ragged tail + buffer, under adaptive extensions, and
    /// at every pool size (sharded score sweep exercised via a lowered
    /// threshold).
    #[test]
    fn shared_qd_attend_matches_per_session_attend_bitwise() {
        use crate::tensor::par_matmul_bt;
        let cfgs = [
            LexicoConfig { sparsity: 4, n_buffer: 3, ..Default::default() },
            LexicoConfig {
                sparsity: 4,
                n_buffer: 3,
                precision: CoefPrecision::Fp16,
                ..Default::default()
            },
            LexicoConfig {
                sparsity: 2,
                n_buffer: 2,
                adaptive: Some((8, 0.05)),
                ..Default::default()
            },
        ];
        for cfg in cfgs {
            let adaptive = cfg.adaptive.is_some();
            // tiny dictionary under adaptive mode → extension growth certain
            let n_atoms = if adaptive { 16 } else { 64 };
            for threads in [1usize, 2, 4] {
                let (shape, mut c) = setup(n_atoms, cfg.clone());
                let pool = Arc::new(crate::exec::ExecPool::new(threads));
                let rt = rt_of(&c).with_pool(pool.clone());
                c.set_runtime(&rt);
                c.set_par_score_min(16);
                let mut rng = Rng::new(77);
                let n_tok = PAGE_TOKENS + 9; // ≥1 sealed page + ragged tail
                for _ in 0..n_tok {
                    let k = rng.normal_vec(shape.kv_dim());
                    let v = rng.normal_vec(shape.kv_dim());
                    for l in 0..shape.n_layers {
                        c.append(l, &k, &v);
                    }
                }
                assert!(!c.heads[0].pages.is_empty());
                if adaptive {
                    let extra: usize =
                        c.adaptive_k.iter().flatten().map(|a| a.n_extra).sum();
                    assert!(extra > 0, "adaptive dict never grew — extensions unexercised");
                }
                let dicts = c.shared_dicts().expect("lexico reports shared dicts");
                assert!(Arc::ptr_eq(&dicts, &c.dicts));
                let q = rng.normal_vec(shape.q_dim());
                let m = shape.head_dim;
                for layer in 0..shape.n_layers {
                    let mut want = vec![0.0; shape.q_dim()];
                    c.attend(layer, &q, &mut want);

                    let (dk, dv) = (&dicts.keys[layer], &dicts.values[layer]);
                    let mut qd_base = vec![0.0; shape.n_heads * dk.n];
                    par_matmul_bt(&pool, &mut qd_base, &q, &dk.atoms, shape.n_heads, m, dk.n);
                    let mut z_base = vec![0.0; shape.n_heads * dv.n];
                    c.begin_shared_attend(layer, &q, &qd_base, &mut z_base);
                    // the engine's shared value pass: base atoms ascending,
                    // zero bins skipped (matches attend's axpy loop)
                    let mut got = vec![0.0; shape.q_dim()];
                    for n in 0..dv.n {
                        let atom = &dv.atoms[n * m..(n + 1) * m];
                        for h in 0..shape.n_heads {
                            let zn = z_base[h * dv.n + n];
                            if zn != 0.0 {
                                axpy(&mut got[h * m..(h + 1) * m], zn, atom);
                            }
                        }
                    }
                    c.finish_shared_attend(layer, &mut got);
                    assert_eq!(
                        got, want,
                        "shared-qd attend diverged (adaptive={adaptive}, T={threads}, layer={layer})"
                    );
                }
            }
        }
    }

    #[test]
    fn attend_batch_scratch_shrinks_after_oversized_rounds() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(91);
        for _ in 0..PAGE_TOKENS + 5 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let qdim = shape.q_dim();
        let q = rng.normal_vec(qdim);
        let mut want = vec![0.0; qdim];
        c.attend(0, &q, &mut want);

        // one oversized B=16 round...
        let b = 16;
        let qs = rng.normal_vec(b * qdim);
        let mut out = vec![0.0; b * qdim];
        c.attend_batch(0, &qs, &mut out, b);

        // ...must not pin the high-water scratch for the session's life
        let k_n = c.dicts.keys[0].n;
        let v_n = c.dicts.values[0].n;
        assert!(
            c.qd.capacity() < b * shape.n_heads * k_n,
            "qd scratch kept the B={b} high-water mark ({} elems)",
            c.qd.capacity()
        );
        assert!(
            c.z.capacity() < b * shape.n_heads * v_n,
            "z scratch kept the B={b} high-water mark ({} elems)",
            c.z.capacity()
        );
        let one_query_scores: usize = (0..shape.n_heads)
            .map(|h| {
                let head = &c.heads[c.head_idx(0, h / shape.group())];
                head.n_csr + head.buf_len
            })
            .sum();
        assert!(
            c.scores.capacity() < b * one_query_scores,
            "score scratch kept the B={b} high-water mark ({} elems)",
            c.scores.capacity()
        );

        // and subsequent single-query attends still match exactly
        let mut got = vec![0.0; qdim];
        c.attend(0, &q, &mut got);
        assert_eq!(got, want, "attend diverged after scratch shrink");
    }

    fn tmp_store(tag: &str) -> (std::path::PathBuf, Arc<SpillStore>) {
        let dir = std::env::temp_dir()
            .join(format!("lexico_cache_spill_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        (dir.clone(), Arc::new(SpillStore::open(&dir).unwrap()))
    }

    #[test]
    fn spill_fault_round_trip_is_bitwise() {
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16, CoefPrecision::Sign] {
            let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, precision: prec, ..Default::default() };
            let (shape, mut c) = setup(64, cfg);
            let mut rng = Rng::new(111);
            for _ in 0..2 * PAGE_TOKENS + 7 {
                let k = rng.normal_vec(shape.kv_dim());
                let v = rng.normal_vec(shape.kv_dim());
                for l in 0..shape.n_layers {
                    c.append(l, &k, &v);
                }
            }
            let q = rng.normal_vec(shape.q_dim());
            let mut want = vec![0.0; shape.q_dim()];
            c.attend(0, &q, &mut want);
            let mem_before = c.mem_bytes();

            let (dir, store) = tmp_store(&format!("rt{}", prec.bytes_per_coef()));
            let rt = rt_of(&c).with_spill(store.clone());
            c.set_runtime(&rt);
            let (n_pages, freed) = c.spill_cold().unwrap();
            assert!(n_pages > 0 && freed > 0.0);
            assert_eq!(c.mem_bytes(), mem_before - freed, "resident-only accounting");
            assert_eq!(c.spilled_bytes, freed);

            // attend faults lazily and must reproduce the stream bit for bit
            let mut got = vec![0.0; shape.q_dim()];
            c.attend(0, &q, &mut got);
            assert_eq!(got, want, "spill→fault changed attend output ({prec:?})");
            assert_eq!(c.spilled_bytes, 0.0);
            assert_eq!(c.mem_bytes(), mem_before, "accounting must restore exactly");

            // a second evict round needs no I/O (pages already mirrored) and
            // still faults back bitwise
            let disk_before = store.counters().1;
            let (n2, freed2) = c.spill_cold().unwrap();
            assert_eq!(n2, n_pages);
            assert_eq!(freed2, freed);
            assert_eq!(store.counters().1, disk_before, "re-evict must not rewrite pages");
            c.fault_resident().unwrap();
            c.attend(0, &q, &mut got);
            assert_eq!(got, want);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn eviction_skips_pages_shared_with_forks() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 2, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(113);
        for _ in 0..PAGE_TOKENS + 4 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let (dir, store) = tmp_store("forkskip");
        let rt = rt_of(&c).with_spill(store);
        c.set_runtime(&rt);
        let f = c.fork();
        let (n_pages, freed) = c.spill_cold().unwrap();
        assert_eq!((n_pages, freed), (0, 0.0), "shared pages must stay resident");
        drop(f);
        let (n_pages, freed) = c.spill_cold().unwrap();
        assert!(n_pages > 0 && freed > 0.0, "sole-owned pages spill after the fork drops");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hibernate_restore_reproduces_the_session_bitwise() {
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16, CoefPrecision::Sign] {
            let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, precision: prec, ..Default::default() };
            let (shape, mut c) = setup(64, cfg.clone());
            let mut rng = Rng::new(117);
            for _ in 0..PAGE_TOKENS + 9 {
                let k = rng.normal_vec(shape.kv_dim());
                let v = rng.normal_vec(shape.kv_dim());
                for l in 0..shape.n_layers {
                    c.append(l, &k, &v);
                }
            }
            let (dir, store) = tmp_store(&format!("hib{}", prec.bytes_per_coef()));
            let rt = rt_of(&c).with_spill(store.clone());
            c.set_runtime(&rt);
            let blob = c.hibernate_state().unwrap();

            let (_, mut back) = setup(64, cfg);
            let rt = rt_of(&back).with_spill(store);
            back.set_runtime(&rt);
            back.restore_hibernated(&blob).unwrap();
            assert_eq!(back.tokens(), c.tokens());
            assert!(back.spilled_bytes > 0.0, "pages restore as spilled refs");
            back.fault_resident().unwrap();
            assert_eq!(back.mem_bytes(), c.mem_bytes());

            // identical continuations, bitwise
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            let q = rng.normal_vec(shape.q_dim());
            let (mut o1, mut o2) = (vec![0.0; shape.q_dim()], vec![0.0; shape.q_dim()]);
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
                back.append(l, &k, &v);
            }
            for l in 0..shape.n_layers {
                c.attend(l, &q, &mut o1);
                back.attend(l, &q, &mut o2);
                assert_eq!(o1, o2, "restored session diverged ({prec:?}, layer {l})");
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn restore_rejects_corrupt_snapshots_cleanly() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, ..Default::default() };
        let (shape, mut c) = setup(64, cfg.clone());
        let mut rng = Rng::new(119);
        // 2 pages' worth so the snapshot carries real page refs
        for _ in 0..2 * PAGE_TOKENS + 8 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let (dir, store) = tmp_store("corrupt");
        let rt = rt_of(&c).with_spill(store.clone());
        c.set_runtime(&rt);
        let blob = c.hibernate_state().unwrap();
        let fresh = || {
            let (_, mut b) = setup(64, cfg.clone());
            let rt = rt_of(&b).with_spill(store.clone());
            b.set_runtime(&rt);
            b
        };
        // truncated mid-snapshot
        assert!(fresh().restore_hibernated(&blob[..blob.len() / 2]).is_err());
        // truncated by one byte: the final buffer's length prefix overruns
        assert!(fresh().restore_hibernated(&blob[..blob.len() - 1]).is_err());
        // bad magic
        let mut bad = blob.clone();
        bad[0] ^= 0xff;
        assert!(fresh().restore_hibernated(&bad).is_err());
        // mismatched coefficient mode config (one per wrong mode)
        for wrong_prec in [CoefPrecision::Fp16, CoefPrecision::Sign] {
            let (_, mut wrong) = setup(
                64,
                LexicoConfig {
                    sparsity: 4,
                    n_buffer: 4,
                    precision: wrong_prec,
                    ..Default::default()
                },
            );
            let rt = rt_of(&wrong).with_spill(store.clone());
            wrong.set_runtime(&rt);
            assert!(wrong.restore_hibernated(&blob).is_err());
        }
        // a page ref pointing past the page file fails at fault time
        let mut back = fresh();
        back.restore_hibernated(&blob).unwrap();
        for h in &mut back.heads {
            for slot in &mut h.pages {
                if let PageSlot::Spilled { at, .. } = slot {
                    at.offset += 1u64 << 20;
                }
            }
        }
        assert!(back.fault_resident().is_err(), "dangling page ref must error, not panic");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_tier_recompression_is_lossy_but_bounded() {
        use crate::store::ColdTier;
        let cfg = LexicoConfig {
            sparsity: 6,
            n_buffer: 2,
            precision: CoefPrecision::Fp16,
            ..Default::default()
        };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(121);
        for _ in 0..2 * PAGE_TOKENS {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let q = rng.normal_vec(shape.q_dim());
        let mut want = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut want);
        let mem_before = c.mem_bytes();

        let dir = std::env::temp_dir()
            .join(format!("lexico_cache_spill_cold_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(
            SpillStore::open(&dir)
                .unwrap()
                .with_cold_tier(ColdTier { keep_atoms: Some(3), to_fp8: true }),
        );
        let rt = rt_of(&c).with_spill(store);
        c.set_runtime(&rt);
        c.spill_cold().unwrap();
        c.fault_resident().unwrap();
        assert!(c.mem_bytes() < mem_before, "cold tier must shrink the faulted pages");

        // tolerance golden: the recompressed stream differs (lossy by
        // design) but stays a bounded approximation of the exact one
        let mut got = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut got);
        assert_ne!(got, want, "cold tier is expected to change bits");
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in got.iter().zip(&want) {
            assert!(a.is_finite());
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        assert!(
            num.sqrt() <= 0.75 * den.sqrt(),
            "cold-tier attend error too large: {} vs {}",
            num.sqrt(),
            den.sqrt()
        );
    }

    #[test]
    fn adaptive_mode_grows_and_charges_memory() {
        let cfg = LexicoConfig {
            sparsity: 2,
            n_buffer: 1,
            adaptive: Some((8, 0.05)),
            ..Default::default()
        };
        let (shape, mut c) = setup(16, cfg); // tiny dict → adaptation certain
        let mut rng = Rng::new(11);
        for _ in 0..6 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let extra: usize = c.adaptive_k.iter().flatten().map(|a| a.n_extra).sum();
        assert!(extra > 0, "no adaptive growth");
        let base_mem: f64 = c
            .heads
            .iter()
            .flat_map(|h| {
                let mut rows = h.k_rows();
                rows.extend(h.v_rows());
                rows
            })
            .map(|r| r.bytes() as f64)
            .sum::<f64>();
        assert!(c.mem_bytes() > base_mem, "adaptive atoms not charged");
    }
}
