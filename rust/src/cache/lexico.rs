//! The Lexico KV-cache backend (paper §3.4, Algorithm 2, Eq. 7).
//!
//! Per layer and kv head the cache holds
//!   * `K_csr`/`V_csr` — OMP sparse codes (u16 indices + FP8/FP16 coefs);
//!   * a full-precision recency buffer of up to `n_b` tokens.
//! When the buffer exceeds `n_b`, the oldest `n_a` tokens are OMP-compressed
//! (the paper runs this in parallel with the forward pass; here it is the
//! same computation on the same thread, measured separately by the latency
//! bench).
//!
//! Decode attention follows the paper's split computation: the query is
//! first multiplied by the dictionary (`q·D_k`, O(N·m)), then contracted
//! against the sparse codes (O(T·s)); buffer tokens take the dense path;
//! one softmax spans both. The value side accumulates coefficients into a
//! dictionary-bin vector `z` and finishes with atoms·z — the same
//! O(N·m + T·s) complexity the paper reports.

use super::{CacheShape, KvCache};
use crate::dict::adaptive::AdaptiveDict;
use crate::dict::DictionarySet;
use crate::omp::{omp_encode, omp_encode_batch, BatchOmpWorkspace, OmpWorkspace};
use crate::sparse::{CoefPrecision, CsrRow};
use crate::tensor::{axpy, dot, softmax};
use std::sync::Arc;

/// Lexico knobs (paper defaults in comments).
#[derive(Clone, Debug)]
pub struct LexicoConfig {
    /// sparsity per vector (s); with `delta > 0` this is the max sparsity
    pub sparsity: usize,
    /// relative-error early-termination threshold δ (0 ⇒ fixed sparsity)
    pub delta: f32,
    /// full-precision recency buffer length n_b (paper: 128)
    pub n_buffer: usize,
    /// approximation window n_a — tokens compressed per overflow (paper: 1)
    pub n_approx: usize,
    /// CSR coefficient precision (paper main: FP8; ablations: FP16)
    pub precision: CoefPrecision,
    /// adaptive dictionary learning (§4.2.4): (max added atoms, δ_adapt)
    pub adaptive: Option<(usize, f32)>,
}

impl Default for LexicoConfig {
    fn default() -> Self {
        LexicoConfig {
            sparsity: 8,
            delta: 0.0,
            n_buffer: 32,
            n_approx: 1,
            precision: CoefPrecision::Fp8,
            adaptive: None,
        }
    }
}

/// Tokens per frozen CSR page. Compressed rows are immutable once written,
/// so they are grouped into fixed-size pages behind an `Arc`: `fork()`
/// clones the `Arc`s (copy-on-write at page granularity — forks share the
/// compressed prefix physically) and only the unsealed tail plus the
/// full-precision recency buffer are deep-copied per fork.
const PAGE_TOKENS: usize = 32;

/// One frozen page of compressed tokens: parallel K and V rows.
#[derive(Clone, Default)]
struct CsrPage {
    k: Vec<CsrRow>,
    v: Vec<CsrRow>,
}

impl CsrPage {
    fn bytes(&self) -> f64 {
        self.k.iter().chain(&self.v).map(|r| r.bytes() as f64).sum()
    }
}

/// Per-(layer, kv-head) state.
struct HeadState {
    /// sealed compressed pages, oldest first — shared across forks
    pages: Vec<Arc<CsrPage>>,
    /// unsealed compressed rows (< PAGE_TOKENS of them) — fork-private
    tail_k: Vec<CsrRow>,
    tail_v: Vec<CsrRow>,
    /// total compressed tokens (pages + tail)
    n_csr: usize,
    /// token-major buffer rows, oldest first: [t][m]
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

impl HeadState {
    /// Append one compressed token (K and V rows always arrive in pairs),
    /// sealing a page whenever the tail fills.
    fn push_csr(&mut self, k: CsrRow, v: CsrRow) {
        self.tail_k.push(k);
        self.tail_v.push(v);
        self.n_csr += 1;
        if self.tail_k.len() >= PAGE_TOKENS {
            self.pages.push(Arc::new(CsrPage {
                k: std::mem::take(&mut self.tail_k),
                v: std::mem::take(&mut self.tail_v),
            }));
        }
    }

    /// Compressed K rows in token order (pages, then the unsealed tail).
    fn k_rows(&self) -> impl Iterator<Item = &CsrRow> {
        self.pages.iter().flat_map(|p| p.k.iter()).chain(self.tail_k.iter())
    }

    /// Compressed V rows in token order.
    fn v_rows(&self) -> impl Iterator<Item = &CsrRow> {
        self.pages.iter().flat_map(|p| p.v.iter()).chain(self.tail_v.iter())
    }

    /// Fork-private copy: pages shared by `Arc`, tail and buffer cloned.
    fn fork(&self) -> HeadState {
        HeadState {
            pages: self.pages.clone(),
            tail_k: self.tail_k.clone(),
            tail_v: self.tail_v.clone(),
            n_csr: self.n_csr,
            k_buf: self.k_buf.clone(),
            v_buf: self.v_buf.clone(),
            buf_len: self.buf_len,
        }
    }
}

pub struct LexicoCache {
    shape: CacheShape,
    cfg: LexicoConfig,
    dicts: Arc<DictionarySet>,
    /// adaptive overlays (lazily created when cfg.adaptive is set)
    adaptive_k: Vec<Option<AdaptiveDict>>,
    adaptive_v: Vec<Option<AdaptiveDict>>,
    /// heads[layer * n_kv_heads + g]
    heads: Vec<HeadState>,
    tokens: usize,
    ws: OmpWorkspace,
    /// batched-OMP workspace (overflow compression of all heads at once)
    bws: BatchOmpWorkspace,
    // overflow-gather scratch: [total][m] K and V rows pending compression
    gather_k: Vec<f32>,
    gather_v: Vec<f32>,
    // attend scratch
    scores: Vec<f32>,
    qd: Vec<f32>,
    z: Vec<f32>,
    /// attend_batch: per-(query, head) offsets into the flat score buffer
    score_off: Vec<usize>,
}

impl LexicoCache {
    pub fn new(shape: CacheShape, dicts: Arc<DictionarySet>, cfg: LexicoConfig) -> Self {
        assert_eq!(dicts.keys.len(), shape.n_layers, "dict layers mismatch");
        let n = dicts.keys[0].n;
        let m = shape.head_dim;
        assert_eq!(dicts.keys[0].m, m, "dict head_dim mismatch");
        let heads = (0..shape.n_layers * shape.n_kv_heads)
            .map(|_| HeadState {
                pages: Vec::new(),
                tail_k: Vec::new(),
                tail_v: Vec::new(),
                n_csr: 0,
                k_buf: Vec::new(),
                v_buf: Vec::new(),
                buf_len: 0,
            })
            .collect();
        let (adaptive_k, adaptive_v) = if let Some((max_extra, d)) = cfg.adaptive {
            (
                dicts.keys.iter().map(|b| Some(AdaptiveDict::new(b, max_extra, d))).collect(),
                dicts.values.iter().map(|b| Some(AdaptiveDict::new(b, max_extra, d))).collect(),
            )
        } else {
            (
                (0..shape.n_layers).map(|_| None).collect(),
                (0..shape.n_layers).map(|_| None).collect(),
            )
        };
        let n_cap = n + cfg.adaptive.map(|(e, _)| e).unwrap_or(0);
        LexicoCache {
            shape,
            ws: OmpWorkspace::new(n_cap, m, cfg.sparsity.max(1)),
            bws: BatchOmpWorkspace::new(),
            cfg,
            dicts,
            adaptive_k,
            adaptive_v,
            heads,
            tokens: 0,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
            scores: Vec::new(),
            qd: vec![0.0; n_cap],
            z: vec![0.0; n_cap],
            score_off: Vec::new(),
        }
    }

    #[inline]
    fn head_idx(&self, layer: usize, g: usize) -> usize {
        layer * self.shape.n_kv_heads + g
    }

    /// Compress one vector with the layer's K or V dictionary.
    fn encode(&mut self, layer: usize, is_key: bool, x: &[f32]) -> CsrRow {
        let prec = self.cfg.precision;
        let (s, delta) = (self.cfg.sparsity, self.cfg.delta);
        let adapt = if is_key {
            &mut self.adaptive_k[layer]
        } else {
            &mut self.adaptive_v[layer]
        };
        let code = if let Some(ad) = adapt.as_mut() {
            ad.encode(x, s, &mut self.ws).0
        } else {
            let d = if is_key {
                &self.dicts.keys[layer]
            } else {
                &self.dicts.values[layer]
            };
            omp_encode(&d.atoms, d.n, d.m, x, s, delta, &mut self.ws)
        };
        CsrRow::from_f32(&code.idx, &code.val, prec)
    }

    /// Compress the oldest `n` buffer tokens of every kv head in `layer`.
    ///
    /// Non-adaptive dictionaries take the batch-first path: the pending
    /// K rows of *all* kv heads are gathered into one `[total, m]` matrix
    /// and sparse-coded by [`omp_encode_batch`] (one GEMM correlation step
    /// per pursuit iteration, one dictionary stream for the whole layer),
    /// then the same for V. Per-vector results are bit-identical to the
    /// sequential encoder, so cache contents don't depend on the path.
    fn compress_oldest(&mut self, layer: usize, n: usize) {
        let m = self.shape.head_dim;
        if self.cfg.adaptive.is_some() {
            // Adaptive growth mutates the dictionary per encoded vector, so
            // results are order-dependent: keep the sequential path.
            for g in 0..self.shape.n_kv_heads {
                let hi = self.head_idx(layer, g);
                for _ in 0..n {
                    if self.heads[hi].buf_len == 0 {
                        break;
                    }
                    let k: Vec<f32> = self.heads[hi].k_buf[..m].to_vec();
                    let v: Vec<f32> = self.heads[hi].v_buf[..m].to_vec();
                    let k_row = self.encode(layer, true, &k);
                    let v_row = self.encode(layer, false, &v);
                    let h = &mut self.heads[hi];
                    h.push_csr(k_row, v_row);
                    h.k_buf.drain(..m);
                    h.v_buf.drain(..m);
                    h.buf_len -= 1;
                }
            }
            return;
        }
        // gather the oldest rows of every head into one batch
        self.gather_k.clear();
        self.gather_v.clear();
        let n_kv = self.shape.n_kv_heads;
        let mut takes = vec![0usize; n_kv];
        for (g, take) in takes.iter_mut().enumerate() {
            let hi = self.head_idx(layer, g);
            *take = n.min(self.heads[hi].buf_len);
            self.gather_k.extend_from_slice(&self.heads[hi].k_buf[..*take * m]);
            self.gather_v.extend_from_slice(&self.heads[hi].v_buf[..*take * m]);
        }
        let total: usize = takes.iter().sum();
        if total == 0 {
            return;
        }
        let dicts = self.dicts.clone();
        let (dk, dv) = (&dicts.keys[layer], &dicts.values[layer]);
        let (s, delta, prec) = (self.cfg.sparsity, self.cfg.delta, self.cfg.precision);
        let k_codes =
            omp_encode_batch(&dk.atoms, dk.n, dk.m, &self.gather_k, total, s, delta, &mut self.bws);
        let v_codes =
            omp_encode_batch(&dv.atoms, dv.n, dv.m, &self.gather_v, total, s, delta, &mut self.bws);
        let mut off = 0;
        for (g, &take) in takes.iter().enumerate() {
            let hi = self.head_idx(layer, g);
            let h = &mut self.heads[hi];
            for code_i in off..off + take {
                let (kc, vc) = (&k_codes[code_i], &v_codes[code_i]);
                h.push_csr(
                    CsrRow::from_f32(&kc.idx, &kc.val, prec),
                    CsrRow::from_f32(&vc.idx, &vc.val, prec),
                );
            }
            h.k_buf.drain(..take * m);
            h.v_buf.drain(..take * m);
            h.buf_len -= take;
            off += take;
        }
    }

    /// Current atom views per layer (base or adaptive overlay).
    fn atoms(&self, layer: usize, is_key: bool) -> (&[f32], usize) {
        let (ad, base) = if is_key {
            (&self.adaptive_k[layer], &self.dicts.keys[layer])
        } else {
            (&self.adaptive_v[layer], &self.dicts.values[layer])
        };
        match ad {
            Some(a) => (a.atoms(), a.n_atoms()),
            None => (&base.atoms, base.n),
        }
    }
}

impl KvCache for LexicoCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        let m = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        // load everything into the buffer, then compress all but the last n_b
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            for ti in 0..t {
                self.heads[hi]
                    .k_buf
                    .extend_from_slice(&ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
                self.heads[hi]
                    .v_buf
                    .extend_from_slice(&vs[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
            }
            self.heads[hi].buf_len += t;
        }
        let overflow = self.heads[self.head_idx(layer, 0)]
            .buf_len
            .saturating_sub(self.cfg.n_buffer);
        if overflow > 0 {
            self.compress_oldest(layer, overflow);
        }
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let m = self.shape.head_dim;
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            self.heads[hi].k_buf.extend_from_slice(&k[g * m..(g + 1) * m]);
            self.heads[hi].v_buf.extend_from_slice(&v[g * m..(g + 1) * m]);
            self.heads[hi].buf_len += 1;
        }
        if self.heads[self.head_idx(layer, 0)].buf_len > self.cfg.n_buffer {
            self.compress_oldest(layer, self.cfg.n_approx);
        }
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn append_batch(&mut self, layer: usize, ks: &[f32], vs: &[f32], b: usize) {
        if b == 0 {
            return;
        }
        let m = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            for ti in 0..b {
                self.heads[hi]
                    .k_buf
                    .extend_from_slice(&ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
                self.heads[hi]
                    .v_buf
                    .extend_from_slice(&vs[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
            }
            self.heads[hi].buf_len += b;
        }
        // Replay the sequential trigger schedule exactly: each append whose
        // post-append buffer tops n_buffer compresses min(n_a, buf_len)
        // tokens (compress_oldest is bounded by the buffer). The compressed
        // tokens are always the oldest, so the non-adaptive path can run
        // the whole schedule as ONE compress_oldest call — the entire
        // overflow goes through the GEMM-batched OMP at once.
        let len = self.heads[self.head_idx(layer, 0)].buf_len;
        let (nb, na) = (self.cfg.n_buffer, self.cfg.n_approx);
        if na > 0 {
            let adaptive = self.cfg.adaptive.is_some();
            let mut cur = len - b; // pre-append buffer length
            let mut total = 0usize;
            for _ in 0..b {
                cur += 1;
                if cur > nb {
                    let c = na.min(cur);
                    cur -= c;
                    if adaptive {
                        // Adaptive growth is order-dependent and the
                        // dictionary is shared across kv heads, so the
                        // per-trigger head interleave of the sequential
                        // path must be reproduced call-for-call.
                        self.compress_oldest(layer, c);
                    } else {
                        total += c;
                    }
                }
            }
            if total > 0 {
                self.compress_oldest(layer, total);
            }
        }
        if layer == 0 {
            self.tokens += b;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        let (k_atoms_ptr, k_n) = {
            let (a, n) = self.atoms(layer, true);
            (a.as_ptr(), n)
        };
        let (v_atoms_ptr, v_n) = {
            let (a, n) = self.atoms(layer, false);
            (a.as_ptr(), n)
        };
        // SAFETY: atoms live in self and are not mutated during attend.
        let k_atoms = unsafe { std::slice::from_raw_parts(k_atoms_ptr, k_n * m) };
        let v_atoms = unsafe { std::slice::from_raw_parts(v_atoms_ptr, v_n * m) };

        // qd[h][n] = q_h · D_k[n] for ALL heads in one streaming pass over
        // the dictionary (perf pass #1, EXPERIMENTS.md §Perf: one load of
        // each atom now serves every query head instead of H separate
        // passes over the N·m array). Set LEXICO_QD_PER_HEAD=1 to use the
        // pre-optimization per-head layout (kept for the §Perf comparison).
        if self.qd.len() < n_heads * k_n {
            self.qd.resize(n_heads * k_n, 0.0);
        }
        {
            let qd = &mut self.qd[..n_heads * k_n];
            if std::env::var_os("LEXICO_QD_PER_HEAD").is_some() {
                for h in 0..n_heads {
                    let qh = &q[h * m..(h + 1) * m];
                    for n in 0..k_n {
                        qd[h * k_n + n] = dot(qh, &k_atoms[n * m..(n + 1) * m]);
                    }
                }
            } else {
                for n in 0..k_n {
                    let atom = &k_atoms[n * m..(n + 1) * m];
                    for h in 0..n_heads {
                        qd[h * k_n + n] = dot(&q[h * m..(h + 1) * m], atom);
                    }
                }
            }
        }

        for h in 0..n_heads {
            let g = h / self.shape.group();
            let hi = self.head_idx(layer, g);
            let head = &self.heads[hi];
            let tc = head.n_csr;
            let tb = head.buf_len;
            let qh = &q[h * m..(h + 1) * m];
            let qd = &self.qd[h * k_n..(h + 1) * k_n];
            // compressed scores: O(T·s)
            self.scores.resize(tc + tb, 0.0);
            for (ti, row) in head.k_rows().enumerate() {
                let mut sc = 0.0;
                for j in 0..row.nnz() {
                    sc += qd[row.idx[j] as usize] * row.coef(j);
                }
                self.scores[ti] = sc * scale;
            }
            // buffer scores: dense
            for ti in 0..tb {
                self.scores[tc + ti] =
                    dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
            }
            softmax(&mut self.scores[..tc + tb]);

            // value side: z-bin accumulation, then atoms·z  (O(T·s + N·m))
            let oh = &mut out[h * m..(h + 1) * m];
            let z = &mut self.z[..v_n];
            z.fill(0.0);
            for (ti, row) in head.v_rows().enumerate() {
                let w = self.scores[ti];
                for j in 0..row.nnz() {
                    z[row.idx[j] as usize] += w * row.coef(j);
                }
            }
            for (n, &zn) in z.iter().enumerate() {
                if zn != 0.0 {
                    axpy(oh, zn, &v_atoms[n * m..(n + 1) * m]);
                }
            }
            for ti in 0..tb {
                axpy(oh, self.scores[tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
            }
        }
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32], b: usize) {
        if b == 0 {
            return;
        }
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let qdim = self.shape.q_dim();
        let group = self.shape.group();
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        let (k_atoms_ptr, k_n) = {
            let (a, n) = self.atoms(layer, true);
            (a.as_ptr(), n)
        };
        let (v_atoms_ptr, v_n) = {
            let (a, n) = self.atoms(layer, false);
            (a.as_ptr(), n)
        };
        // SAFETY: atoms live in self and are not mutated during attend_batch.
        let k_atoms = unsafe { std::slice::from_raw_parts(k_atoms_ptr, k_n * m) };
        let v_atoms = unsafe { std::slice::from_raw_parts(v_atoms_ptr, v_n * m) };
        let rows = b * n_heads;

        // (1) qd[row][n] = q_row · D_k[n]: ONE streaming pass over the key
        // dictionary serves every query's every head (extends perf pass #1
        // across the whole query batch).
        if self.qd.len() < rows * k_n {
            self.qd.resize(rows * k_n, 0.0);
        }
        {
            let qd = &mut self.qd[..rows * k_n];
            for n in 0..k_n {
                let atom = &k_atoms[n * m..(n + 1) * m];
                for qi in 0..b {
                    for h in 0..n_heads {
                        qd[(qi * n_heads + h) * k_n + n] =
                            dot(&qs[qi * qdim + h * m..qi * qdim + (h + 1) * m], atom);
                    }
                }
            }
        }

        // (2) per-row scores + softmax + value-bin accumulation (the flat
        // score buffer is kept for phase 4; offsets per row).
        self.score_off.clear();
        self.score_off.push(0);
        for _qi in 0..b {
            for h in 0..n_heads {
                let hi = self.head_idx(layer, h / group);
                let len = self.heads[hi].n_csr + self.heads[hi].buf_len;
                let prev = *self.score_off.last().unwrap();
                self.score_off.push(prev + len);
            }
        }
        let total_scores = *self.score_off.last().unwrap();
        if self.scores.len() < total_scores {
            self.scores.resize(total_scores, 0.0);
        }
        if self.z.len() < rows * v_n {
            self.z.resize(rows * v_n, 0.0);
        }
        self.z[..rows * v_n].fill(0.0);
        for qi in 0..b {
            for h in 0..n_heads {
                let row = qi * n_heads + h;
                let hi = self.head_idx(layer, h / group);
                let head = &self.heads[hi];
                let tc = head.n_csr;
                let tb = head.buf_len;
                let off = self.score_off[row];
                let qh = &qs[qi * qdim + h * m..qi * qdim + (h + 1) * m];
                let qdrow = &self.qd[row * k_n..(row + 1) * k_n];
                for (ti, csr) in head.k_rows().enumerate() {
                    let mut sc = 0.0;
                    for j in 0..csr.nnz() {
                        sc += qdrow[csr.idx[j] as usize] * csr.coef(j);
                    }
                    self.scores[off + ti] = sc * scale;
                }
                for ti in 0..tb {
                    self.scores[off + tc + ti] =
                        dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
                }
                softmax(&mut self.scores[off..off + tc + tb]);
                let z = &mut self.z[row * v_n..(row + 1) * v_n];
                for (ti, csr) in head.v_rows().enumerate() {
                    let w = self.scores[off + ti];
                    for j in 0..csr.nnz() {
                        z[csr.idx[j] as usize] += w * csr.coef(j);
                    }
                }
            }
        }

        // (3) ONE streaming pass over the value dictionary finishes the
        // compressed-token term of every (query, head) output. Per output
        // element contributions still arrive in ascending-atom order, so
        // this is bitwise identical to the per-head atoms·z pass.
        for n in 0..v_n {
            let atom = &v_atoms[n * m..(n + 1) * m];
            for row in 0..rows {
                let zn = self.z[row * v_n + n];
                if zn != 0.0 {
                    let (qi, h) = (row / n_heads, row % n_heads);
                    axpy(&mut out[qi * qdim + h * m..qi * qdim + (h + 1) * m], zn, atom);
                }
            }
        }

        // (4) recency-buffer tokens, dense — after the dictionary term,
        // matching the sequential attend's per-head accumulation order.
        for qi in 0..b {
            for h in 0..n_heads {
                let row = qi * n_heads + h;
                let hi = self.head_idx(layer, h / group);
                let head = &self.heads[hi];
                let tc = head.n_csr;
                let off = self.score_off[row];
                let oh = &mut out[qi * qdim + h * m..qi * qdim + (h + 1) * m];
                for ti in 0..head.buf_len {
                    axpy(oh, self.scores[off + tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
                }
            }
        }
    }

    /// Copy-on-write fork: sealed CSR pages are shared (`Arc` clone), the
    /// unsealed tail, recency buffer, token counter and adaptive overlays
    /// are deep-copied, and scratch/workspaces start fresh (they carry no
    /// semantic state — OMP results are workspace-independent). Continuing
    /// either copy is bitwise identical to continuing the original.
    fn fork(&self) -> Box<dyn KvCache> {
        let n = self.dicts.keys[0].n;
        let m = self.shape.head_dim;
        let n_cap = n + self.cfg.adaptive.map(|(e, _)| e).unwrap_or(0);
        Box::new(LexicoCache {
            shape: self.shape,
            ws: OmpWorkspace::new(n_cap, m, self.cfg.sparsity.max(1)),
            bws: BatchOmpWorkspace::with_pool(self.bws.pool().clone()),
            cfg: self.cfg.clone(),
            dicts: self.dicts.clone(),
            adaptive_k: self.adaptive_k.clone(),
            adaptive_v: self.adaptive_v.clone(),
            heads: self.heads.iter().map(|h| h.fork()).collect(),
            tokens: self.tokens,
            gather_k: Vec::new(),
            gather_v: Vec::new(),
            scores: Vec::new(),
            qd: vec![0.0; n_cap],
            z: vec![0.0; n_cap],
            score_off: Vec::new(),
        })
    }

    /// Bytes living in pages whose `Arc` is held by more than one cache —
    /// the physically shared compressed prefix. Charged once by the page
    /// owner (prefix-cache prototype or primary fan-out candidate).
    fn shared_prefix_bytes(&self) -> f64 {
        self.heads
            .iter()
            .flat_map(|h| &h.pages)
            .filter(|p| Arc::strong_count(p) > 1)
            .map(|p| p.bytes())
            .sum()
    }

    /// Adaptive dictionaries grow per encoded vector, so the encode *order*
    /// matters and split prefill diverges; the plain universal-dictionary
    /// path compresses vector-by-vector independently.
    fn split_prefill_exact(&self) -> bool {
        self.cfg.adaptive.is_none()
    }

    /// Overflow compression (the GEMM-batched OMP encoder) runs on `pool`;
    /// codes are bitwise independent of the pool's thread count.
    fn set_pool(&mut self, pool: Arc<crate::exec::ExecPool>) {
        self.bws.set_pool(pool);
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    fn mem_bytes(&self) -> f64 {
        let m = self.shape.head_dim;
        let mut bytes = 0.0;
        for head in &self.heads {
            for row in head.k_rows().chain(head.v_rows()) {
                bytes += row.bytes() as f64;
            }
            bytes += (head.buf_len * 2 * m * 2) as f64; // buffer @ FP16
        }
        // adaptive atoms are session-private → charged to KV size (§4.2.4)
        for ad in self.adaptive_k.iter().chain(&self.adaptive_v).flatten() {
            bytes += ad.extra_bytes() as f64;
        }
        bytes
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        let mut s = format!("lexico_s{}", self.cfg.sparsity);
        if self.cfg.delta > 0.0 {
            s += &format!("_d{:.2}", self.cfg.delta);
        }
        if self.cfg.adaptive.is_some() {
            s += "_adaptive";
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(n_atoms: usize, cfg: LexicoConfig) -> (CacheShape, LexicoCache) {
        let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let dicts = DictionarySet {
            keys: (0..2).map(|i| crate::dict::Dictionary::random(16, n_atoms, i)).collect(),
            values: (0..2).map(|i| crate::dict::Dictionary::random(16, n_atoms, 100 + i)).collect(),
        };
        let c = LexicoCache::new(shape, Arc::new(dicts), cfg);
        (shape, c)
    }

    #[test]
    fn buffer_then_compression() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, n_approx: 1, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        // 10 tokens, buffer 4 → 6 compressed per head
        let h = &c.heads[0];
        assert_eq!(h.buf_len, 4);
        assert_eq!(h.n_csr, 6);
        assert!(c.kv_ratio() < 1.0);
        assert_eq!(c.tokens(), 10);
    }

    #[test]
    fn attend_matches_full_cache_when_reconstruction_is_exact() {
        // Identity dictionary (16 atoms = basis) with s=16 reconstructs
        // exactly → Lexico attention must equal full-cache attention.
        let shape = CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 16 };
        let mut atoms = vec![0.0; 16 * 16];
        for i in 0..16 {
            atoms[i * 16 + i] = 1.0;
        }
        let d = crate::dict::Dictionary::new(16, 16, atoms);
        let dicts = DictionarySet { keys: vec![d.clone()], values: vec![d] };
        let cfg = LexicoConfig {
            sparsity: 16,
            n_buffer: 2,
            precision: CoefPrecision::Fp16,
            ..Default::default()
        };
        let mut lex = LexicoCache::new(shape, Arc::new(dicts), cfg);
        let mut full = crate::cache::full::FullCache::new(shape);
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            // keep coordinates modest so fp16 rounding stays negligible
            let k: Vec<f32> = rng.normal_vec(16).iter().map(|x| x * 0.5).collect();
            let v: Vec<f32> = rng.normal_vec(16).iter().map(|x| x * 0.5).collect();
            lex.append(0, &k, &v);
            full.append(0, &k, &v);
        }
        let q = rng.normal_vec(shape.q_dim());
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        lex.attend(0, &q, &mut o1);
        full.attend(0, &q, &mut o2);
        crate::util::prop::assert_close(&o1, &o2, 2e-2, "lexico≈full").unwrap();
    }

    #[test]
    fn batch_entry_points_match_sequential_exactly() {
        // append_batch must leave bit-identical cache state (the batched
        // OMP is bit-equal to sequential OMP and the overflow schedule
        // lands in the same place); attend_batch must be bitwise equal to
        // per-query attends.
        let cfgs = [
            LexicoConfig { sparsity: 4, n_buffer: 5, n_approx: 1, ..Default::default() },
            LexicoConfig { sparsity: 4, n_buffer: 5, n_approx: 3, ..Default::default() },
            // n_a > n_buffer + 1: each sequential trigger compresses only
            // min(n_a, buf_len) — the replayed schedule must match that
            LexicoConfig { sparsity: 4, n_buffer: 2, n_approx: 5, ..Default::default() },
            // adaptive: shared per-layer dictionary mutates per encode, so
            // append_batch must reproduce the sequential head interleave
            LexicoConfig {
                sparsity: 2,
                n_buffer: 5,
                n_approx: 1,
                adaptive: Some((16, 0.2)),
                ..Default::default()
            },
        ];
        for cfg in cfgs {
            let na = cfg.n_approx;
            let (shape, mut seq) = setup(64, cfg.clone());
            let (_, mut bat) = setup(64, cfg);
            let mut rng = Rng::new(31);
            let kvd = shape.kv_dim();
            let n = 11;
            let ks = rng.normal_vec(n * kvd);
            let vs = rng.normal_vec(n * kvd);
            for l in 0..shape.n_layers {
                for i in 0..n {
                    seq.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
                }
                bat.append_batch(l, &ks, &vs, n);
            }
            assert_eq!(seq.tokens(), bat.tokens());
            for (hs, hb) in seq.heads.iter().zip(&bat.heads) {
                assert_eq!(hs.buf_len, hb.buf_len, "na={na}");
                assert_eq!(hs.n_csr, hb.n_csr, "na={na}");
                for (a, b) in hs.k_rows().zip(hb.k_rows()) {
                    assert_eq!(a.idx, b.idx, "na={na}");
                    assert_eq!(a.coef_bits, b.coef_bits, "na={na}");
                }
                assert_eq!(hs.k_buf, hb.k_buf, "na={na}");
                assert_eq!(hs.v_buf, hb.v_buf, "na={na}");
            }
            assert_eq!(seq.mem_bytes(), bat.mem_bytes(), "na={na}");
            // attention parity over a query batch
            let b = 3;
            let qd = shape.q_dim();
            let qs = rng.normal_vec(b * qd);
            let mut o_seq = vec![0.0; b * qd];
            let mut o_bat = vec![0.0; b * qd];
            for i in 0..b {
                seq.attend(0, &qs[i * qd..(i + 1) * qd], &mut o_seq[i * qd..(i + 1) * qd]);
            }
            bat.attend_batch(0, &qs, &mut o_bat, b);
            assert_eq!(o_seq, o_bat, "na={na}: attend_batch diverged");
        }
    }

    #[test]
    fn prefill_compresses_all_but_buffer() {
        let cfg = LexicoConfig { sparsity: 2, n_buffer: 3, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(5);
        let t = 9;
        let ks = rng.normal_vec(t * shape.kv_dim());
        let vs = rng.normal_vec(t * shape.kv_dim());
        for l in 0..shape.n_layers {
            c.ingest_prefill(l, &ks, &vs, t, &[], 0);
        }
        assert_eq!(c.heads[0].buf_len, 3);
        assert_eq!(c.heads[0].n_csr, 6);
        assert_eq!(c.tokens(), t);
    }

    #[test]
    fn memory_accounting_matches_formula() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 2, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(7);
        for _ in 0..6 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        // per head: 4 csr tokens ≤ (3·4+2)·2 rows... plus 2 buffer tokens
        // random vectors are dense: every row has exactly s=4 nnz
        let per_head = 4 * (3 * 4 + 2) * 2 + 2 * 2 * 16 * 2;
        let total = per_head * shape.n_layers * shape.n_kv_heads;
        assert_eq!(c.mem_bytes(), total as f64);
    }

    #[test]
    fn fork_shares_sealed_pages_and_stays_bitwise_identical() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 2, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(17);
        // enough appends to seal at least one PAGE_TOKENS page per head
        for _ in 0..PAGE_TOKENS + 8 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        assert!(!c.heads[0].pages.is_empty());
        assert_eq!(c.shared_prefix_bytes(), 0.0, "no forks yet → nothing shared");

        let mut f = c.fork();
        assert_eq!(f.tokens(), c.tokens());
        assert_eq!(f.mem_bytes(), c.mem_bytes());
        assert!(c.shared_prefix_bytes() > 0.0, "sealed pages now shared");
        assert_eq!(f.shared_prefix_bytes(), c.shared_prefix_bytes());
        assert!(
            f.shared_prefix_bytes() < f.mem_bytes(),
            "tail + buffer stay private"
        );

        // identical continuations must match bitwise
        let q = rng.normal_vec(shape.q_dim());
        let (mut o1, mut o2) = (vec![0.0; shape.q_dim()], vec![0.0; shape.q_dim()]);
        c.attend(0, &q, &mut o1);
        f.attend(0, &q, &mut o2);
        assert_eq!(o1, o2, "fork attend diverged");
        let k = rng.normal_vec(shape.kv_dim());
        let v = rng.normal_vec(shape.kv_dim());
        for l in 0..shape.n_layers {
            c.append(l, &k, &v);
            f.append(l, &k, &v);
        }
        c.attend(1, &q, &mut o1);
        f.attend(1, &q, &mut o2);
        assert_eq!(o1, o2, "fork diverged after post-fork appends");

        // divergent continuation of the fork must not disturb the original
        let before = o1.clone();
        let k2 = rng.normal_vec(shape.kv_dim());
        let v2 = rng.normal_vec(shape.kv_dim());
        f.append(1, &k2, &v2);
        c.attend(1, &q, &mut o1);
        assert_eq!(o1, before, "fork mutation leaked into the original");

        // dropping the fork releases the sharing
        drop(f);
        assert_eq!(c.shared_prefix_bytes(), 0.0);
    }

    #[test]
    fn split_prefill_matches_cold_prefill_bitwise() {
        // ingest(prefix) + ingest(suffix) must equal ingest(prefix++suffix)
        // for the non-adaptive configs (the prefix-cache contract).
        for cfg in [
            LexicoConfig { sparsity: 4, n_buffer: 3, ..Default::default() },
            LexicoConfig {
                sparsity: 4,
                n_buffer: 3,
                precision: CoefPrecision::Fp16,
                ..Default::default()
            },
        ] {
            let (shape, mut cold) = setup(64, cfg.clone());
            assert!(cold.split_prefill_exact());
            let (_, mut split) = setup(64, cfg);
            let mut rng = Rng::new(23);
            let (tp, ts) = (9, 5);
            let ks = rng.normal_vec((tp + ts) * shape.kv_dim());
            let vs = rng.normal_vec((tp + ts) * shape.kv_dim());
            let cut = tp * shape.kv_dim();
            for l in 0..shape.n_layers {
                cold.ingest_prefill(l, &ks, &vs, tp + ts, &[], 0);
                split.ingest_prefill(l, &ks[..cut], &vs[..cut], tp, &[], 0);
                split.ingest_prefill(l, &ks[cut..], &vs[cut..], ts, &[], 0);
            }
            assert_eq!(cold.tokens(), split.tokens());
            assert_eq!(cold.mem_bytes(), split.mem_bytes());
            for (hc, hs) in cold.heads.iter().zip(&split.heads) {
                assert_eq!(hc.n_csr, hs.n_csr);
                for (a, b) in hc.k_rows().zip(hs.k_rows()) {
                    assert_eq!((&a.idx, &a.coef_bits), (&b.idx, &b.coef_bits));
                }
                for (a, b) in hc.v_rows().zip(hs.v_rows()) {
                    assert_eq!((&a.idx, &a.coef_bits), (&b.idx, &b.coef_bits));
                }
                assert_eq!(hc.k_buf, hs.k_buf);
                assert_eq!(hc.v_buf, hs.v_buf);
            }
        }
        // adaptive mode must *declare* itself split-inexact
        let (_, c) = setup(16, LexicoConfig {
            sparsity: 2,
            n_buffer: 2,
            adaptive: Some((8, 0.1)),
            ..Default::default()
        });
        assert!(!c.split_prefill_exact());
    }

    #[test]
    fn adaptive_mode_grows_and_charges_memory() {
        let cfg = LexicoConfig {
            sparsity: 2,
            n_buffer: 1,
            adaptive: Some((8, 0.05)),
            ..Default::default()
        };
        let (shape, mut c) = setup(16, cfg); // tiny dict → adaptation certain
        let mut rng = Rng::new(11);
        for _ in 0..6 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let extra: usize = c.adaptive_k.iter().flatten().map(|a| a.n_extra).sum();
        assert!(extra > 0, "no adaptive growth");
        let base_mem: f64 = c
            .heads
            .iter()
            .flat_map(|h| h.k_rows().chain(h.v_rows()).collect::<Vec<_>>())
            .map(|r| r.bytes() as f64)
            .sum::<f64>();
        assert!(c.mem_bytes() > base_mem, "adaptive atoms not charged");
    }
}
