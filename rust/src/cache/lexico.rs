//! The Lexico KV-cache backend (paper §3.4, Algorithm 2, Eq. 7).
//!
//! Per layer and kv head the cache holds
//!   * `K_csr`/`V_csr` — OMP sparse codes (u16 indices + FP8/FP16 coefs);
//!   * a full-precision recency buffer of up to `n_b` tokens.
//! When the buffer exceeds `n_b`, the oldest `n_a` tokens are OMP-compressed
//! (the paper runs this in parallel with the forward pass; here it is the
//! same computation on the same thread, measured separately by the latency
//! bench).
//!
//! Decode attention follows the paper's split computation: the query is
//! first multiplied by the dictionary (`q·D_k`, O(N·m)), then contracted
//! against the sparse codes (O(T·s)); buffer tokens take the dense path;
//! one softmax spans both. The value side accumulates coefficients into a
//! dictionary-bin vector `z` and finishes with atoms·z — the same
//! O(N·m + T·s) complexity the paper reports.

use super::{CacheShape, KvCache};
use crate::dict::adaptive::AdaptiveDict;
use crate::dict::DictionarySet;
use crate::omp::{omp_encode, OmpWorkspace};
use crate::sparse::{CoefPrecision, CsrRow};
use crate::tensor::{axpy, dot, softmax};
use std::sync::Arc;

/// Lexico knobs (paper defaults in comments).
#[derive(Clone, Debug)]
pub struct LexicoConfig {
    /// sparsity per vector (s); with `delta > 0` this is the max sparsity
    pub sparsity: usize,
    /// relative-error early-termination threshold δ (0 ⇒ fixed sparsity)
    pub delta: f32,
    /// full-precision recency buffer length n_b (paper: 128)
    pub n_buffer: usize,
    /// approximation window n_a — tokens compressed per overflow (paper: 1)
    pub n_approx: usize,
    /// CSR coefficient precision (paper main: FP8; ablations: FP16)
    pub precision: CoefPrecision,
    /// adaptive dictionary learning (§4.2.4): (max added atoms, δ_adapt)
    pub adaptive: Option<(usize, f32)>,
}

impl Default for LexicoConfig {
    fn default() -> Self {
        LexicoConfig {
            sparsity: 8,
            delta: 0.0,
            n_buffer: 32,
            n_approx: 1,
            precision: CoefPrecision::Fp8,
            adaptive: None,
        }
    }
}

/// Per-(layer, kv-head) state.
struct HeadState {
    k_csr: Vec<CsrRow>,
    v_csr: Vec<CsrRow>,
    /// token-major buffer rows, oldest first: [t][m]
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

pub struct LexicoCache {
    shape: CacheShape,
    cfg: LexicoConfig,
    dicts: Arc<DictionarySet>,
    /// adaptive overlays (lazily created when cfg.adaptive is set)
    adaptive_k: Vec<Option<AdaptiveDict>>,
    adaptive_v: Vec<Option<AdaptiveDict>>,
    /// heads[layer * n_kv_heads + g]
    heads: Vec<HeadState>,
    tokens: usize,
    ws: OmpWorkspace,
    // attend scratch
    scores: Vec<f32>,
    qd: Vec<f32>,
    z: Vec<f32>,
}

impl LexicoCache {
    pub fn new(shape: CacheShape, dicts: Arc<DictionarySet>, cfg: LexicoConfig) -> Self {
        assert_eq!(dicts.keys.len(), shape.n_layers, "dict layers mismatch");
        let n = dicts.keys[0].n;
        let m = shape.head_dim;
        assert_eq!(dicts.keys[0].m, m, "dict head_dim mismatch");
        let heads = (0..shape.n_layers * shape.n_kv_heads)
            .map(|_| HeadState {
                k_csr: Vec::new(),
                v_csr: Vec::new(),
                k_buf: Vec::new(),
                v_buf: Vec::new(),
                buf_len: 0,
            })
            .collect();
        let (adaptive_k, adaptive_v) = if let Some((max_extra, d)) = cfg.adaptive {
            (
                dicts.keys.iter().map(|b| Some(AdaptiveDict::new(b, max_extra, d))).collect(),
                dicts.values.iter().map(|b| Some(AdaptiveDict::new(b, max_extra, d))).collect(),
            )
        } else {
            (
                (0..shape.n_layers).map(|_| None).collect(),
                (0..shape.n_layers).map(|_| None).collect(),
            )
        };
        let n_cap = n + cfg.adaptive.map(|(e, _)| e).unwrap_or(0);
        LexicoCache {
            shape,
            ws: OmpWorkspace::new(n_cap, m, cfg.sparsity.max(1)),
            cfg,
            dicts,
            adaptive_k,
            adaptive_v,
            heads,
            tokens: 0,
            scores: Vec::new(),
            qd: vec![0.0; n_cap],
            z: vec![0.0; n_cap],
        }
    }

    #[inline]
    fn head_idx(&self, layer: usize, g: usize) -> usize {
        layer * self.shape.n_kv_heads + g
    }

    /// Compress one vector with the layer's K or V dictionary.
    fn encode(&mut self, layer: usize, is_key: bool, x: &[f32]) -> CsrRow {
        let prec = self.cfg.precision;
        let (s, delta) = (self.cfg.sparsity, self.cfg.delta);
        let adapt = if is_key {
            &mut self.adaptive_k[layer]
        } else {
            &mut self.adaptive_v[layer]
        };
        let code = if let Some(ad) = adapt.as_mut() {
            ad.encode(x, s, &mut self.ws).0
        } else {
            let d = if is_key {
                &self.dicts.keys[layer]
            } else {
                &self.dicts.values[layer]
            };
            omp_encode(&d.atoms, d.n, d.m, x, s, delta, &mut self.ws)
        };
        CsrRow::from_f32(&code.idx, &code.val, prec)
    }

    /// Compress the oldest `n` buffer tokens of every kv head in `layer`.
    fn compress_oldest(&mut self, layer: usize, n: usize) {
        let m = self.shape.head_dim;
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            for _ in 0..n {
                if self.heads[hi].buf_len == 0 {
                    break;
                }
                let k: Vec<f32> = self.heads[hi].k_buf[..m].to_vec();
                let v: Vec<f32> = self.heads[hi].v_buf[..m].to_vec();
                let k_row = self.encode(layer, true, &k);
                let v_row = self.encode(layer, false, &v);
                let h = &mut self.heads[hi];
                h.k_csr.push(k_row);
                h.v_csr.push(v_row);
                h.k_buf.drain(..m);
                h.v_buf.drain(..m);
                h.buf_len -= 1;
            }
        }
    }

    /// Current atom views per layer (base or adaptive overlay).
    fn atoms(&self, layer: usize, is_key: bool) -> (&[f32], usize) {
        let (ad, base) = if is_key {
            (&self.adaptive_k[layer], &self.dicts.keys[layer])
        } else {
            (&self.adaptive_v[layer], &self.dicts.values[layer])
        };
        match ad {
            Some(a) => (a.atoms(), a.n_atoms()),
            None => (&base.atoms, base.n),
        }
    }
}

impl KvCache for LexicoCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        let m = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        // load everything into the buffer, then compress all but the last n_b
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            for ti in 0..t {
                self.heads[hi]
                    .k_buf
                    .extend_from_slice(&ks[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
                self.heads[hi]
                    .v_buf
                    .extend_from_slice(&vs[ti * kvd + g * m..ti * kvd + (g + 1) * m]);
            }
            self.heads[hi].buf_len += t;
        }
        let overflow = self.heads[self.head_idx(layer, 0)]
            .buf_len
            .saturating_sub(self.cfg.n_buffer);
        if overflow > 0 {
            self.compress_oldest(layer, overflow);
        }
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let m = self.shape.head_dim;
        for g in 0..self.shape.n_kv_heads {
            let hi = self.head_idx(layer, g);
            self.heads[hi].k_buf.extend_from_slice(&k[g * m..(g + 1) * m]);
            self.heads[hi].v_buf.extend_from_slice(&v[g * m..(g + 1) * m]);
            self.heads[hi].buf_len += 1;
        }
        if self.heads[self.head_idx(layer, 0)].buf_len > self.cfg.n_buffer {
            self.compress_oldest(layer, self.cfg.n_approx);
        }
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let m = self.shape.head_dim;
        let n_heads = self.shape.n_heads;
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        let (k_atoms_ptr, k_n) = {
            let (a, n) = self.atoms(layer, true);
            (a.as_ptr(), n)
        };
        let (v_atoms_ptr, v_n) = {
            let (a, n) = self.atoms(layer, false);
            (a.as_ptr(), n)
        };
        // SAFETY: atoms live in self and are not mutated during attend.
        let k_atoms = unsafe { std::slice::from_raw_parts(k_atoms_ptr, k_n * m) };
        let v_atoms = unsafe { std::slice::from_raw_parts(v_atoms_ptr, v_n * m) };

        // qd[h][n] = q_h · D_k[n] for ALL heads in one streaming pass over
        // the dictionary (perf pass #1, EXPERIMENTS.md §Perf: one load of
        // each atom now serves every query head instead of H separate
        // passes over the N·m array). Set LEXICO_QD_PER_HEAD=1 to use the
        // pre-optimization per-head layout (kept for the §Perf comparison).
        if self.qd.len() < n_heads * k_n {
            self.qd.resize(n_heads * k_n, 0.0);
        }
        {
            let qd = &mut self.qd[..n_heads * k_n];
            if std::env::var_os("LEXICO_QD_PER_HEAD").is_some() {
                for h in 0..n_heads {
                    let qh = &q[h * m..(h + 1) * m];
                    for n in 0..k_n {
                        qd[h * k_n + n] = dot(qh, &k_atoms[n * m..(n + 1) * m]);
                    }
                }
            } else {
                for n in 0..k_n {
                    let atom = &k_atoms[n * m..(n + 1) * m];
                    for h in 0..n_heads {
                        qd[h * k_n + n] = dot(&q[h * m..(h + 1) * m], atom);
                    }
                }
            }
        }

        for h in 0..n_heads {
            let g = h / self.shape.group();
            let hi = self.head_idx(layer, g);
            let head = &self.heads[hi];
            let tc = head.k_csr.len();
            let tb = head.buf_len;
            let qh = &q[h * m..(h + 1) * m];
            let qd = &self.qd[h * k_n..(h + 1) * k_n];
            // compressed scores: O(T·s)
            self.scores.resize(tc + tb, 0.0);
            for (ti, row) in head.k_csr.iter().enumerate() {
                let mut sc = 0.0;
                for j in 0..row.nnz() {
                    sc += qd[row.idx[j] as usize] * row.coef(j);
                }
                self.scores[ti] = sc * scale;
            }
            // buffer scores: dense
            for ti in 0..tb {
                self.scores[tc + ti] =
                    dot(qh, &head.k_buf[ti * m..(ti + 1) * m]) * scale;
            }
            softmax(&mut self.scores[..tc + tb]);

            // value side: z-bin accumulation, then atoms·z  (O(T·s + N·m))
            let oh = &mut out[h * m..(h + 1) * m];
            let z = &mut self.z[..v_n];
            z.fill(0.0);
            for (ti, row) in head.v_csr.iter().enumerate() {
                let w = self.scores[ti];
                for j in 0..row.nnz() {
                    z[row.idx[j] as usize] += w * row.coef(j);
                }
            }
            for (n, &zn) in z.iter().enumerate() {
                if zn != 0.0 {
                    axpy(oh, zn, &v_atoms[n * m..(n + 1) * m]);
                }
            }
            for ti in 0..tb {
                axpy(oh, self.scores[tc + ti], &head.v_buf[ti * m..(ti + 1) * m]);
            }
        }
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    fn mem_bytes(&self) -> f64 {
        let m = self.shape.head_dim;
        let mut bytes = 0.0;
        for head in &self.heads {
            for row in head.k_csr.iter().chain(&head.v_csr) {
                bytes += row.bytes() as f64;
            }
            bytes += (head.buf_len * 2 * m * 2) as f64; // buffer @ FP16
        }
        // adaptive atoms are session-private → charged to KV size (§4.2.4)
        for ad in self.adaptive_k.iter().chain(&self.adaptive_v).flatten() {
            bytes += ad.extra_bytes() as f64;
        }
        bytes
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        let mut s = format!("lexico_s{}", self.cfg.sparsity);
        if self.cfg.delta > 0.0 {
            s += &format!("_d{:.2}", self.cfg.delta);
        }
        if self.cfg.adaptive.is_some() {
            s += "_adaptive";
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(n_atoms: usize, cfg: LexicoConfig) -> (CacheShape, LexicoCache) {
        let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let dicts = DictionarySet {
            keys: (0..2).map(|i| crate::dict::Dictionary::random(16, n_atoms, i)).collect(),
            values: (0..2).map(|i| crate::dict::Dictionary::random(16, n_atoms, 100 + i)).collect(),
        };
        let c = LexicoCache::new(shape, Arc::new(dicts), cfg);
        (shape, c)
    }

    #[test]
    fn buffer_then_compression() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 4, n_approx: 1, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        // 10 tokens, buffer 4 → 6 compressed per head
        let h = &c.heads[0];
        assert_eq!(h.buf_len, 4);
        assert_eq!(h.k_csr.len(), 6);
        assert!(c.kv_ratio() < 1.0);
        assert_eq!(c.tokens(), 10);
    }

    #[test]
    fn attend_matches_full_cache_when_reconstruction_is_exact() {
        // Identity dictionary (16 atoms = basis) with s=16 reconstructs
        // exactly → Lexico attention must equal full-cache attention.
        let shape = CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 16 };
        let mut atoms = vec![0.0; 16 * 16];
        for i in 0..16 {
            atoms[i * 16 + i] = 1.0;
        }
        let d = crate::dict::Dictionary::new(16, 16, atoms);
        let dicts = DictionarySet { keys: vec![d.clone()], values: vec![d] };
        let cfg = LexicoConfig {
            sparsity: 16,
            n_buffer: 2,
            precision: CoefPrecision::Fp16,
            ..Default::default()
        };
        let mut lex = LexicoCache::new(shape, Arc::new(dicts), cfg);
        let mut full = crate::cache::full::FullCache::new(shape);
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            // keep coordinates modest so fp16 rounding stays negligible
            let k: Vec<f32> = rng.normal_vec(16).iter().map(|x| x * 0.5).collect();
            let v: Vec<f32> = rng.normal_vec(16).iter().map(|x| x * 0.5).collect();
            lex.append(0, &k, &v);
            full.append(0, &k, &v);
        }
        let q = rng.normal_vec(shape.q_dim());
        let mut o1 = vec![0.0; shape.q_dim()];
        let mut o2 = vec![0.0; shape.q_dim()];
        lex.attend(0, &q, &mut o1);
        full.attend(0, &q, &mut o2);
        crate::util::prop::assert_close(&o1, &o2, 2e-2, "lexico≈full").unwrap();
    }

    #[test]
    fn prefill_compresses_all_but_buffer() {
        let cfg = LexicoConfig { sparsity: 2, n_buffer: 3, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(5);
        let t = 9;
        let ks = rng.normal_vec(t * shape.kv_dim());
        let vs = rng.normal_vec(t * shape.kv_dim());
        for l in 0..shape.n_layers {
            c.ingest_prefill(l, &ks, &vs, t, &[], 0);
        }
        assert_eq!(c.heads[0].buf_len, 3);
        assert_eq!(c.heads[0].k_csr.len(), 6);
        assert_eq!(c.tokens(), t);
    }

    #[test]
    fn memory_accounting_matches_formula() {
        let cfg = LexicoConfig { sparsity: 4, n_buffer: 2, ..Default::default() };
        let (shape, mut c) = setup(64, cfg);
        let mut rng = Rng::new(7);
        for _ in 0..6 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        // per head: 4 csr tokens ≤ (3·4+2)·2 rows... plus 2 buffer tokens
        // random vectors are dense: every row has exactly s=4 nnz
        let per_head = 4 * (3 * 4 + 2) * 2 + 2 * 2 * 16 * 2;
        let total = per_head * shape.n_layers * shape.n_kv_heads;
        assert_eq!(c.mem_bytes(), total as f64);
    }

    #[test]
    fn adaptive_mode_grows_and_charges_memory() {
        let cfg = LexicoConfig {
            sparsity: 2,
            n_buffer: 1,
            adaptive: Some((8, 0.05)),
            ..Default::default()
        };
        let (shape, mut c) = setup(16, cfg); // tiny dict → adaptation certain
        let mut rng = Rng::new(11);
        for _ in 0..6 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        let extra: usize = c.adaptive_k.iter().flatten().map(|a| a.n_extra).sum();
        assert!(extra > 0, "no adaptive growth");
        let base_mem: f64 = c
            .heads
            .iter()
            .flat_map(|h| h.k_csr.iter().chain(&h.v_csr))
            .map(|r| r.bytes() as f64)
            .sum::<f64>();
        assert!(c.mem_bytes() > base_mem, "adaptive atoms not charged");
    }
}
