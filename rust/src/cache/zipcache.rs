//! ZipCache (He et al. 2024): salient-token mixed-precision quantization.
//!
//! Tokens are quantized per token when they leave the recency window; the
//! precision (hi vs lo bits) is chosen by *accumulated normalized attention
//! mass* — the saliency metric ZipCache introduces. Saliency is tracked
//! from every `attend` call (normalized by how many queries a token has
//! been visible to, so early tokens are not unfairly favoured).

use super::{CacheShape, KvCache};
use crate::quant::{dequantize_vector, quantize_vector, QuantGroup};

#[derive(Clone, Debug)]
pub struct ZipCacheConfig {
    pub bits_hi: u8,
    pub bits_lo: u8,
    pub group: usize,
    /// fraction of tokens treated as salient (paper sweeps ~0.1–0.4)
    pub salient_frac: f32,
    /// recency window kept in FP16 while saliency statistics accumulate
    pub n_buffer: usize,
}

impl Default for ZipCacheConfig {
    fn default() -> Self {
        ZipCacheConfig { bits_hi: 4, bits_lo: 2, group: 16, salient_frac: 0.2, n_buffer: 16 }
    }
}

#[derive(Clone)]
struct LayerState {
    qk: Vec<Vec<QuantGroup>>,
    qv: Vec<Vec<QuantGroup>>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
    /// accumulated attention mass per *visible* token (quantized + buffer)
    salience: Vec<f32>,
    /// number of attend calls each token has been visible to
    exposure: Vec<f32>,
}

#[derive(Clone)]
pub struct ZipCache {
    shape: CacheShape,
    cfg: ZipCacheConfig,
    layers: Vec<LayerState>,
    tokens: usize,
    /// incremental compressed-footprint bytes (kept in sync on every
    /// buffer push and spill → `mem_bytes` is O(1))
    mem: f64,
    scores: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

impl ZipCache {
    pub fn new(shape: CacheShape, cfg: ZipCacheConfig) -> Self {
        let layers = (0..shape.n_layers)
            .map(|_| LayerState {
                qk: Vec::new(),
                qv: Vec::new(),
                k_buf: Vec::new(),
                v_buf: Vec::new(),
                buf_len: 0,
                salience: Vec::new(),
                exposure: Vec::new(),
            })
            .collect();
        ZipCache {
            shape,
            cfg,
            layers,
            tokens: 0,
            mem: 0.0,
            scores: Vec::new(),
            dk: Vec::new(),
            dv: Vec::new(),
        }
    }

    /// FP16 accounting of one buffered token (K + V rows).
    fn buf_token_bytes(&self) -> f64 {
        (2 * self.shape.kv_dim() * 2) as f64
    }

    fn spill(&mut self, layer: usize) {
        let kvd = self.shape.kv_dim();
        let buf_bytes = self.buf_token_bytes();
        let mut dm = 0.0;
        let cfg = &self.cfg;
        let st = &mut self.layers[layer];
        while st.buf_len > cfg.n_buffer {
            let tid = st.qk.len(); // global index of the token being spilled
            // normalized saliency of this token vs. the median of all seen
            let norm = |i: usize, st: &LayerState| {
                st.salience[i] / st.exposure[i].max(1.0)
            };
            let mine = norm(tid, st);
            let mut all: Vec<f32> = (0..st.salience.len()).map(|i| norm(i, st)).collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let cut = all[(((1.0 - cfg.salient_frac) as f64 * (all.len() - 1) as f64) as usize)
                .min(all.len() - 1)];
            let bits = if mine >= cut { cfg.bits_hi } else { cfg.bits_lo };
            let k: Vec<f32> = st.k_buf[..kvd].to_vec();
            let v: Vec<f32> = st.v_buf[..kvd].to_vec();
            st.qk.push(quantize_vector(&k, cfg.group.min(kvd), bits));
            st.qv.push(quantize_vector(&v, cfg.group.min(kvd), bits));
            dm += st.qk.last().unwrap().iter().map(|g| g.bytes()).sum::<f64>();
            dm += st.qv.last().unwrap().iter().map(|g| g.bytes()).sum::<f64>();
            dm -= buf_bytes;
            st.k_buf.drain(..kvd);
            st.v_buf.drain(..kvd);
            st.buf_len -= 1;
        }
        self.mem += dm;
    }

    fn materialize(&mut self, layer: usize) -> usize {
        let kvd = self.shape.kv_dim();
        let st = &self.layers[layer];
        let tq = st.qk.len();
        let t = tq + st.buf_len;
        self.dk.resize(t * kvd, 0.0);
        self.dv.resize(t * kvd, 0.0);
        for ti in 0..tq {
            dequantize_vector(&st.qk[ti], &mut self.dk[ti * kvd..(ti + 1) * kvd]);
            dequantize_vector(&st.qv[ti], &mut self.dv[ti * kvd..(ti + 1) * kvd]);
        }
        self.dk[tq * kvd..t * kvd].copy_from_slice(&st.k_buf[..st.buf_len * kvd]);
        self.dv[tq * kvd..t * kvd].copy_from_slice(&st.v_buf[..st.buf_len * kvd]);
        t
    }
}

impl KvCache for ZipCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      q_win: &[f32], w: usize) {
        {
            let st = &mut self.layers[layer];
            st.k_buf.extend_from_slice(ks);
            st.v_buf.extend_from_slice(vs);
            st.buf_len += t;
            st.salience.resize(st.salience.len() + t, 0.0);
            st.exposure.resize(st.exposure.len() + t, 0.0);
        }
        self.mem += t as f64 * self.buf_token_bytes();
        // seed saliency with the observation-window queries so prefill
        // tokens spill with informed precision
        if w > 0 {
            let qd = self.shape.q_dim();
            for wi in 0..w {
                let q: Vec<f32> = q_win[wi * qd..(wi + 1) * qd].to_vec();
                let mut scratch = vec![0.0; qd];
                self.attend(layer, &q, &mut scratch); // updates salience
            }
        }
        self.spill(layer);
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let st = &mut self.layers[layer];
        st.k_buf.extend_from_slice(k);
        st.v_buf.extend_from_slice(v);
        st.buf_len += 1;
        st.salience.push(0.0);
        st.exposure.push(0.0);
        self.mem += self.buf_token_bytes();
        self.spill(layer);
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let t = self.materialize(layer);
        let m = self.shape.head_dim;
        let kvd = self.shape.kv_dim();
        let scale = 1.0 / (m as f32).sqrt();
        out.fill(0.0);
        self.scores.resize(t, 0.0);
        let st = &mut self.layers[layer];
        for h in 0..self.shape.n_heads {
            let g = h / self.shape.group();
            let qh = &q[h * m..(h + 1) * m];
            for ti in 0..t {
                self.scores[ti] = crate::tensor::dot(
                    qh,
                    &self.dk[ti * kvd + g * m..ti * kvd + (g + 1) * m],
                ) * scale;
            }
            crate::tensor::softmax(&mut self.scores[..t]);
            let oh = &mut out[h * m..(h + 1) * m];
            for ti in 0..t {
                crate::tensor::axpy(
                    oh,
                    self.scores[ti],
                    &self.dv[ti * kvd + g * m..ti * kvd + (g + 1) * m],
                );
                st.salience[ti] += self.scores[ti];
            }
        }
        for ti in 0..t {
            st.exposure[ti] += 1.0;
        }
    }

    /// Forks carry the accumulated salience/exposure statistics with them,
    /// so a fork's future spill decisions match the original's exactly.
    fn fork(&self) -> Box<dyn KvCache> {
        Box::new(self.clone())
    }

    /// Salience accumulates across the *whole* prompt before prefill spill
    /// decisions are made; splitting the prompt changes the statistics at
    /// spill time, so split prefill is not bitwise-reproducible.
    fn caps(&self) -> super::CacheCaps {
        super::CacheCaps {
            split_prefill_exact: false,
            ..Default::default()
        }
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    /// O(1): maintained incrementally on push/spill instead of re-walking
    /// every quant group per call.
    fn mem_bytes(&self) -> f64 {
        self.mem
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        format!("zipcache_{}_{}", self.cfg.bits_hi, self.cfg.bits_lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn shape() -> CacheShape {
        CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 16 }
    }

    #[test]
    fn mixed_precision_sits_between_lo_and_hi() {
        let mk = |hi, lo| {
            let cfg = ZipCacheConfig {
                bits_hi: hi, bits_lo: lo, group: 16, salient_frac: 0.3, n_buffer: 2,
            };
            let mut c = ZipCache::new(shape(), cfg);
            let mut rng = Rng::new(6);
            let mut out = vec![0.0; 32];
            for _ in 0..20 {
                let k = rng.normal_vec(16);
                let v = rng.normal_vec(16);
                c.append(0, &k, &v);
                let q = rng.normal_vec(32);
                c.attend(0, &q, &mut out);
            }
            c.kv_ratio()
        };
        let pure2 = mk(2, 2);
        let mixed = mk(4, 2);
        let pure4 = mk(4, 4);
        assert!(pure2 < mixed && mixed < pure4, "{pure2} {mixed} {pure4}");
    }

    #[test]
    fn incremental_mem_equals_walked_groups() {
        // the O(1) counter vs the full walk (the pre-PR formula), exactly —
        // spill precision varies per token (hi/lo), so group bytes differ
        let cfg = ZipCacheConfig {
            bits_hi: 4, bits_lo: 2, group: 8, salient_frac: 0.3, n_buffer: 2,
        };
        let mut c = ZipCache::new(shape(), cfg);
        let mut rng = Rng::new(20);
        let walk = |c: &ZipCache| -> f64 {
            let mut bytes = 0.0;
            for st in &c.layers {
                for groups in st.qk.iter().chain(&st.qv) {
                    bytes += groups.iter().map(|g| g.bytes()).sum::<f64>();
                }
                bytes += (st.buf_len * 2 * c.shape.kv_dim() * 2) as f64;
            }
            bytes
        };
        let mut out = vec![0.0; 32];
        for i in 0..12 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
            let q = rng.normal_vec(32);
            c.attend(0, &q, &mut out); // accumulate salience → mixed spills
            assert_eq!(c.mem_bytes(), walk(&c), "after append {i}");
        }
        let f = c.fork();
        assert_eq!(f.mem_bytes(), c.mem_bytes(), "fork accounting");
    }

    #[test]
    fn salience_accumulates() {
        let mut c = ZipCache::new(shape(), ZipCacheConfig::default());
        let mut rng = Rng::new(8);
        let k = rng.normal_vec(16);
        let v = rng.normal_vec(16);
        c.append(0, &k, &v);
        let q = rng.normal_vec(32);
        let mut out = vec![0.0; 32];
        c.attend(0, &q, &mut out);
        // single token takes all attention mass from both heads
        assert!((c.layers[0].salience[0] - 2.0).abs() < 1e-5);
        assert_eq!(c.layers[0].exposure[0], 1.0);
    }
}
