//! KIVI (Liu et al. 2024): asymmetric 2/4-bit KV quantization.
//!
//! Keys are quantized **per channel** in groups of `g` tokens (outlier
//! channels dominate key error, so grouping along the token axis per
//! channel isolates them); values are quantized **per token** (group size
//! `g` along channels). The most recent `n_b` tokens stay full precision
//! (the residual), and key tokens leave the residual only in complete
//! groups of `g` so the per-channel grouping stays aligned — both exactly
//! as in the reference implementation.

use super::{dense_attend, dense_attend_batch, CacheShape, KvCache};
use crate::quant::{dequantize_group, dequantize_vector, quantize_group, quantize_vector, QuantGroup};

#[derive(Clone, Debug)]
pub struct KiviConfig {
    pub bits: u8,
    /// quantization group size g (tokens for keys, channels for values)
    pub group: usize,
    /// residual window n_b kept in FP16
    pub n_buffer: usize,
}

impl Default for KiviConfig {
    fn default() -> Self {
        KiviConfig { bits: 2, group: 16, n_buffer: 16 }
    }
}

/// One quantized key block: `g` tokens × kv_dim channels, stored as one
/// QuantGroup per channel (codes indexed by token-within-block).
#[derive(Clone)]
struct KeyBlock {
    per_channel: Vec<QuantGroup>, // [kv_dim]
    len: usize,                   // tokens in the block (== g)
}

#[derive(Clone)]
struct LayerState {
    key_blocks: Vec<KeyBlock>,
    /// per-token quantized values, in token order
    qv: Vec<Vec<QuantGroup>>,
    /// keys waiting for a full group (already out of the residual window)
    k_pending: Vec<f32>, // [t][kv_dim]
    pending_len: usize,
    /// fp residual (most recent n_b tokens), token-major
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

#[derive(Clone)]
pub struct KiviCache {
    shape: CacheShape,
    cfg: KiviConfig,
    layers: Vec<LayerState>,
    tokens: usize,
    /// incremental compressed-footprint bytes (kept in sync on every
    /// buffer push, value spill, pending move and key-block seal →
    /// `mem_bytes` is O(1))
    mem: f64,
    scores: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

impl KiviCache {
    pub fn new(shape: CacheShape, cfg: KiviConfig) -> Self {
        let layers = (0..shape.n_layers)
            .map(|_| LayerState {
                key_blocks: Vec::new(),
                qv: Vec::new(),
                k_pending: Vec::new(),
                pending_len: 0,
                k_buf: Vec::new(),
                v_buf: Vec::new(),
                buf_len: 0,
            })
            .collect();
        KiviCache {
            shape,
            cfg,
            layers,
            tokens: 0,
            mem: 0.0,
            scores: Vec::new(),
            dk: Vec::new(),
            dv: Vec::new(),
        }
    }

    /// Move tokens beyond the residual window out of the buffer: values are
    /// quantized immediately per token; keys accumulate in `k_pending`
    /// until `g` of them form a per-channel block.
    fn spill(&mut self, layer: usize) {
        let kvd = self.shape.kv_dim();
        let g = self.cfg.group;
        let bits = self.cfg.bits;
        let mut dm = 0.0;
        let st = &mut self.layers[layer];
        while st.buf_len > self.cfg.n_buffer {
            let v: Vec<f32> = st.v_buf[..kvd].to_vec();
            st.qv.push(quantize_vector(&v, g.min(kvd), bits));
            // residual (2·kvd·2 B) → quantized value + FP16 pending key
            dm += st.qv.last().unwrap().iter().map(|q| q.bytes()).sum::<f64>();
            dm += (kvd * 2) as f64 - (2 * kvd * 2) as f64;
            st.k_pending.extend_from_slice(&st.k_buf[..kvd]);
            st.pending_len += 1;
            st.k_buf.drain(..kvd);
            st.v_buf.drain(..kvd);
            st.buf_len -= 1;
        }
        while st.pending_len >= g {
            // per-channel quantization over the g oldest pending tokens
            let mut per_channel = Vec::with_capacity(kvd);
            let mut col = vec![0.0f32; g];
            for c in 0..kvd {
                for ti in 0..g {
                    col[ti] = st.k_pending[ti * kvd + c];
                }
                per_channel.push(quantize_group(&col, bits));
            }
            // g FP16 pending keys → one per-channel block
            dm += per_channel.iter().map(|q| q.bytes()).sum::<f64>();
            dm -= (g * kvd * 2) as f64;
            st.key_blocks.push(KeyBlock { per_channel, len: g });
            st.k_pending.drain(..g * kvd);
            st.pending_len -= g;
        }
        self.mem += dm;
    }

    /// Dequantize everything (blocks + pending keys + residual) token-major.
    fn materialize(&mut self, layer: usize) -> usize {
        let kvd = self.shape.kv_dim();
        let st = &self.layers[layer];
        let t_blocks: usize = st.key_blocks.iter().map(|b| b.len).sum();
        let t = t_blocks + st.pending_len + st.buf_len;
        self.dk.resize(t * kvd, 0.0);
        self.dv.resize(t * kvd, 0.0);
        // keys from per-channel blocks
        let mut off = 0;
        let mut col = vec![0.0f32; self.cfg.group];
        for b in &st.key_blocks {
            for c in 0..kvd {
                dequantize_group(&b.per_channel[c], &mut col[..b.len]);
                for ti in 0..b.len {
                    self.dk[(off + ti) * kvd + c] = col[ti];
                }
            }
            off += b.len;
        }
        // pending keys (still fp; charged as fp16 in accounting)
        self.dk[off * kvd..(off + st.pending_len) * kvd]
            .copy_from_slice(&st.k_pending[..st.pending_len * kvd]);
        // residual keys
        let roff = off + st.pending_len;
        self.dk[roff * kvd..t * kvd].copy_from_slice(&st.k_buf[..st.buf_len * kvd]);
        // values: quantized tokens then residual
        let tq = st.qv.len();
        for ti in 0..tq {
            dequantize_vector(&st.qv[ti], &mut self.dv[ti * kvd..(ti + 1) * kvd]);
        }
        self.dv[tq * kvd..t * kvd].copy_from_slice(&st.v_buf[..st.buf_len * kvd]);
        debug_assert_eq!(tq + st.buf_len, t, "value/key token count mismatch");
        t
    }
}

impl KvCache for KiviCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        let st = &mut self.layers[layer];
        st.k_buf.extend_from_slice(ks);
        st.v_buf.extend_from_slice(vs);
        st.buf_len += t;
        self.mem += (t * 2 * self.shape.kv_dim() * 2) as f64;
        self.spill(layer);
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let st = &mut self.layers[layer];
        st.k_buf.extend_from_slice(k);
        st.v_buf.extend_from_slice(v);
        st.buf_len += 1;
        self.mem += (2 * self.shape.kv_dim() * 2) as f64;
        self.spill(layer);
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let t = self.materialize(layer);
        let mut scores = std::mem::take(&mut self.scores);
        let dk = std::mem::take(&mut self.dk);
        let dv = std::mem::take(&mut self.dv);
        dense_attend(&self.shape, &dk, &dv, t, q, out, &mut scores);
        self.scores = scores;
        self.dk = dk;
        self.dv = dv;
    }

    fn append_batch(&mut self, layer: usize, ks: &[f32], vs: &[f32], b: usize) {
        // one bulk extend + one spill: the spill loop moves tokens out
        // oldest-first until the residual fits, which is exactly the state
        // `b` sequential append/spill pairs leave behind.
        let st = &mut self.layers[layer];
        st.k_buf.extend_from_slice(ks);
        st.v_buf.extend_from_slice(vs);
        st.buf_len += b;
        self.mem += (b * 2 * self.shape.kv_dim() * 2) as f64;
        self.spill(layer);
        if layer == 0 {
            self.tokens += b;
        }
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32], b: usize) {
        // the win: one dequantization pass serves every query
        let t = self.materialize(layer);
        let mut scores = std::mem::take(&mut self.scores);
        let dk = std::mem::take(&mut self.dk);
        let dv = std::mem::take(&mut self.dv);
        dense_attend_batch(&self.shape, &dk, &dv, t, qs, out, b, &mut scores);
        self.scores = scores;
        self.dk = dk;
        self.dv = dv;
    }

    fn fork(&self) -> Box<dyn KvCache> {
        Box::new(self.clone())
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    /// O(1): maintained incrementally on push/spill/block-seal instead of
    /// re-walking every quant group per call.
    fn mem_bytes(&self) -> f64 {
        self.mem
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        format!("kivi_{}bit", self.cfg.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::full::FullCache;
    use crate::util::rng::Rng;

    fn shape() -> CacheShape {
        CacheShape { n_layers: 1, n_heads: 2, n_kv_heads: 1, head_dim: 16 }
    }

    #[test]
    fn key_blocks_form_per_group() {
        let cfg = KiviConfig { bits: 2, group: 4, n_buffer: 2 };
        let mut c = KiviCache::new(shape(), cfg);
        let mut rng = Rng::new(1);
        for _ in 0..11 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
        }
        // 11 tokens, buffer 2 → 9 out; 2 full key blocks of 4, 1 pending
        let st = &c.layers[0];
        assert_eq!(st.key_blocks.len(), 2);
        assert_eq!(st.pending_len, 1);
        assert_eq!(st.qv.len(), 9);
        assert_eq!(st.buf_len, 2);
    }

    #[test]
    fn batch_entry_points_match_sequential_exactly() {
        let cfg = KiviConfig { bits: 2, group: 4, n_buffer: 3 };
        let mut seq = KiviCache::new(shape(), cfg.clone());
        let mut bat = KiviCache::new(shape(), cfg);
        let mut rng = Rng::new(21);
        let (kvd, qd) = (16, 32);
        let n = 13; // crosses several spill + key-block boundaries
        let ks = rng.normal_vec(n * kvd);
        let vs = rng.normal_vec(n * kvd);
        for i in 0..n {
            seq.append(0, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
        }
        bat.append_batch(0, &ks, &vs, n);
        assert_eq!(seq.tokens(), bat.tokens());
        assert_eq!(seq.layers[0].key_blocks.len(), bat.layers[0].key_blocks.len());
        assert_eq!(seq.layers[0].pending_len, bat.layers[0].pending_len);
        assert_eq!(seq.layers[0].buf_len, bat.layers[0].buf_len);
        assert_eq!(seq.mem_bytes(), bat.mem_bytes());
        let b = 3;
        let qs = rng.normal_vec(b * qd);
        let mut o_seq = vec![0.0; b * qd];
        let mut o_bat = vec![0.0; b * qd];
        for i in 0..b {
            seq.attend(0, &qs[i * qd..(i + 1) * qd], &mut o_seq[i * qd..(i + 1) * qd]);
        }
        bat.attend_batch(0, &qs, &mut o_bat, b);
        assert_eq!(o_seq, o_bat, "one-dequantization attend must match");
    }

    #[test]
    fn incremental_mem_equals_walked_groups() {
        // the O(1) counter vs the full walk (the pre-PR formula), exactly —
        // across value spills, pending keys, and key-block seals
        let cfg = KiviConfig { bits: 2, group: 4, n_buffer: 2 };
        let mut c = KiviCache::new(shape(), cfg);
        let mut rng = Rng::new(14);
        let walk = |c: &KiviCache| -> f64 {
            let kvd = c.shape.kv_dim() as f64;
            let mut bytes = 0.0;
            for st in &c.layers {
                for b in &st.key_blocks {
                    bytes += b.per_channel.iter().map(|g| g.bytes()).sum::<f64>();
                }
                for groups in &st.qv {
                    bytes += groups.iter().map(|g| g.bytes()).sum::<f64>();
                }
                bytes += st.pending_len as f64 * kvd * 2.0;
                bytes += st.buf_len as f64 * 2.0 * kvd * 2.0;
            }
            bytes
        };
        let t = 6;
        let ks = rng.normal_vec(t * 16);
        let vs = rng.normal_vec(t * 16);
        c.ingest_prefill(0, &ks, &vs, t, &[], 0);
        assert_eq!(c.mem_bytes(), walk(&c), "after prefill");
        for i in 0..13 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
            assert_eq!(c.mem_bytes(), walk(&c), "after append {i}");
        }
        assert!(!c.layers[0].key_blocks.is_empty(), "block seal exercised");
        let f = c.fork();
        assert_eq!(f.mem_bytes(), c.mem_bytes(), "fork accounting");
    }

    #[test]
    fn high_bit_attention_close_to_full() {
        let cfg = KiviConfig { bits: 8, group: 4, n_buffer: 0 };
        let mut c = KiviCache::new(shape(), cfg);
        let mut f = FullCache::new(shape());
        let mut rng = Rng::new(9);
        for _ in 0..8 {
            let k = rng.normal_vec(16);
            let v = rng.normal_vec(16);
            c.append(0, &k, &v);
            f.append(0, &k, &v);
        }
        let q = rng.normal_vec(32);
        let (mut o1, mut o2) = (vec![0.0; 32], vec![0.0; 32]);
        c.attend(0, &q, &mut o1);
        f.attend(0, &q, &mut o2);
        crate::util::prop::assert_close(&o1, &o2, 0.05, "kivi8≈full").unwrap();
    }

    #[test]
    fn two_bit_is_smaller_than_four_bit() {
        let mut sizes = Vec::new();
        for bits in [2u8, 4] {
            let cfg = KiviConfig { bits, group: 16, n_buffer: 0 };
            let mut c = KiviCache::new(shape(), cfg);
            let mut rng = Rng::new(4);
            for _ in 0..32 {
                let k = rng.normal_vec(16);
                let v = rng.normal_vec(16);
                c.append(0, &k, &v);
            }
            sizes.push(c.kv_ratio());
        }
        assert!(sizes[0] < sizes[1], "{sizes:?}");
        // 2-bit g=16 at kvd=16: 8 B keys + 8 B values per token vs 64 B
        assert!(sizes[0] < 0.3, "{sizes:?}");
    }
}
