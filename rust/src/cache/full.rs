//! Full-precision (FP16-accounted) KV cache — the paper's "Full Cache" row.

use super::{dense_attend, CacheShape, KvCache};

pub struct FullCache {
    shape: CacheShape,
    /// per-layer token-major K/V rows
    ks: Vec<Vec<f32>>,
    vs: Vec<Vec<f32>>,
    tokens: usize,
    scores: Vec<f32>,
}

impl FullCache {
    pub fn new(shape: CacheShape) -> Self {
        FullCache {
            ks: vec![Vec::new(); shape.n_layers],
            vs: vec![Vec::new(); shape.n_layers],
            shape,
            tokens: 0,
            scores: Vec::new(),
        }
    }

    /// Raw access for tests / key-geometry analysis (Fig. 3).
    pub fn keys(&self, layer: usize) -> &[f32] {
        &self.ks[layer]
    }

    /// Raw value access (Table 1 KV-vector collection).
    pub fn values(&self, layer: usize) -> &[f32] {
        &self.vs[layer]
    }
}

impl KvCache for FullCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        self.ks[layer].extend_from_slice(ks);
        self.vs[layer].extend_from_slice(vs);
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        self.ks[layer].extend_from_slice(k);
        self.vs[layer].extend_from_slice(v);
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let t = self.ks[layer].len() / self.shape.kv_dim();
        // borrow juggling: move scores buffer out during the call
        let mut scores = std::mem::take(&mut self.scores);
        dense_attend(&self.shape, &self.ks[layer], &self.vs[layer], t, q, out, &mut scores);
        self.scores = scores;
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    fn mem_bytes(&self) -> f64 {
        self.full_bytes()
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        "full".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn shape2() -> CacheShape {
        CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 8 }
    }

    #[test]
    fn append_and_ratio() {
        let shape = shape2();
        let mut c = FullCache::new(shape);
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        assert_eq!(c.tokens(), 5);
        assert!((c.kv_ratio() - 1.0).abs() < 1e-12);
        // 2 layers * 5 tokens * (2 vectors * 16 dims * 2 bytes)
        assert_eq!(c.full_bytes(), (2 * 5 * 2 * 16 * 2) as f64);
    }

    #[test]
    fn attend_is_softmax_average() {
        // With identical keys, attention must average the values.
        let shape = shape2();
        let mut c = FullCache::new(shape);
        let k = vec![1.0; shape.kv_dim()];
        let mut v1 = vec![0.0; shape.kv_dim()];
        let mut v2 = vec![2.0; shape.kv_dim()];
        v1[0] = 4.0;
        v2[0] = 0.0;
        c.append(0, &k, &v1);
        c.append(0, &k, &v2);
        let q = vec![0.5; shape.q_dim()];
        let mut out = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-5); // mean of 4 and 0
        assert!((out[1] - 1.0).abs() < 1e-5); // mean of 0 and 2
    }
}
