//! Full-precision (FP16-accounted) KV cache — the paper's "Full Cache" row.

use super::{dense_attend, dense_attend_batch, CacheShape, KvCache};

#[derive(Clone)]
pub struct FullCache {
    shape: CacheShape,
    /// per-layer token-major K/V rows
    ks: Vec<Vec<f32>>,
    vs: Vec<Vec<f32>>,
    tokens: usize,
    scores: Vec<f32>,
}

impl FullCache {
    pub fn new(shape: CacheShape) -> Self {
        FullCache {
            ks: vec![Vec::new(); shape.n_layers],
            vs: vec![Vec::new(); shape.n_layers],
            shape,
            tokens: 0,
            scores: Vec::new(),
        }
    }

    /// Raw access for tests / key-geometry analysis (Fig. 3).
    pub fn keys(&self, layer: usize) -> &[f32] {
        &self.ks[layer]
    }

    /// Raw value access (Table 1 KV-vector collection).
    pub fn values(&self, layer: usize) -> &[f32] {
        &self.vs[layer]
    }
}

impl KvCache for FullCache {
    fn ingest_prefill(&mut self, layer: usize, ks: &[f32], vs: &[f32], t: usize,
                      _q_win: &[f32], _w: usize) {
        self.ks[layer].extend_from_slice(ks);
        self.vs[layer].extend_from_slice(vs);
        if layer == 0 {
            self.tokens += t;
        }
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        self.ks[layer].extend_from_slice(k);
        self.vs[layer].extend_from_slice(v);
        if layer == 0 {
            self.tokens += 1;
        }
    }

    fn attend(&mut self, layer: usize, q: &[f32], out: &mut [f32]) {
        let t = self.ks[layer].len() / self.shape.kv_dim();
        // borrow juggling: move scores buffer out during the call
        let mut scores = std::mem::take(&mut self.scores);
        dense_attend(&self.shape, &self.ks[layer], &self.vs[layer], t, q, out, &mut scores);
        self.scores = scores;
    }

    fn append_batch(&mut self, layer: usize, ks: &[f32], vs: &[f32], b: usize) {
        self.ks[layer].extend_from_slice(ks);
        self.vs[layer].extend_from_slice(vs);
        if layer == 0 {
            self.tokens += b;
        }
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32], b: usize) {
        let t = self.ks[layer].len() / self.shape.kv_dim();
        let mut scores = std::mem::take(&mut self.scores);
        dense_attend_batch(&self.shape, &self.ks[layer], &self.vs[layer], t, qs, out, b, &mut scores);
        self.scores = scores;
    }

    fn fork(&self) -> Box<dyn KvCache> {
        Box::new(self.clone())
    }

    fn tokens(&self) -> usize {
        self.tokens
    }

    fn mem_bytes(&self) -> f64 {
        self.full_bytes()
    }

    fn full_bytes(&self) -> f64 {
        self.shape.n_layers as f64 * self.tokens as f64 * self.shape.full_token_bytes()
    }

    fn name(&self) -> String {
        "full".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn shape2() -> CacheShape {
        CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 8 }
    }

    #[test]
    fn append_and_ratio() {
        let shape = shape2();
        let mut c = FullCache::new(shape);
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            let k = rng.normal_vec(shape.kv_dim());
            let v = rng.normal_vec(shape.kv_dim());
            for l in 0..shape.n_layers {
                c.append(l, &k, &v);
            }
        }
        assert_eq!(c.tokens(), 5);
        assert!((c.kv_ratio() - 1.0).abs() < 1e-12);
        // 2 layers * 5 tokens * (2 vectors * 16 dims * 2 bytes)
        assert_eq!(c.full_bytes(), (2 * 5 * 2 * 16 * 2) as f64);
    }

    #[test]
    fn batch_entry_points_match_sequential_exactly() {
        let shape = shape2();
        let (kvd, qd) = (shape.kv_dim(), shape.q_dim());
        let mut seq = FullCache::new(shape);
        let mut bat = FullCache::new(shape);
        let mut rng = Rng::new(9);
        let n = 5;
        let ks = rng.normal_vec(n * kvd);
        let vs = rng.normal_vec(n * kvd);
        for l in 0..shape.n_layers {
            for i in 0..n {
                seq.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
            }
            bat.append_batch(l, &ks, &vs, n);
        }
        assert_eq!(seq.tokens(), bat.tokens());
        assert_eq!(seq.mem_bytes(), bat.mem_bytes());
        let b = 3;
        let qs = rng.normal_vec(b * qd);
        let mut o_seq = vec![0.0; b * qd];
        let mut o_bat = vec![0.0; b * qd];
        for i in 0..b {
            seq.attend(0, &qs[i * qd..(i + 1) * qd], &mut o_seq[i * qd..(i + 1) * qd]);
        }
        bat.attend_batch(0, &qs, &mut o_bat, b);
        assert_eq!(o_seq, o_bat, "batched attention must be bitwise identical");
    }

    #[test]
    fn attend_is_softmax_average() {
        // With identical keys, attention must average the values.
        let shape = shape2();
        let mut c = FullCache::new(shape);
        let k = vec![1.0; shape.kv_dim()];
        let mut v1 = vec![0.0; shape.kv_dim()];
        let mut v2 = vec![2.0; shape.kv_dim()];
        v1[0] = 4.0;
        v2[0] = 0.0;
        c.append(0, &k, &v1);
        c.append(0, &k, &v2);
        let q = vec![0.5; shape.q_dim()];
        let mut out = vec![0.0; shape.q_dim()];
        c.attend(0, &q, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-5); // mean of 4 and 0
        assert!((out[1] - 1.0).abs() < 1e-5); // mean of 0 and 2
    }
}
