//! Sparse KV-cache storage: CSR rows, coefficient precision, byte accounting.

pub mod fp8;
pub mod memory;

use fp8::{e4m3_to_f32, f16_to_f32, f32_to_e4m3, f32_to_f16};

/// Precision of the stored CSR coefficients.
///
/// The paper's main configuration is FP8 (E4M3); the ablations in
/// Tables 4/5/9/10 use FP16 coefficients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoefPrecision {
    Fp8,
    Fp16,
}

impl CoefPrecision {
    pub fn bytes_per_coef(self) -> usize {
        match self {
            CoefPrecision::Fp8 => 1,
            CoefPrecision::Fp16 => 2,
        }
    }
}

/// One compressed vector: `s` (index, coefficient) pairs.
///
/// Storage-exact representation: indices are u16 (dictionary size ≤ 65536),
/// coefficients are stored already *quantized through* the chosen precision
/// so that every downstream computation sees exactly what a bit-packed
/// implementation would see. Byte accounting (paper §3.4): 3s+2 for FP8
/// (s values + 2s indices + 2-byte CSR offset), 4s+2 for FP16.
#[derive(Clone, Debug, Default)]
pub struct CsrRow {
    pub idx: Vec<u16>,
    /// Quantized coefficient *bits*: low byte = e4m3, or full u16 = f16.
    pub coef_bits: Vec<u16>,
    pub precision_fp16: bool,
}

impl CsrRow {
    pub fn from_f32(idx: &[u16], vals: &[f32], prec: CoefPrecision) -> Self {
        debug_assert_eq!(idx.len(), vals.len());
        let coef_bits = match prec {
            CoefPrecision::Fp8 => vals.iter().map(|&v| f32_to_e4m3(v) as u16).collect(),
            CoefPrecision::Fp16 => vals.iter().map(|&v| f32_to_f16(v)).collect(),
        };
        CsrRow {
            idx: idx.to_vec(),
            coef_bits,
            precision_fp16: prec == CoefPrecision::Fp16,
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Decode coefficient `j` back to f32.
    #[inline]
    pub fn coef(&self, j: usize) -> f32 {
        if self.precision_fp16 {
            f16_to_f32(self.coef_bits[j])
        } else {
            e4m3_to_f32(self.coef_bits[j] as u8)
        }
    }

    /// Dense reconstruction into `out` [m] given the dictionary atoms
    /// (`atoms` is [N, m], atom-major — see `dict::Dictionary`).
    pub fn reconstruct(&self, atoms: &[f32], m: usize, out: &mut [f32]) {
        out.fill(0.0);
        for j in 0..self.nnz() {
            let a = &atoms[self.idx[j] as usize * m..(self.idx[j] as usize + 1) * m];
            crate::tensor::axpy(out, self.coef(j), a);
        }
    }

    /// Exact storage bytes for this row (paper §3.4 accounting):
    /// coefficient bytes + 2 bytes/index + 2-byte CSR row offset.
    pub fn bytes(&self) -> usize {
        let per = if self.precision_fp16 { 2 } else { 1 };
        self.nnz() * (per + 2) + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_bytes_formula() {
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefPrecision::Fp8);
        assert_eq!(r.bytes(), 3 * 3 + 2); // 3s + 2
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefPrecision::Fp16);
        assert_eq!(r.bytes(), 4 * 3 + 2); // 4s + 2
    }

    #[test]
    fn csr_reconstruct() {
        // atoms: identity-ish 2 atoms of dim 3
        let atoms = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]; // [2,3]
        let r = CsrRow::from_f32(&[0, 1], &[2.0, -0.5], CoefPrecision::Fp16);
        let mut out = vec![0.0; 3];
        r.reconstruct(&atoms, 3, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-3);
        assert!((out[1] + 0.5).abs() < 1e-3);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn fp8_quantization_is_visible() {
        // Storing through FP8 must round the coefficient exactly as e4m3.
        let r = CsrRow::from_f32(&[0], &[0.3], CoefPrecision::Fp8);
        assert_eq!(r.coef(0), fp8::e4m3_to_f32(fp8::f32_to_e4m3(0.3)));
        assert!((r.coef(0) - 0.3).abs() < 0.02);
    }
}
