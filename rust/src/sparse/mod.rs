//! Sparse KV-cache storage: CSR slabs (struct-of-arrays), CSR rows,
//! coefficient precision, byte accounting.
//!
//! The hot-path storage type is [`CsrSlab`]: one contiguous `idx` array,
//! one contiguous `coef_bits` array, and a row-offset array — so scoring
//! and bin-accumulation over thousands of compressed tokens are linear
//! sweeps over three flat buffers instead of a pointer chase through
//! per-token `Vec`s. [`CsrRow`] remains as the one-row interchange /
//! reference type (the property suites check the slab sweeps against a
//! row-by-row reference built from it).

pub mod fp8;
pub mod memory;

use fp8::{e4m3_lut, e4m3_to_f32, f16_to_f32, f32_to_e4m3, f32_to_f16};

/// Precision of the stored CSR coefficients.
///
/// The paper's main configuration is FP8 (E4M3); the ablations in
/// Tables 4/5/9/10 use FP16 coefficients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoefPrecision {
    Fp8,
    Fp16,
}

impl CoefPrecision {
    pub fn bytes_per_coef(self) -> usize {
        match self {
            CoefPrecision::Fp8 => 1,
            CoefPrecision::Fp16 => 2,
        }
    }
}

/// One compressed vector: `s` (index, coefficient) pairs.
///
/// Storage-exact representation: indices are u16 (dictionary size ≤ 65536),
/// coefficients are stored already *quantized through* the chosen precision
/// so that every downstream computation sees exactly what a bit-packed
/// implementation would see. Byte accounting (paper §3.4): 3s+2 for FP8
/// (s values + 2s indices + 2-byte CSR offset), 4s+2 for FP16.
#[derive(Clone, Debug, Default)]
pub struct CsrRow {
    pub idx: Vec<u16>,
    /// Quantized coefficient *bits*: low byte = e4m3, or full u16 = f16.
    pub coef_bits: Vec<u16>,
    pub precision_fp16: bool,
}

impl CsrRow {
    pub fn from_f32(idx: &[u16], vals: &[f32], prec: CoefPrecision) -> Self {
        debug_assert_eq!(idx.len(), vals.len());
        let coef_bits = match prec {
            CoefPrecision::Fp8 => vals.iter().map(|&v| f32_to_e4m3(v) as u16).collect(),
            CoefPrecision::Fp16 => vals.iter().map(|&v| f32_to_f16(v)).collect(),
        };
        CsrRow {
            idx: idx.to_vec(),
            coef_bits,
            precision_fp16: prec == CoefPrecision::Fp16,
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Decode coefficient `j` back to f32.
    #[inline]
    pub fn coef(&self, j: usize) -> f32 {
        if self.precision_fp16 {
            f16_to_f32(self.coef_bits[j])
        } else {
            e4m3_to_f32(self.coef_bits[j] as u8)
        }
    }

    /// Dense reconstruction into `out` [m] given the dictionary atoms
    /// (`atoms` is [N, m], atom-major — see `dict::Dictionary`).
    pub fn reconstruct(&self, atoms: &[f32], m: usize, out: &mut [f32]) {
        out.fill(0.0);
        for j in 0..self.nnz() {
            let a = &atoms[self.idx[j] as usize * m..(self.idx[j] as usize + 1) * m];
            crate::tensor::axpy(out, self.coef(j), a);
        }
    }

    /// Exact storage bytes for this row (paper §3.4 accounting):
    /// coefficient bytes + 2 bytes/index + 2-byte CSR row offset.
    pub fn bytes(&self) -> usize {
        let per = if self.precision_fp16 { 2 } else { 1 };
        self.nnz() * (per + 2) + 2
    }
}

/// Struct-of-arrays slab of CSR rows — the flat storage the compressed
/// attention hot path sweeps (DESIGN.md §8).
///
/// Layout: `idx`/`coef_bits` hold the concatenated (index, coefficient)
/// pairs of every row; `row_off` (length `rows + 1`, starting at 0) marks
/// each row's span, so row `r` is `idx[row_off[r]..row_off[r+1]]`.
/// Coefficients are stored *already quantized through* the slab's
/// precision, exactly like [`CsrRow`]; byte accounting is O(1) from the
/// aggregate counts (`nnz·(per+2) + rows·2`, the paper's §3.4 formula
/// summed over rows).
#[derive(Clone, Debug)]
pub struct CsrSlab {
    idx: Vec<u16>,
    /// quantized coefficient bits: low byte = e4m3, or full u16 = f16
    coef_bits: Vec<u16>,
    /// row r spans `row_off[r]..row_off[r+1]`; always starts with 0
    row_off: Vec<u32>,
    precision_fp16: bool,
}

impl Default for CsrSlab {
    fn default() -> Self {
        CsrSlab::new(CoefPrecision::Fp8)
    }
}

impl CsrSlab {
    pub fn new(prec: CoefPrecision) -> Self {
        CsrSlab {
            idx: Vec::new(),
            coef_bits: Vec::new(),
            row_off: vec![0],
            precision_fp16: prec == CoefPrecision::Fp16,
        }
    }

    pub fn precision(&self) -> CoefPrecision {
        if self.precision_fp16 {
            CoefPrecision::Fp16
        } else {
            CoefPrecision::Fp8
        }
    }

    /// Number of rows (compressed tokens) in the slab.
    #[inline]
    pub fn rows(&self) -> usize {
        self.row_off.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.rows() == 0
    }

    /// Total stored (index, coefficient) pairs across all rows.
    #[inline]
    pub fn nnz(&self) -> usize {
        *self.row_off.last().unwrap() as usize
    }

    /// Append one row, quantizing `vals` through the slab's precision.
    pub fn push_f32(&mut self, idx: &[u16], vals: &[f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        self.idx.extend_from_slice(idx);
        if self.precision_fp16 {
            self.coef_bits.extend(vals.iter().map(|&v| f32_to_f16(v)));
        } else {
            self.coef_bits.extend(vals.iter().map(|&v| f32_to_e4m3(v) as u16));
        }
        self.row_off.push(self.idx.len() as u32);
    }

    /// Append one already-quantized row (bits in this slab's precision).
    pub fn push_bits(&mut self, idx: &[u16], bits: &[u16]) {
        debug_assert_eq!(idx.len(), bits.len());
        self.idx.extend_from_slice(idx);
        self.coef_bits.extend_from_slice(bits);
        self.row_off.push(self.idx.len() as u32);
    }

    /// Move the contents out, leaving an empty slab of the same precision
    /// (the page-sealing primitive).
    pub fn take(&mut self) -> CsrSlab {
        std::mem::replace(self, CsrSlab::new(self.precision()))
    }

    /// Row `r` as (indices, quantized bits).
    pub fn row(&self, r: usize) -> (&[u16], &[u16]) {
        let (s, e) = (self.row_off[r] as usize, self.row_off[r + 1] as usize);
        (&self.idx[s..e], &self.coef_bits[s..e])
    }

    /// Decode one stored coefficient word to f32.
    #[inline]
    pub fn decode(&self, bits: u16) -> f32 {
        if self.precision_fp16 {
            f16_to_f32(bits)
        } else {
            e4m3_to_f32(bits as u8)
        }
    }

    /// Exact storage bytes (paper §3.4 summed over rows) — O(1).
    pub fn bytes(&self) -> usize {
        let per = if self.precision_fp16 { 2 } else { 1 };
        self.nnz() * (per + 2) + self.rows() * 2
    }

    /// `out[r - lo] = scale · Σ_j qd[idx[j]] · coef[j]` for rows
    /// `lo..hi` — the split-computation score sweep (`q·D` is already in
    /// `qd`). Per row the products accumulate in ascending storage order
    /// into a single f32 accumulator, identical to the row-iterator
    /// reference, so sub-range calls (pool shards) compose bitwise.
    pub fn score_rows(&self, lo: usize, hi: usize, qd: &[f32], scale: f32, out: &mut [f32]) {
        debug_assert!(hi <= self.rows() && lo <= hi);
        debug_assert!(out.len() >= hi - lo);
        let offs = &self.row_off[lo..=hi];
        if self.precision_fp16 {
            for (r, w) in offs.windows(2).enumerate() {
                let (s, e) = (w[0] as usize, w[1] as usize);
                let mut sc = 0.0f32;
                for j in s..e {
                    sc += qd[self.idx[j] as usize] * f16_to_f32(self.coef_bits[j]);
                }
                out[r] = sc * scale;
            }
        } else {
            let lut = e4m3_lut();
            for (r, w) in offs.windows(2).enumerate() {
                let (s, e) = (w[0] as usize, w[1] as usize);
                let mut sc = 0.0f32;
                for j in s..e {
                    sc += qd[self.idx[j] as usize] * lut[(self.coef_bits[j] & 0xff) as usize];
                }
                out[r] = sc * scale;
            }
        }
    }

    /// `z[idx[j]] += weights[r] · coef[j]` for every row `r` — the value
    /// side's dictionary-bin accumulation, as one linear sweep. Rows are
    /// processed in storage order with each row's pairs in ascending
    /// order, matching the row-iterator reference exactly.
    pub fn accumulate_bins(&self, weights: &[f32], z: &mut [f32]) {
        debug_assert!(weights.len() >= self.rows());
        if self.precision_fp16 {
            for (r, w) in self.row_off.windows(2).enumerate() {
                let (s, e) = (w[0] as usize, w[1] as usize);
                let wr = weights[r];
                for j in s..e {
                    z[self.idx[j] as usize] += wr * f16_to_f32(self.coef_bits[j]);
                }
            }
        } else {
            let lut = e4m3_lut();
            for (r, w) in self.row_off.windows(2).enumerate() {
                let (s, e) = (w[0] as usize, w[1] as usize);
                let wr = weights[r];
                for j in s..e {
                    z[self.idx[j] as usize] += wr * lut[(self.coef_bits[j] & 0xff) as usize];
                }
            }
        }
    }

    /// Borrow the three flat storage arrays `(idx, coef_bits, row_off)` —
    /// the serialization view used by the page store (`store::page`).
    pub fn raw_parts(&self) -> (&[u16], &[u16], &[u32]) {
        (&self.idx, &self.coef_bits, &self.row_off)
    }

    /// Rebuild a slab from its flat arrays, validating the CSR invariants
    /// (`row_off` starts at 0, is monotone, and its last entry equals the
    /// pair-array length). This is the deserialization entry point: a slab
    /// built from a well-formed page file is field-for-field identical to
    /// the slab that was serialized, so every downstream sweep is bitwise
    /// unchanged.
    pub fn from_raw_parts(
        idx: Vec<u16>,
        coef_bits: Vec<u16>,
        row_off: Vec<u32>,
        prec: CoefPrecision,
    ) -> Result<CsrSlab, String> {
        if idx.len() != coef_bits.len() {
            return Err(format!(
                "csr: idx/coef length mismatch ({} vs {})",
                idx.len(),
                coef_bits.len()
            ));
        }
        if row_off.first() != Some(&0) {
            return Err("csr: row_off must start at 0".into());
        }
        if row_off.windows(2).any(|w| w[0] > w[1]) {
            return Err("csr: row_off must be monotone non-decreasing".into());
        }
        if *row_off.last().unwrap() as usize != idx.len() {
            return Err(format!(
                "csr: row_off end {} != nnz {}",
                row_off.last().unwrap(),
                idx.len()
            ));
        }
        Ok(CsrSlab {
            idx,
            coef_bits,
            row_off,
            precision_fp16: prec == CoefPrecision::Fp16,
        })
    }

    /// Cold-tier recompression: keep at most `keep` atoms per row, dropping
    /// the lowest-|coefficient| ones first (ties broken toward keeping the
    /// earlier storage position). Survivors stay in their original storage
    /// order, so the result is a valid, smaller slab of the same precision.
    /// Lossy by construction — never applied inside the bitwise contract.
    pub fn retain_top(&self, keep: usize) -> CsrSlab {
        let mut out = CsrSlab::new(self.precision());
        let mut order: Vec<usize> = Vec::new();
        for r in 0..self.rows() {
            let (idx, bits) = self.row(r);
            if idx.len() <= keep {
                out.push_bits(idx, bits);
                continue;
            }
            order.clear();
            order.extend(0..idx.len());
            // sort by descending |coef|, ascending position on ties
            order.sort_by(|&a, &b| {
                let (ma, mb) = (self.decode(bits[a]).abs(), self.decode(bits[b]).abs());
                mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
            });
            let mut kept: Vec<usize> = order[..keep].to_vec();
            kept.sort_unstable();
            let ki: Vec<u16> = kept.iter().map(|&j| idx[j]).collect();
            let kb: Vec<u16> = kept.iter().map(|&j| bits[j]).collect();
            out.push_bits(&ki, &kb);
        }
        out
    }

    /// Cold-tier precision tightening: requantize every coefficient through
    /// `prec` (meaningful for FP16 → FP8; FP8 → FP8 is the identity since
    /// stored bits already round-trip through e4m3). Lossy for FP16 inputs
    /// — never applied inside the bitwise contract.
    pub fn to_precision(&self, prec: CoefPrecision) -> CsrSlab {
        if prec == self.precision() {
            return self.clone();
        }
        let mut out = CsrSlab::new(prec);
        for r in 0..self.rows() {
            let (idx, bits) = self.row(r);
            let vals: Vec<f32> = bits.iter().map(|&b| self.decode(b)).collect();
            out.push_f32(idx, &vals);
        }
        out
    }

    /// Materialize as per-token [`CsrRow`]s — the retained row-iterator
    /// view used by reference implementations in tests and benches.
    pub fn to_rows(&self) -> Vec<CsrRow> {
        (0..self.rows())
            .map(|r| {
                let (idx, bits) = self.row(r);
                CsrRow {
                    idx: idx.to_vec(),
                    coef_bits: bits.to_vec(),
                    precision_fp16: self.precision_fp16,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_bytes_formula() {
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefPrecision::Fp8);
        assert_eq!(r.bytes(), 3 * 3 + 2); // 3s + 2
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefPrecision::Fp16);
        assert_eq!(r.bytes(), 4 * 3 + 2); // 4s + 2
    }

    #[test]
    fn csr_reconstruct() {
        // atoms: identity-ish 2 atoms of dim 3
        let atoms = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]; // [2,3]
        let r = CsrRow::from_f32(&[0, 1], &[2.0, -0.5], CoefPrecision::Fp16);
        let mut out = vec![0.0; 3];
        r.reconstruct(&atoms, 3, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-3);
        assert!((out[1] + 0.5).abs() < 1e-3);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn slab_matches_rows_and_bytes_are_o1_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16] {
            let mut slab = CsrSlab::new(prec);
            let mut rows = Vec::new();
            let mut want_bytes = 0usize;
            for r in 0..17 {
                let nnz = r % 5; // includes empty rows
                let idx: Vec<u16> = (0..nnz as u16).map(|j| j * 3 + r as u16).collect();
                let vals = rng.normal_vec(nnz);
                slab.push_f32(&idx, &vals);
                let row = CsrRow::from_f32(&idx, &vals, prec);
                want_bytes += row.bytes();
                rows.push(row);
            }
            assert_eq!(slab.rows(), 17);
            assert_eq!(slab.bytes(), want_bytes, "O(1) bytes must equal summed row bytes");
            // per-row bit equality with the CsrRow reference
            for (r, row) in rows.iter().enumerate() {
                let (idx, bits) = slab.row(r);
                assert_eq!(idx, &row.idx[..]);
                assert_eq!(bits, &row.coef_bits[..]);
                for (j, &b) in bits.iter().enumerate() {
                    assert_eq!(slab.decode(b).to_bits(), row.coef(j).to_bits());
                }
            }
            // to_rows round-trips
            let back = slab.to_rows();
            for (a, b) in back.iter().zip(&rows) {
                assert_eq!((&a.idx, &a.coef_bits), (&b.idx, &b.coef_bits));
            }
        }
    }

    #[test]
    fn slab_sweeps_match_row_iterator_reference_bitwise() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16] {
            let n_bins = 64usize;
            let mut slab = CsrSlab::new(prec);
            for _ in 0..23 {
                let nnz = 1 + rng.below(6);
                let idx: Vec<u16> = (0..nnz).map(|_| rng.below(n_bins) as u16).collect();
                let vals = rng.normal_vec(nnz);
                slab.push_f32(&idx, &vals);
            }
            let rows = slab.to_rows();
            let qd = rng.normal_vec(n_bins);
            let scale = 0.25f32;
            // score sweep vs row-by-row reference (the pre-slab loop shape)
            let mut got = vec![0.0f32; slab.rows()];
            slab.score_rows(0, slab.rows(), &qd, scale, &mut got);
            for (ti, row) in rows.iter().enumerate() {
                let mut sc = 0.0f32;
                for j in 0..row.nnz() {
                    sc += qd[row.idx[j] as usize] * row.coef(j);
                }
                assert_eq!(got[ti].to_bits(), (sc * scale).to_bits(), "row {ti}");
            }
            // sub-range calls compose to the full sweep (pool-shard shape)
            let mut parts = vec![0.0f32; slab.rows()];
            let mid = slab.rows() / 3;
            slab.score_rows(0, mid, &qd, scale, &mut parts[..mid]);
            slab.score_rows(mid, slab.rows(), &qd, scale, &mut parts[mid..]);
            assert_eq!(parts, got);
            // bin accumulation vs reference
            let weights = rng.normal_vec(slab.rows());
            let mut z_got = vec![0.0f32; n_bins];
            slab.accumulate_bins(&weights, &mut z_got);
            let mut z_want = vec![0.0f32; n_bins];
            for (ti, row) in rows.iter().enumerate() {
                for j in 0..row.nnz() {
                    z_want[row.idx[j] as usize] += weights[ti] * row.coef(j);
                }
            }
            for (a, b) in z_got.iter().zip(&z_want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn slab_take_seals_and_resets() {
        let mut slab = CsrSlab::new(CoefPrecision::Fp16);
        slab.push_f32(&[1, 2], &[0.5, -0.5]);
        slab.push_bits(&[3], &[0x3c00]); // 1.0 in f16
        let sealed = slab.take();
        assert_eq!(sealed.rows(), 2);
        assert_eq!(sealed.nnz(), 3);
        assert_eq!(sealed.decode(sealed.row(1).1[0]), 1.0);
        assert_eq!(slab.rows(), 0);
        assert_eq!(slab.nnz(), 0);
        assert_eq!(slab.precision(), CoefPrecision::Fp16);
        assert_eq!(slab.bytes(), 0);
    }

    #[test]
    fn raw_parts_round_trip_is_field_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16] {
            let mut slab = CsrSlab::new(prec);
            for r in 0..9 {
                let nnz = r % 4;
                let idx: Vec<u16> = (0..nnz as u16).map(|j| j * 7 + r as u16).collect();
                slab.push_f32(&idx, &rng.normal_vec(nnz));
            }
            let (i, c, o) = slab.raw_parts();
            let back =
                CsrSlab::from_raw_parts(i.to_vec(), c.to_vec(), o.to_vec(), prec).unwrap();
            let (bi, bc, bo) = back.raw_parts();
            assert_eq!((i, c, o), (bi, bc, bo));
            assert_eq!(back.precision(), prec);
            assert_eq!(back.bytes(), slab.bytes());
        }
    }

    #[test]
    fn from_raw_parts_rejects_malformed_csr() {
        let prec = CoefPrecision::Fp8;
        // idx/coef length mismatch
        assert!(CsrSlab::from_raw_parts(vec![1, 2], vec![3], vec![0, 2], prec).is_err());
        // row_off not starting at 0
        assert!(CsrSlab::from_raw_parts(vec![1], vec![3], vec![1, 1], prec).is_err());
        // row_off decreasing
        assert!(CsrSlab::from_raw_parts(vec![1, 2], vec![3, 4], vec![0, 2, 1], prec).is_err());
        // row_off end != nnz
        assert!(CsrSlab::from_raw_parts(vec![1, 2], vec![3, 4], vec![0, 1], prec).is_err());
        // empty row_off
        assert!(CsrSlab::from_raw_parts(vec![], vec![], vec![], prec).is_err());
    }

    #[test]
    fn retain_top_keeps_largest_coefs_in_storage_order() {
        let mut slab = CsrSlab::new(CoefPrecision::Fp16);
        slab.push_f32(&[4, 9, 2, 7], &[0.25, -2.0, 1.0, 0.5]);
        slab.push_f32(&[1], &[3.0]); // shorter than keep: untouched
        slab.push_f32(&[], &[]); // empty row survives as empty
        let top = slab.retain_top(2);
        assert_eq!(top.rows(), 3);
        // row 0: keeps |-2.0| (idx 9) and |1.0| (idx 2), original order
        let (idx, bits) = top.row(0);
        assert_eq!(idx, &[9, 2]);
        assert_eq!(top.decode(bits[0]), -2.0);
        assert_eq!(top.decode(bits[1]), 1.0);
        let (idx, _) = top.row(1);
        assert_eq!(idx, &[1]);
        assert_eq!(top.row(2).0.len(), 0);
        assert!(top.bytes() < slab.bytes());
    }

    #[test]
    fn to_precision_requantizes_through_e4m3() {
        let mut slab = CsrSlab::new(CoefPrecision::Fp16);
        slab.push_f32(&[0, 3], &[0.3, -1.7]);
        let cold = slab.to_precision(CoefPrecision::Fp8);
        assert_eq!(cold.precision(), CoefPrecision::Fp8);
        let (idx, bits) = cold.row(0);
        assert_eq!(idx, slab.row(0).0);
        for (j, &b) in bits.iter().enumerate() {
            let want = fp8::e4m3_to_f32(fp8::f32_to_e4m3(slab.decode(slab.row(0).1[j])));
            assert_eq!(cold.decode(b).to_bits(), want.to_bits());
        }
        // identity for matching precision
        let same = slab.to_precision(CoefPrecision::Fp16);
        assert_eq!(same.raw_parts(), slab.raw_parts());
    }

    #[test]
    fn fp8_quantization_is_visible() {
        // Storing through FP8 must round the coefficient exactly as e4m3.
        let r = CsrRow::from_f32(&[0], &[0.3], CoefPrecision::Fp8);
        assert_eq!(r.coef(0), fp8::e4m3_to_f32(fp8::f32_to_e4m3(0.3)));
        assert!((r.coef(0) - 0.3).abs() < 0.02);
    }
}
