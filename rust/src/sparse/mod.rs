//! Sparse KV-cache storage: CSR slabs (struct-of-arrays), CSR rows,
//! coefficient modes, byte accounting.
//!
//! The hot-path storage type is [`CsrSlab`]: one contiguous `idx` array,
//! flat coefficient storage, and a row-offset array — so scoring and
//! bin-accumulation over thousands of compressed tokens are linear
//! sweeps over flat buffers instead of a pointer chase through
//! per-token `Vec`s. [`CsrRow`] remains as the one-row interchange /
//! reference type (the property suites check the slab sweeps against a
//! row-by-row reference built from it).
//!
//! Coefficient storage comes in three modes ([`CoefMode`]): byte-wide
//! FP8/FP16 words, and the 1-bit *sign* tier where a row's coefficients
//! are `±α` for one per-row f16 scale `α` — a packed sign bitmap plus
//! one scale word (DESIGN.md §14).

pub mod fp8;
pub mod memory;

use fp8::{e4m3_lut, e4m3_to_f32, f16_to_f32, f32_to_e4m3, f32_to_f16};

/// Storage mode of the CSR coefficients.
///
/// The paper's main configuration is FP8 (E4M3); the ablations in
/// Tables 4/5/9/10 use FP16 coefficients. `Sign` is the extreme-
/// compression tier: each coefficient collapses to one bit of sign
/// against a shared per-row f16 magnitude `α` (the mean |coefficient|,
/// folded in by the encoder's sign-finalize pass — `omp::sign_finalize`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CoefMode {
    #[default]
    Fp8,
    Fp16,
    Sign,
}

/// Former name of [`CoefMode`], kept so `precision`-era call sites and
/// configs keep compiling unchanged.
pub type CoefPrecision = CoefMode;

impl CoefMode {
    /// Bytes per stored coefficient *word* for the byte-wide modes.
    /// `Sign` packs bits, not bytes, and returns 0 here — its exact
    /// accounting lives in [`CsrRow::bytes`]/[`CsrSlab::bytes`].
    pub fn bytes_per_coef(self) -> usize {
        match self {
            CoefMode::Fp8 => 1,
            CoefMode::Fp16 => 2,
            CoefMode::Sign => 0,
        }
    }

    /// Stored bits per coefficient, counting the sign tier's packed
    /// bitmap byte (so s=4 rows pay 2 bits/coef, s≥8 rows 1 bit/coef);
    /// the per-row scale word is row overhead, like the CSR offset.
    pub fn bits_per_coef(self, s: usize) -> f64 {
        match self {
            CoefMode::Fp8 => 8.0,
            CoefMode::Fp16 => 16.0,
            CoefMode::Sign => {
                if s == 0 {
                    0.0
                } else {
                    8.0 * s.div_ceil(8) as f64 / s as f64
                }
            }
        }
    }

    /// Parse a CLI/env spelling (`fp8` | `fp16` | `sign`). This is the
    /// one spelling table shared by `--coef-mode`, `LEXICO_COEF_MODE`
    /// and the method-spec `sign`/`fp16` flags.
    pub fn parse(s: &str) -> Option<CoefMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fp8" => Some(CoefMode::Fp8),
            "fp16" => Some(CoefMode::Fp16),
            "sign" => Some(CoefMode::Sign),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CoefMode::Fp8 => "fp8",
            CoefMode::Fp16 => "fp16",
            CoefMode::Sign => "sign",
        }
    }
}

/// The shared per-row sign-tier scale: f16(mean |v|). Computed with an
/// ascending-order f32 sum so it is deterministic, and idempotent — a
/// row already holding `±α` values re-derives exactly the same bits
/// (the n-fold sum of one f16 value and the division by n are both
/// exact in f32, see `omp::sign_finalize`).
fn sign_alpha_bits(vals: &[f32]) -> u16 {
    if vals.is_empty() {
        return 0;
    }
    let mut sum = 0.0f32;
    for &v in vals {
        sum += v.abs();
    }
    f32_to_f16(sum / vals.len() as f32)
}

/// One compressed vector: `s` (index, coefficient) pairs.
///
/// Storage-exact representation: indices are u16 (dictionary size ≤ 65536),
/// coefficients are stored already *quantized through* the chosen mode
/// so that every downstream computation sees exactly what a bit-packed
/// implementation would see. Byte accounting (paper §3.4): 3s+2 for FP8
/// (s values + 2s indices + 2-byte CSR offset), 4s+2 for FP16, and
/// 2s + ⌈s/8⌉ + 4 for the sign tier (2s indices + the packed sign
/// bitmap + 2-byte offset + 2-byte f16 row scale).
#[derive(Clone, Debug, Default)]
pub struct CsrRow {
    pub idx: Vec<u16>,
    /// Fp8/Fp16: quantized coefficient *bits* (low byte = e4m3, or full
    /// u16 = f16). Sign: one word per pair, 0 = `+α`, 1 = `−α`.
    pub coef_bits: Vec<u16>,
    /// Sign mode only: the shared row magnitude `α` as f16 bits.
    pub scale_bits: u16,
    pub mode: CoefMode,
}

impl CsrRow {
    pub fn from_f32(idx: &[u16], vals: &[f32], mode: CoefMode) -> Self {
        debug_assert_eq!(idx.len(), vals.len());
        let mut scale_bits = 0u16;
        let coef_bits = match mode {
            CoefMode::Fp8 => vals.iter().map(|&v| f32_to_e4m3(v) as u16).collect(),
            CoefMode::Fp16 => vals.iter().map(|&v| f32_to_f16(v)).collect(),
            CoefMode::Sign => {
                scale_bits = sign_alpha_bits(vals);
                vals.iter().map(|&v| v.is_sign_negative() as u16).collect()
            }
        };
        CsrRow {
            idx: idx.to_vec(),
            coef_bits,
            scale_bits,
            mode,
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Decode coefficient `j` back to f32.
    #[inline]
    pub fn coef(&self, j: usize) -> f32 {
        match self.mode {
            CoefMode::Fp16 => f16_to_f32(self.coef_bits[j]),
            CoefMode::Fp8 => e4m3_to_f32(self.coef_bits[j] as u8),
            CoefMode::Sign => {
                let a = f16_to_f32(self.scale_bits);
                if self.coef_bits[j] != 0 {
                    -a
                } else {
                    a
                }
            }
        }
    }

    /// Dense reconstruction into `out` [m] given the dictionary atoms
    /// (`atoms` is [N, m], atom-major — see `dict::Dictionary`).
    pub fn reconstruct(&self, atoms: &[f32], m: usize, out: &mut [f32]) {
        out.fill(0.0);
        for j in 0..self.nnz() {
            let a = &atoms[self.idx[j] as usize * m..(self.idx[j] as usize + 1) * m];
            crate::tensor::axpy(out, self.coef(j), a);
        }
    }

    /// Exact storage bytes for this row (paper §3.4 accounting): the
    /// mode's coefficient payload + 2 bytes/index + 2-byte CSR row
    /// offset (+ the 2-byte row scale in sign mode).
    pub fn bytes(&self) -> usize {
        let s = self.nnz();
        match self.mode {
            CoefMode::Fp8 => s * 3 + 2,
            CoefMode::Fp16 => s * 4 + 2,
            CoefMode::Sign => s * 2 + s.div_ceil(8) + 4,
        }
    }
}

/// Struct-of-arrays slab of CSR rows — the flat storage the compressed
/// attention hot path sweeps (DESIGN.md §8).
///
/// Layout: `idx` holds the concatenated indices of every row; `row_off`
/// (length `rows + 1`, starting at 0) marks each row's span, so row `r`
/// is `idx[row_off[r]..row_off[r+1]]`. In the byte-wide modes the
/// coefficients sit in `coef_bits`, parallel to `idx`. In sign mode
/// `coef_bits` stays empty: each row owns a byte-aligned span of the
/// packed `signs` bitmap (bit j of the row = sign of its j-th pair,
/// 1 = negative, tracked by `sign_off`) plus one f16 `row_scale` word.
/// Coefficients are stored *already quantized through* the slab's mode,
/// exactly like [`CsrRow`]; byte accounting is O(1) from the aggregate
/// counts and equals the paper's §3.4 formula summed over rows.
#[derive(Clone, Debug)]
pub struct CsrSlab {
    idx: Vec<u16>,
    /// byte-wide modes: quantized coefficient bits (low byte = e4m3, or
    /// full u16 = f16); empty in sign mode
    coef_bits: Vec<u16>,
    /// sign mode: packed per-row byte-aligned sign bitmaps
    signs: Vec<u8>,
    /// sign mode: row r's bitmap spans `signs[sign_off[r]..sign_off[r+1]]`
    sign_off: Vec<u32>,
    /// sign mode: per-row shared magnitude `α` as f16 bits
    row_scale: Vec<u16>,
    /// row r spans `row_off[r]..row_off[r+1]`; always starts with 0
    row_off: Vec<u32>,
    mode: CoefMode,
}

impl Default for CsrSlab {
    fn default() -> Self {
        CsrSlab::new(CoefMode::Fp8)
    }
}

fn validate_row_off(row_off: &[u32], nnz: usize) -> Result<(), String> {
    if row_off.first() != Some(&0) {
        return Err("csr: row_off must start at 0".into());
    }
    if row_off.windows(2).any(|w| w[0] > w[1]) {
        return Err("csr: row_off must be monotone non-decreasing".into());
    }
    if *row_off.last().unwrap() as usize != nnz {
        return Err(format!(
            "csr: row_off end {} != nnz {}",
            row_off.last().unwrap(),
            nnz
        ));
    }
    Ok(())
}

impl CsrSlab {
    pub fn new(mode: CoefMode) -> Self {
        CsrSlab {
            idx: Vec::new(),
            coef_bits: Vec::new(),
            signs: Vec::new(),
            sign_off: vec![0],
            row_scale: Vec::new(),
            row_off: vec![0],
            mode,
        }
    }

    pub fn precision(&self) -> CoefMode {
        self.mode
    }

    /// Number of rows (compressed tokens) in the slab.
    #[inline]
    pub fn rows(&self) -> usize {
        self.row_off.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.rows() == 0
    }

    /// Total stored (index, coefficient) pairs across all rows.
    #[inline]
    pub fn nnz(&self) -> usize {
        *self.row_off.last().unwrap() as usize
    }

    /// Append one row, quantizing `vals` through the slab's mode. In
    /// sign mode this derives the row scale `α = f16(mean |v|)` and
    /// packs one sign bit per pair — idempotent on already-finalized
    /// `±α` rows (same `α` bits re-derived, same bitmap).
    pub fn push_f32(&mut self, idx: &[u16], vals: &[f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        self.idx.extend_from_slice(idx);
        match self.mode {
            CoefMode::Fp16 => self.coef_bits.extend(vals.iter().map(|&v| f32_to_f16(v))),
            CoefMode::Fp8 => self
                .coef_bits
                .extend(vals.iter().map(|&v| f32_to_e4m3(v) as u16)),
            CoefMode::Sign => {
                self.row_scale.push(sign_alpha_bits(vals));
                let base = self.signs.len();
                self.signs.resize(base + vals.len().div_ceil(8), 0u8);
                for (j, &v) in vals.iter().enumerate() {
                    if v.is_sign_negative() {
                        self.signs[base + j / 8] |= 1 << (j % 8);
                    }
                }
                self.sign_off.push(self.signs.len() as u32);
            }
        }
        self.row_off.push(self.idx.len() as u32);
    }

    /// Append one already-quantized row (bits in this slab's byte-wide
    /// mode). Sign rows carry per-row state and go through
    /// [`Self::push_f32`] or [`Self::push_sign_row`].
    pub fn push_bits(&mut self, idx: &[u16], bits: &[u16]) {
        assert!(
            self.mode != CoefMode::Sign,
            "push_bits is for byte-wide coefficient modes"
        );
        debug_assert_eq!(idx.len(), bits.len());
        self.idx.extend_from_slice(idx);
        self.coef_bits.extend_from_slice(bits);
        self.row_off.push(self.idx.len() as u32);
    }

    /// Append one already-finalized sign row: indices, the row's `α`
    /// bits, and one negative-flag per pair.
    pub fn push_sign_row(&mut self, idx: &[u16], scale_bits: u16, neg: &[bool]) {
        assert!(self.mode == CoefMode::Sign, "push_sign_row needs a sign slab");
        debug_assert_eq!(idx.len(), neg.len());
        self.idx.extend_from_slice(idx);
        self.row_scale.push(scale_bits);
        let base = self.signs.len();
        self.signs.resize(base + idx.len().div_ceil(8), 0u8);
        for (j, &n) in neg.iter().enumerate() {
            if n {
                self.signs[base + j / 8] |= 1 << (j % 8);
            }
        }
        self.sign_off.push(self.signs.len() as u32);
        self.row_off.push(self.idx.len() as u32);
    }

    /// Move the contents out, leaving an empty slab of the same mode
    /// (the page-sealing primitive).
    pub fn take(&mut self) -> CsrSlab {
        std::mem::replace(self, CsrSlab::new(self.mode))
    }

    /// Row `r` as (indices, quantized bits). In sign mode the bits
    /// slice is empty — use [`Self::sign_row`] for the bitmap view.
    pub fn row(&self, r: usize) -> (&[u16], &[u16]) {
        let (s, e) = (self.row_off[r] as usize, self.row_off[r + 1] as usize);
        match self.mode {
            CoefMode::Sign => (&self.idx[s..e], &self.coef_bits[..]),
            _ => (&self.idx[s..e], &self.coef_bits[s..e]),
        }
    }

    /// Sign-mode row view: (indices, byte-aligned sign bitmap, `α` bits).
    pub fn sign_row(&self, r: usize) -> (&[u16], &[u8], u16) {
        assert!(self.mode == CoefMode::Sign, "sign_row needs a sign slab");
        let (s, e) = (self.row_off[r] as usize, self.row_off[r + 1] as usize);
        let (bs, be) = (self.sign_off[r] as usize, self.sign_off[r + 1] as usize);
        (&self.idx[s..e], &self.signs[bs..be], self.row_scale[r])
    }

    /// Decode one stored coefficient word to f32 (byte-wide modes).
    #[inline]
    pub fn decode(&self, bits: u16) -> f32 {
        match self.mode {
            CoefMode::Fp16 => f16_to_f32(bits),
            CoefMode::Fp8 => e4m3_to_f32(bits as u8),
            CoefMode::Sign => unreachable!("sign slabs decode rows via sign_row/row_values"),
        }
    }

    /// Decode all of row `r`'s coefficients into `out` (any mode).
    pub fn row_values(&self, r: usize, out: &mut Vec<f32>) {
        out.clear();
        let (s, e) = (self.row_off[r] as usize, self.row_off[r + 1] as usize);
        match self.mode {
            CoefMode::Fp16 => out.extend(self.coef_bits[s..e].iter().map(|&b| f16_to_f32(b))),
            CoefMode::Fp8 => {
                out.extend(self.coef_bits[s..e].iter().map(|&b| e4m3_to_f32(b as u8)))
            }
            CoefMode::Sign => {
                let sb = self.sign_off[r] as usize;
                let alpha = f16_to_f32(self.row_scale[r]);
                for j in 0..e - s {
                    let neg = self.signs[sb + j / 8] >> (j % 8) & 1 != 0;
                    out.push(if neg { -alpha } else { alpha });
                }
            }
        }
    }

    /// Exact storage bytes (paper §3.4 summed over rows) — O(1).
    pub fn bytes(&self) -> usize {
        match self.mode {
            CoefMode::Fp8 => self.nnz() * 3 + self.rows() * 2,
            CoefMode::Fp16 => self.nnz() * 4 + self.rows() * 2,
            // 2s idx + bitmap bytes + (2 offset + 2 scale) per row
            CoefMode::Sign => self.nnz() * 2 + self.signs.len() + self.rows() * 4,
        }
    }

    /// `out[r - lo] = scale · Σ_j qd[idx[j]] · coef[j]` for rows
    /// `lo..hi` — the split-computation score sweep (`q·D` is already in
    /// `qd`). Per row the products accumulate in ascending storage order
    /// into a single f32 accumulator, identical to the row-iterator
    /// reference, so sub-range calls (pool shards) compose bitwise. The
    /// sign tier factors the shared magnitude out of the loop — signed
    /// sums of `qd` gathers, then `(sum · α) · scale` — and IEEE
    /// negation is exact, so this too is one canonical reduction order.
    pub fn score_rows(&self, lo: usize, hi: usize, qd: &[f32], scale: f32, out: &mut [f32]) {
        debug_assert!(hi <= self.rows() && lo <= hi);
        debug_assert!(out.len() >= hi - lo);
        let offs = &self.row_off[lo..=hi];
        match self.mode {
            CoefMode::Fp16 => {
                for (r, w) in offs.windows(2).enumerate() {
                    let (s, e) = (w[0] as usize, w[1] as usize);
                    let mut sc = 0.0f32;
                    for j in s..e {
                        sc += qd[self.idx[j] as usize] * f16_to_f32(self.coef_bits[j]);
                    }
                    out[r] = sc * scale;
                }
            }
            CoefMode::Fp8 => {
                let lut = e4m3_lut();
                for (r, w) in offs.windows(2).enumerate() {
                    let (s, e) = (w[0] as usize, w[1] as usize);
                    let mut sc = 0.0f32;
                    for j in s..e {
                        sc += qd[self.idx[j] as usize] * lut[(self.coef_bits[j] & 0xff) as usize];
                    }
                    out[r] = sc * scale;
                }
            }
            CoefMode::Sign => {
                for (r, w) in offs.windows(2).enumerate() {
                    let (s, e) = (w[0] as usize, w[1] as usize);
                    let sb = self.sign_off[lo + r] as usize;
                    let mut sc = 0.0f32;
                    for j in s..e {
                        let q = qd[self.idx[j] as usize];
                        if self.signs[sb + (j - s) / 8] >> ((j - s) % 8) & 1 != 0 {
                            sc -= q;
                        } else {
                            sc += q;
                        }
                    }
                    let alpha = f16_to_f32(self.row_scale[lo + r]);
                    out[r] = (sc * alpha) * scale;
                }
            }
        }
    }

    /// `z[idx[j]] += weights[r] · coef[j]` for every row `r` — the value
    /// side's dictionary-bin accumulation, as one linear sweep. Rows are
    /// processed in storage order with each row's pairs in ascending
    /// order, matching the row-iterator reference exactly. The sign tier
    /// folds the magnitude once per row (`wrα = weights[r] · α`) and
    /// adds/subtracts that product per bin — the same value every
    /// per-element path would produce, in the same order.
    pub fn accumulate_bins(&self, weights: &[f32], z: &mut [f32]) {
        debug_assert!(weights.len() >= self.rows());
        match self.mode {
            CoefMode::Fp16 => {
                for (r, w) in self.row_off.windows(2).enumerate() {
                    let (s, e) = (w[0] as usize, w[1] as usize);
                    let wr = weights[r];
                    for j in s..e {
                        z[self.idx[j] as usize] += wr * f16_to_f32(self.coef_bits[j]);
                    }
                }
            }
            CoefMode::Fp8 => {
                let lut = e4m3_lut();
                for (r, w) in self.row_off.windows(2).enumerate() {
                    let (s, e) = (w[0] as usize, w[1] as usize);
                    let wr = weights[r];
                    for j in s..e {
                        z[self.idx[j] as usize] += wr * lut[(self.coef_bits[j] & 0xff) as usize];
                    }
                }
            }
            CoefMode::Sign => {
                for (r, w) in self.row_off.windows(2).enumerate() {
                    let (s, e) = (w[0] as usize, w[1] as usize);
                    let sb = self.sign_off[r] as usize;
                    let wra = weights[r] * f16_to_f32(self.row_scale[r]);
                    for j in s..e {
                        let bin = self.idx[j] as usize;
                        if self.signs[sb + (j - s) / 8] >> ((j - s) % 8) & 1 != 0 {
                            z[bin] -= wra;
                        } else {
                            z[bin] += wra;
                        }
                    }
                }
            }
        }
    }

    /// Borrow the three flat storage arrays `(idx, coef_bits, row_off)` —
    /// the serialization view used by the page store (`store::page`) for
    /// the byte-wide modes.
    pub fn raw_parts(&self) -> (&[u16], &[u16], &[u32]) {
        (&self.idx, &self.coef_bits, &self.row_off)
    }

    /// Sign-mode serialization view: `(idx, signs, row_scale, row_off)`.
    pub fn sign_parts(&self) -> (&[u16], &[u8], &[u16], &[u32]) {
        (&self.idx, &self.signs, &self.row_scale, &self.row_off)
    }

    /// Rebuild a byte-wide slab from its flat arrays, validating the CSR
    /// invariants (`row_off` starts at 0, is monotone, and its last entry
    /// equals the pair-array length). This is the deserialization entry
    /// point: a slab built from a well-formed page file is
    /// field-for-field identical to the slab that was serialized, so
    /// every downstream sweep is bitwise unchanged.
    pub fn from_raw_parts(
        idx: Vec<u16>,
        coef_bits: Vec<u16>,
        row_off: Vec<u32>,
        mode: CoefMode,
    ) -> Result<CsrSlab, String> {
        if mode == CoefMode::Sign {
            return Err("csr: sign slabs deserialize via from_sign_parts".into());
        }
        if idx.len() != coef_bits.len() {
            return Err(format!(
                "csr: idx/coef length mismatch ({} vs {})",
                idx.len(),
                coef_bits.len()
            ));
        }
        if row_off.is_empty() {
            return Err("csr: row_off must start at 0".into());
        }
        validate_row_off(&row_off, idx.len())?;
        Ok(CsrSlab {
            idx,
            coef_bits,
            signs: Vec::new(),
            sign_off: vec![0],
            row_scale: Vec::new(),
            row_off,
            mode,
        })
    }

    /// Rebuild a sign slab from its flat arrays. `sign_off` is derived
    /// from `row_off` (each row's bitmap is byte-aligned), so a
    /// round-trip through [`Self::sign_parts`] is field-for-field exact.
    pub fn from_sign_parts(
        idx: Vec<u16>,
        signs: Vec<u8>,
        row_scale: Vec<u16>,
        row_off: Vec<u32>,
    ) -> Result<CsrSlab, String> {
        if row_off.is_empty() {
            return Err("csr: row_off must start at 0".into());
        }
        validate_row_off(&row_off, idx.len())?;
        let rows = row_off.len() - 1;
        if row_scale.len() != rows {
            return Err(format!(
                "csr: {} row scales for {} rows",
                row_scale.len(),
                rows
            ));
        }
        let mut sign_off = Vec::with_capacity(rows + 1);
        sign_off.push(0u32);
        let mut total = 0usize;
        for w in row_off.windows(2) {
            total += ((w[1] - w[0]) as usize).div_ceil(8);
            sign_off.push(total as u32);
        }
        if signs.len() != total {
            return Err(format!(
                "csr: sign bitmap is {} bytes, expected {}",
                signs.len(),
                total
            ));
        }
        Ok(CsrSlab {
            idx,
            coef_bits: Vec::new(),
            signs,
            sign_off,
            row_scale,
            row_off,
            mode: CoefMode::Sign,
        })
    }

    /// Cold-tier recompression: keep at most `keep` atoms per row, dropping
    /// the lowest-|coefficient| ones first (ties broken toward keeping the
    /// earlier storage position). Survivors stay in their original storage
    /// order, so the result is a valid, smaller slab of the same mode.
    /// In sign mode every magnitude is the row's shared `α`, so the
    /// tie-break keeps the earliest `keep` positions and the scale is
    /// preserved. Lossy by construction — never applied inside the
    /// bitwise contract.
    pub fn retain_top(&self, keep: usize) -> CsrSlab {
        let mut out = CsrSlab::new(self.mode);
        if self.mode == CoefMode::Sign {
            for r in 0..self.rows() {
                let (idx, bitmap, ab) = self.sign_row(r);
                let take = idx.len().min(keep);
                let neg: Vec<bool> =
                    (0..take).map(|j| bitmap[j / 8] >> (j % 8) & 1 != 0).collect();
                out.push_sign_row(&idx[..take], ab, &neg);
            }
            return out;
        }
        let mut order: Vec<usize> = Vec::new();
        for r in 0..self.rows() {
            let (idx, bits) = self.row(r);
            if idx.len() <= keep {
                out.push_bits(idx, bits);
                continue;
            }
            order.clear();
            order.extend(0..idx.len());
            // sort by descending |coef|, ascending position on ties
            order.sort_by(|&a, &b| {
                let (ma, mb) = (self.decode(bits[a]).abs(), self.decode(bits[b]).abs());
                mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
            });
            let mut kept: Vec<usize> = order[..keep].to_vec();
            kept.sort_unstable();
            let ki: Vec<u16> = kept.iter().map(|&j| idx[j]).collect();
            let kb: Vec<u16> = kept.iter().map(|&j| bits[j]).collect();
            out.push_bits(&ki, &kb);
        }
        out
    }

    /// Cold-tier mode conversion: requantize every coefficient through
    /// `mode` (meaningful for FP16 → FP8 tightening, or folding a
    /// byte-wide slab down to the sign tier; FP8 → FP8 is the identity
    /// since stored bits already round-trip through e4m3). Lossy across
    /// modes — never applied inside the bitwise contract.
    pub fn to_precision(&self, mode: CoefMode) -> CsrSlab {
        if mode == self.mode {
            return self.clone();
        }
        let mut out = CsrSlab::new(mode);
        let mut vals = Vec::new();
        for r in 0..self.rows() {
            self.row_values(r, &mut vals);
            let (s, e) = (self.row_off[r] as usize, self.row_off[r + 1] as usize);
            out.push_f32(&self.idx[s..e], &vals);
        }
        out
    }

    /// Materialize as per-token [`CsrRow`]s — the retained row-iterator
    /// view used by reference implementations in tests and benches.
    pub fn to_rows(&self) -> Vec<CsrRow> {
        (0..self.rows())
            .map(|r| match self.mode {
                CoefMode::Sign => {
                    let (idx, bitmap, ab) = self.sign_row(r);
                    let coef_bits = (0..idx.len())
                        .map(|j| (bitmap[j / 8] >> (j % 8) & 1) as u16)
                        .collect();
                    CsrRow {
                        idx: idx.to_vec(),
                        coef_bits,
                        scale_bits: ab,
                        mode: CoefMode::Sign,
                    }
                }
                _ => {
                    let (idx, bits) = self.row(r);
                    CsrRow {
                        idx: idx.to_vec(),
                        coef_bits: bits.to_vec(),
                        scale_bits: 0,
                        mode: self.mode,
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_bytes_formula() {
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefMode::Fp8);
        assert_eq!(r.bytes(), 3 * 3 + 2); // 3s + 2
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefMode::Fp16);
        assert_eq!(r.bytes(), 4 * 3 + 2); // 4s + 2
        let r = CsrRow::from_f32(&[1, 5, 9], &[0.5, -1.0, 2.0], CoefMode::Sign);
        assert_eq!(r.bytes(), 2 * 3 + 1 + 4); // 2s + ceil(s/8) + 4
    }

    #[test]
    fn csr_reconstruct() {
        // atoms: identity-ish 2 atoms of dim 3
        let atoms = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]; // [2,3]
        let r = CsrRow::from_f32(&[0, 1], &[2.0, -0.5], CoefMode::Fp16);
        let mut out = vec![0.0; 3];
        r.reconstruct(&atoms, 3, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-3);
        assert!((out[1] + 0.5).abs() < 1e-3);
        assert_eq!(out[2], 0.0);
    }

    #[test]
    fn slab_matches_rows_and_bytes_are_o1_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for prec in [CoefMode::Fp8, CoefMode::Fp16] {
            let mut slab = CsrSlab::new(prec);
            let mut rows = Vec::new();
            let mut want_bytes = 0usize;
            for r in 0..17 {
                let nnz = r % 5; // includes empty rows
                let idx: Vec<u16> = (0..nnz as u16).map(|j| j * 3 + r as u16).collect();
                let vals = rng.normal_vec(nnz);
                slab.push_f32(&idx, &vals);
                let row = CsrRow::from_f32(&idx, &vals, prec);
                want_bytes += row.bytes();
                rows.push(row);
            }
            assert_eq!(slab.rows(), 17);
            assert_eq!(slab.bytes(), want_bytes, "O(1) bytes must equal summed row bytes");
            // per-row bit equality with the CsrRow reference
            for (r, row) in rows.iter().enumerate() {
                let (idx, bits) = slab.row(r);
                assert_eq!(idx, &row.idx[..]);
                assert_eq!(bits, &row.coef_bits[..]);
                for (j, &b) in bits.iter().enumerate() {
                    assert_eq!(slab.decode(b).to_bits(), row.coef(j).to_bits());
                }
            }
            // to_rows round-trips
            let back = slab.to_rows();
            for (a, b) in back.iter().zip(&rows) {
                assert_eq!((&a.idx, &a.coef_bits), (&b.idx, &b.coef_bits));
            }
        }
    }

    #[test]
    fn sign_slab_matches_row_reference_and_bytes_are_o1_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(78);
        let mut slab = CsrSlab::new(CoefMode::Sign);
        let mut rows = Vec::new();
        let mut want_bytes = 0usize;
        for r in 0..21 {
            let nnz = r % 11; // spans both sides of a bitmap byte boundary
            let idx: Vec<u16> = (0..nnz as u16).map(|j| j * 3 + r as u16).collect();
            let vals = rng.normal_vec(nnz);
            slab.push_f32(&idx, &vals);
            let row = CsrRow::from_f32(&idx, &vals, CoefMode::Sign);
            want_bytes += row.bytes();
            rows.push(row);
        }
        assert_eq!(slab.rows(), 21);
        assert_eq!(slab.bytes(), want_bytes, "O(1) bytes must equal summed row bytes");
        for (r, row) in rows.iter().enumerate() {
            let (idx, bitmap, ab) = slab.sign_row(r);
            assert_eq!(idx, &row.idx[..]);
            assert_eq!(ab, row.scale_bits, "row {r} scale");
            assert_eq!(bitmap.len(), row.nnz().div_ceil(8));
            let mut vals = Vec::new();
            slab.row_values(r, &mut vals);
            for (j, &v) in vals.iter().enumerate() {
                assert_eq!(v.to_bits(), row.coef(j).to_bits(), "row {r} coef {j}");
            }
        }
        // to_rows carries mode, per-element sign words and the row scale
        let back = slab.to_rows();
        for (a, b) in back.iter().zip(&rows) {
            assert_eq!(a.mode, CoefMode::Sign);
            assert_eq!(
                (&a.idx, &a.coef_bits, a.scale_bits),
                (&b.idx, &b.coef_bits, b.scale_bits)
            );
        }
        // ≤ 2 bits per stored coefficient at the paper's operating points
        for s in [4usize, 6, 8] {
            assert!(CoefMode::Sign.bits_per_coef(s) <= 2.0 + 1e-12, "s={s}");
        }
    }

    #[test]
    fn sign_alpha_is_idempotent_bitwise() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(79);
        for n in 1..17usize {
            let vals = rng.normal_vec(n);
            let idx: Vec<u16> = (0..n as u16).collect();
            let mut slab = CsrSlab::new(CoefMode::Sign);
            slab.push_f32(&idx, &vals);
            // decode the finalized row and push it again: the re-derived
            // α bits and bitmap must be identical (the mean of n copies
            // of a f16-representable α is exact in f32)
            let mut dec = Vec::new();
            slab.row_values(0, &mut dec);
            slab.push_f32(&idx, &dec);
            let (_, b0, a0) = slab.sign_row(0);
            let (_, b1, a1) = slab.sign_row(1);
            assert_eq!(a0, a1, "n={n} scale must be stable");
            assert_eq!(b0, b1, "n={n} bitmap must be stable");
        }
    }

    #[test]
    fn slab_sweeps_match_row_iterator_reference_bitwise() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for prec in [CoefMode::Fp8, CoefMode::Fp16, CoefMode::Sign] {
            let n_bins = 64usize;
            let mut slab = CsrSlab::new(prec);
            for _ in 0..23 {
                let nnz = 1 + rng.below(6);
                let idx: Vec<u16> = (0..nnz).map(|_| rng.below(n_bins) as u16).collect();
                let vals = rng.normal_vec(nnz);
                slab.push_f32(&idx, &vals);
            }
            let rows = slab.to_rows();
            let qd = rng.normal_vec(n_bins);
            let scale = 0.25f32;
            // score sweep vs row-by-row reference (the pre-slab loop shape;
            // the sign tier's reference replays the same signed-sum-then-
            // scale order, which is the canonical order of that mode)
            let mut got = vec![0.0f32; slab.rows()];
            slab.score_rows(0, slab.rows(), &qd, scale, &mut got);
            for (ti, row) in rows.iter().enumerate() {
                let want = match prec {
                    CoefMode::Sign => {
                        let mut sc = 0.0f32;
                        for j in 0..row.nnz() {
                            let q = qd[row.idx[j] as usize];
                            if row.coef_bits[j] != 0 {
                                sc -= q;
                            } else {
                                sc += q;
                            }
                        }
                        (sc * f16_to_f32(row.scale_bits)) * scale
                    }
                    _ => {
                        let mut sc = 0.0f32;
                        for j in 0..row.nnz() {
                            sc += qd[row.idx[j] as usize] * row.coef(j);
                        }
                        sc * scale
                    }
                };
                assert_eq!(got[ti].to_bits(), want.to_bits(), "row {ti}");
            }
            // sub-range calls compose to the full sweep (pool-shard shape)
            let mut parts = vec![0.0f32; slab.rows()];
            let mid = slab.rows() / 3;
            slab.score_rows(0, mid, &qd, scale, &mut parts[..mid]);
            slab.score_rows(mid, slab.rows(), &qd, scale, &mut parts[mid..]);
            assert_eq!(parts, got);
            // bin accumulation vs reference
            let weights = rng.normal_vec(slab.rows());
            let mut z_got = vec![0.0f32; n_bins];
            slab.accumulate_bins(&weights, &mut z_got);
            let mut z_want = vec![0.0f32; n_bins];
            for (ti, row) in rows.iter().enumerate() {
                match prec {
                    CoefMode::Sign => {
                        let wra = weights[ti] * f16_to_f32(row.scale_bits);
                        for j in 0..row.nnz() {
                            if row.coef_bits[j] != 0 {
                                z_want[row.idx[j] as usize] -= wra;
                            } else {
                                z_want[row.idx[j] as usize] += wra;
                            }
                        }
                    }
                    _ => {
                        for j in 0..row.nnz() {
                            z_want[row.idx[j] as usize] += weights[ti] * row.coef(j);
                        }
                    }
                }
            }
            for (a, b) in z_got.iter().zip(&z_want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn slab_take_seals_and_resets() {
        let mut slab = CsrSlab::new(CoefMode::Fp16);
        slab.push_f32(&[1, 2], &[0.5, -0.5]);
        slab.push_bits(&[3], &[0x3c00]); // 1.0 in f16
        let sealed = slab.take();
        assert_eq!(sealed.rows(), 2);
        assert_eq!(sealed.nnz(), 3);
        assert_eq!(sealed.decode(sealed.row(1).1[0]), 1.0);
        assert_eq!(slab.rows(), 0);
        assert_eq!(slab.nnz(), 0);
        assert_eq!(slab.precision(), CoefMode::Fp16);
        assert_eq!(slab.bytes(), 0);
        // same for the sign tier: take() resets bitmap + scale state too
        let mut slab = CsrSlab::new(CoefMode::Sign);
        slab.push_f32(&[1, 2, 3], &[0.5, -0.5, 0.25]);
        let sealed = slab.take();
        assert_eq!(sealed.rows(), 1);
        assert_eq!(sealed.sign_row(0).1.len(), 1);
        assert_eq!(slab.precision(), CoefMode::Sign);
        assert_eq!((slab.rows(), slab.nnz(), slab.bytes()), (0, 0, 0));
    }

    #[test]
    fn raw_parts_round_trip_is_field_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        for prec in [CoefMode::Fp8, CoefMode::Fp16] {
            let mut slab = CsrSlab::new(prec);
            for r in 0..9 {
                let nnz = r % 4;
                let idx: Vec<u16> = (0..nnz as u16).map(|j| j * 7 + r as u16).collect();
                slab.push_f32(&idx, &rng.normal_vec(nnz));
            }
            let (i, c, o) = slab.raw_parts();
            let back =
                CsrSlab::from_raw_parts(i.to_vec(), c.to_vec(), o.to_vec(), prec).unwrap();
            let (bi, bc, bo) = back.raw_parts();
            assert_eq!((i, c, o), (bi, bc, bo));
            assert_eq!(back.precision(), prec);
            assert_eq!(back.bytes(), slab.bytes());
        }
    }

    #[test]
    fn sign_parts_round_trip_is_field_exact() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(32);
        let mut slab = CsrSlab::new(CoefMode::Sign);
        for r in 0..13 {
            let nnz = r % 10;
            let idx: Vec<u16> = (0..nnz as u16).map(|j| j * 5 + r as u16).collect();
            slab.push_f32(&idx, &rng.normal_vec(nnz));
        }
        let (i, s, sc, o) = slab.sign_parts();
        let back =
            CsrSlab::from_sign_parts(i.to_vec(), s.to_vec(), sc.to_vec(), o.to_vec()).unwrap();
        let (bi, bs, bsc, bo) = back.sign_parts();
        assert_eq!((i, s, sc, o), (bi, bs, bsc, bo));
        assert_eq!(back.precision(), CoefMode::Sign);
        assert_eq!(back.bytes(), slab.bytes());
        // and every sweep input is identical, row by row
        for r in 0..slab.rows() {
            assert_eq!(slab.sign_row(r), back.sign_row(r));
        }
    }

    #[test]
    fn from_raw_parts_rejects_malformed_csr() {
        let prec = CoefMode::Fp8;
        // idx/coef length mismatch
        assert!(CsrSlab::from_raw_parts(vec![1, 2], vec![3], vec![0, 2], prec).is_err());
        // row_off not starting at 0
        assert!(CsrSlab::from_raw_parts(vec![1], vec![3], vec![1, 1], prec).is_err());
        // row_off decreasing
        assert!(CsrSlab::from_raw_parts(vec![1, 2], vec![3, 4], vec![0, 2, 1], prec).is_err());
        // row_off end != nnz
        assert!(CsrSlab::from_raw_parts(vec![1, 2], vec![3, 4], vec![0, 1], prec).is_err());
        // empty row_off
        assert!(CsrSlab::from_raw_parts(vec![], vec![], vec![], prec).is_err());
        // sign slabs must go through from_sign_parts
        assert!(CsrSlab::from_raw_parts(vec![1], vec![0], vec![0, 1], CoefMode::Sign).is_err());
    }

    #[test]
    fn from_sign_parts_rejects_malformed_slabs() {
        // scale count != rows
        assert!(CsrSlab::from_sign_parts(vec![1], vec![0], vec![], vec![0, 1]).is_err());
        // bitmap byte count != sum of per-row ceil(nnz/8)
        assert!(CsrSlab::from_sign_parts(vec![1], vec![0, 0], vec![1], vec![0, 1]).is_err());
        assert!(CsrSlab::from_sign_parts(vec![1], vec![], vec![1], vec![0, 1]).is_err());
        // row_off invariants still enforced
        assert!(CsrSlab::from_sign_parts(vec![1], vec![0], vec![1], vec![1, 1]).is_err());
        assert!(CsrSlab::from_sign_parts(vec![], vec![], vec![], vec![]).is_err());
    }

    #[test]
    fn retain_top_keeps_largest_coefs_in_storage_order() {
        let mut slab = CsrSlab::new(CoefMode::Fp16);
        slab.push_f32(&[4, 9, 2, 7], &[0.25, -2.0, 1.0, 0.5]);
        slab.push_f32(&[1], &[3.0]); // shorter than keep: untouched
        slab.push_f32(&[], &[]); // empty row survives as empty
        let top = slab.retain_top(2);
        assert_eq!(top.rows(), 3);
        // row 0: keeps |-2.0| (idx 9) and |1.0| (idx 2), original order
        let (idx, bits) = top.row(0);
        assert_eq!(idx, &[9, 2]);
        assert_eq!(top.decode(bits[0]), -2.0);
        assert_eq!(top.decode(bits[1]), 1.0);
        let (idx, _) = top.row(1);
        assert_eq!(idx, &[1]);
        assert_eq!(top.row(2).0.len(), 0);
        assert!(top.bytes() < slab.bytes());
    }

    #[test]
    fn retain_top_on_sign_slab_keeps_scale_and_early_positions() {
        let mut slab = CsrSlab::new(CoefMode::Sign);
        slab.push_f32(&[4, 9, 2, 7], &[0.25, -2.0, 1.0, -0.5]);
        slab.push_f32(&[1], &[3.0]);
        let top = slab.retain_top(2);
        assert_eq!(top.rows(), 2);
        let (idx, bitmap, ab) = top.sign_row(0);
        // all magnitudes are the shared α: tie-break keeps positions 0, 1
        assert_eq!(idx, &[4, 9]);
        assert_eq!(ab, slab.sign_row(0).2, "row scale survives recompression");
        assert_eq!(bitmap[0] & 1, 0); // +0.25 stayed positive
        assert_eq!(bitmap[0] >> 1 & 1, 1); // -2.0 stayed negative
        assert!(top.bytes() < slab.bytes());
    }

    #[test]
    fn to_precision_requantizes_through_e4m3() {
        let mut slab = CsrSlab::new(CoefMode::Fp16);
        slab.push_f32(&[0, 3], &[0.3, -1.7]);
        let cold = slab.to_precision(CoefMode::Fp8);
        assert_eq!(cold.precision(), CoefMode::Fp8);
        let (idx, bits) = cold.row(0);
        assert_eq!(idx, slab.row(0).0);
        for (j, &b) in bits.iter().enumerate() {
            let want = fp8::e4m3_to_f32(fp8::f32_to_e4m3(slab.decode(slab.row(0).1[j])));
            assert_eq!(cold.decode(b).to_bits(), want.to_bits());
        }
        // identity for matching precision
        let same = slab.to_precision(CoefMode::Fp16);
        assert_eq!(same.raw_parts(), slab.raw_parts());
    }

    #[test]
    fn to_precision_folds_byte_modes_down_to_sign_and_back() {
        let mut slab = CsrSlab::new(CoefMode::Fp16);
        slab.push_f32(&[0, 3, 5], &[0.3, -1.7, 0.9]);
        let sign = slab.to_precision(CoefMode::Sign);
        assert_eq!(sign.precision(), CoefMode::Sign);
        assert_eq!(sign.row(0).0, slab.row(0).0);
        // α = f16(mean |fp16(v)|), signs preserved
        let vals: Vec<f32> = (0..3).map(|j| slab.decode(slab.row(0).1[j])).collect();
        let want = f16_to_f32(sign_alpha_bits(&vals));
        let mut dec = Vec::new();
        sign.row_values(0, &mut dec);
        assert_eq!(dec[0].to_bits(), want.to_bits());
        assert_eq!(dec[1].to_bits(), (-want).to_bits());
        assert_eq!(dec[2].to_bits(), want.to_bits());
        assert!(sign.bytes() < slab.bytes());
        // sign → fp16 widens the ±α values losslessly (α is f16)
        let wide = sign.to_precision(CoefMode::Fp16);
        let mut w = Vec::new();
        wide.row_values(0, &mut w);
        assert_eq!(w, dec);
    }

    #[test]
    fn fp8_quantization_is_visible() {
        // Storing through FP8 must round the coefficient exactly as e4m3.
        let r = CsrRow::from_f32(&[0], &[0.3], CoefMode::Fp8);
        assert_eq!(r.coef(0), fp8::e4m3_to_f32(fp8::f32_to_e4m3(0.3)));
        assert!((r.coef(0) - 0.3).abs() < 0.02);
    }
}
