//! KV-cache memory accounting (paper §3.4 and §4 "KV size" columns).
//!
//! All methods are charged against the same baseline: the full cache in
//! FP16, `2·m` bytes per vector, two vectors (K and V) per token per kv
//! head per layer. "KV size %" = compressed bytes / baseline bytes at the
//! end of generation, exactly as the paper reports it.

use super::CoefMode;

/// Bytes of one full-precision (FP16) K or V vector.
pub fn full_vector_bytes(head_dim: usize) -> usize {
    2 * head_dim
}

/// Paper formula: CSR row of sparsity `s` with FP8 coefficients costs
/// `3s+2` bytes (s values, 2s u16 indices, 2-byte offset); FP16 costs
/// `4s+2`; the sign tier costs `2s + ⌈s/8⌉ + 4` (2s indices, the packed
/// sign bitmap, 2-byte offset, 2-byte f16 row scale).
pub fn csr_row_bytes(s: usize, mode: CoefMode) -> usize {
    match mode {
        CoefMode::Fp8 => 3 * s + 2,
        CoefMode::Fp16 => 4 * s + 2,
        CoefMode::Sign => 2 * s + s.div_ceil(8) + 4,
    }
}

/// KV-size ratio of a pure-CSR cache (no buffer), as in §3.4:
/// (3s+2) / (2m)  ≈ 1.17·s% at m=128 for FP8.
pub fn csr_ratio(s: usize, head_dim: usize, mode: CoefMode) -> f64 {
    csr_row_bytes(s, mode) as f64 / full_vector_bytes(head_dim) as f64
}

/// Group-quantization cost: `bits` per element plus an FP16 scale and FP16
/// zero-point per group of `g` elements.
pub fn quant_vector_bytes(head_dim: usize, bits: usize, group: usize) -> f64 {
    let n_groups = (head_dim + group - 1) / group;
    (head_dim * bits) as f64 / 8.0 + (n_groups * 4) as f64
}

/// Running KV-size accountant shared by every cache backend.
#[derive(Clone, Debug, Default)]
pub struct Accountant {
    pub compressed_bytes: f64,
    pub baseline_bytes: f64,
}

impl Accountant {
    pub fn ratio(&self) -> f64 {
        if self.baseline_bytes == 0.0 {
            1.0
        } else {
            self.compressed_bytes / self.baseline_bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_formula_at_m128() {
        // Paper: ~1.17·s % at head_dim 128 (e.g. 37.5% for s=32).
        let r = csr_ratio(32, 128, CoefMode::Fp8);
        assert!((r - 0.3828).abs() < 1e-3, "{r}"); // (3*32+2)/256
        let r4 = csr_ratio(4, 128, CoefMode::Fp8);
        assert!((r4 - 14.0 / 256.0).abs() < 1e-9);
    }

    #[test]
    fn our_m32_operating_points() {
        assert!((csr_ratio(2, 32, CoefMode::Fp8) - 0.125).abs() < 1e-9);
        assert!((csr_ratio(4, 32, CoefMode::Fp8) - 0.21875).abs() < 1e-9);
        assert!((csr_ratio(8, 32, CoefMode::Fp8) - 0.40625).abs() < 1e-9);
    }

    #[test]
    fn sign_rows_store_at_most_two_bits_per_coef() {
        // s=8 at m=32: (16 + 1 + 4)/64 — below a third of the FP8 row
        assert_eq!(csr_row_bytes(8, CoefMode::Sign), 21);
        assert_eq!(csr_row_bytes(4, CoefMode::Sign), 13);
        for s in [2usize, 4, 6, 8, 16, 32] {
            assert!(CoefMode::Sign.bits_per_coef(s) <= 2.0 + 1e-12, "s={s}");
            assert!(
                csr_row_bytes(s, CoefMode::Sign) < csr_row_bytes(s, CoefMode::Fp8),
                "s={s}"
            );
        }
    }

    #[test]
    fn quant_bytes() {
        // 2-bit, group 32, m=128: 32 B codes + 4 groups * 4 B = 48 B → vs 256 B
        let b = quant_vector_bytes(128, 2, 32);
        assert_eq!(b, 48.0);
        assert!((b / 256.0 - 0.1875).abs() < 1e-9);
    }
}
