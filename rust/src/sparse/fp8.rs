//! FP8 (E4M3) and FP16 (IEEE binary16) conversion.
//!
//! Lexico stores CSR coefficients in FP8 E4M3 (paper §3.4): 1 sign, 4
//! exponent (bias 7), 3 mantissa bits; no infinities, S.1111.111 = NaN,
//! max finite = 448. The ablations (Tables 4/5/9/10) use FP16 values
//! instead; both are implemented and selectable per cache.

/// Sorted table of the 127 non-negative finite E4M3 values (codes 0..=0x7e).
/// E4M3 decoding is monotone in the code, so code k is at table index k.
fn e4m3_table() -> &'static [f32; 127] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[f32; 127]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0f32; 127];
        for (code, slot) in t.iter_mut().enumerate() {
            *slot = e4m3_to_f32(code as u8);
        }
        t
    })
}

/// Full 256-entry decode table: `e4m3_lut()[code] == e4m3_to_f32(code)`
/// for every byte (including both NaN patterns and all negative codes).
/// The CSR-slab attention sweep indexes this instead of calling
/// [`e4m3_to_f32`] per coefficient — same values, no exponent math in the
/// hot loop.
pub fn e4m3_lut() -> &'static [f32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[f32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0f32; 256];
        for (code, slot) in t.iter_mut().enumerate() {
            *slot = e4m3_to_f32(code as u8);
        }
        t
    })
}

/// Encode f32 → E4M3 byte: nearest representable value, ties to the even
/// code, saturating at ±448 (the E4M3 max-finite; S.1111.111 is NaN).
pub fn f32_to_e4m3(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7f;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    let t = e4m3_table();
    if a >= t[126] {
        return sign | 0x7e;
    }
    // binary search for the first table value > a, then pick the nearest of
    // the two neighbours.
    let hi = t.partition_point(|&v| v <= a);
    let code = if hi == 0 {
        0
    } else {
        let lo = hi - 1;
        let dl = a - t[lo];
        let dh = t[hi] - a;
        if dl < dh || (dl == dh && lo & 1 == 0) {
            lo
        } else {
            hi
        }
    };
    sign | code as u8
}

/// Decode E4M3 byte → f32.
pub fn e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xf) as i32;
    let m = (b & 7) as f32;
    if e == 15 && b & 7 == 7 {
        return f32::NAN;
    }
    if e == 0 {
        sign * m * 2f32.powi(-9) // subnormal
    } else {
        sign * (1.0 + m / 8.0) * 2f32.powi(e - 7)
    }
}

/// Encode f32 → IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16(x: f32) -> u16 {
    if x.is_nan() {
        return 0x7e00;
    }
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32 - 127 + 15;
    let mant = bits & 0x7fffff;
    if (bits & 0x7fffffff) == 0 {
        return sign;
    }
    if exp >= 31 {
        return sign | 0x7c00; // inf / overflow
    }
    if exp <= 0 {
        // subnormal half
        if exp < -10 {
            return sign;
        }
        let m = mant | 0x800000;
        let shift = (14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let rem = m & ((1 << shift) - 1);
        let mut v = (m >> shift) as u16;
        if rem > half || (rem == half && v & 1 == 1) {
            v += 1;
        }
        return sign | v;
    }
    let rem = mant & 0x1fff;
    let mut m10 = (mant >> 13) as u16;
    if rem > 0x1000 || (rem == 0x1000 && m10 & 1 == 1) {
        m10 += 1;
        if m10 == 0x400 {
            m10 = 0;
            exp += 1;
            if exp >= 31 {
                return sign | 0x7c00;
            }
        }
    }
    sign | ((exp as u16) << 10) | m10
}

/// Decode binary16 bits → f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let e = ((h >> 10) & 0x1f) as i32;
    let m = (h & 0x3ff) as f32;
    match e {
        0 => sign * m * 2f32.powi(-24),
        31 => {
            if m == 0.0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            }
        }
        _ => sign * (1.0 + m / 1024.0) * 2f32.powi(e - 15),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn e4m3_exact_values() {
        // Exactly representable values round-trip losslessly.
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 1.5, 2.0, 448.0, -448.0, 0.001953125] {
            let d = e4m3_to_f32(f32_to_e4m3(v));
            assert_eq!(d, v, "value {v} → {d}");
        }
    }

    #[test]
    fn e4m3_relative_error_bound() {
        // For normal-range values, e4m3 relative error ≤ 2^-4 = 6.25%.
        Prop::new(128).check("e4m3_rel_err", |rng, _| {
            let v = rng.range_f32(-400.0, 400.0);
            if v.abs() < 0.02 {
                return Ok(());
            }
            let d = e4m3_to_f32(f32_to_e4m3(v));
            let rel = (d - v).abs() / v.abs();
            if rel <= 0.0625 + 1e-6 {
                Ok(())
            } else {
                Err(format!("{v} → {d}, rel {rel}"))
            }
        });
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3_to_f32(f32_to_e4m3(1e9)), 448.0);
        assert_eq!(e4m3_to_f32(f32_to_e4m3(-1e9)), -448.0);
    }

    #[test]
    fn e4m3_monotone() {
        // Encoding must be monotone in the value.
        let mut prev = e4m3_to_f32(f32_to_e4m3(-500.0));
        let mut x = -500.0f32;
        while x < 500.0 {
            let d = e4m3_to_f32(f32_to_e4m3(x));
            assert!(d >= prev - 1e-6, "non-monotone at {x}: {prev} > {d}");
            prev = d;
            x += 0.37;
        }
    }

    #[test]
    fn f16_roundtrip_exact() {
        for &v in &[0.0f32, 1.0, -2.5, 0.125, 65504.0, -65504.0] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v);
        }
    }

    #[test]
    fn f16_relative_error_bound() {
        Prop::new(128).check("f16_rel_err", |rng, _| {
            let v = rng.range_f32(-1000.0, 1000.0);
            if v.abs() < 1e-3 {
                return Ok(());
            }
            let d = f16_to_f32(f32_to_f16(v));
            let rel = (d - v).abs() / v.abs();
            if rel <= 1.0 / 2048.0 + 1e-7 {
                Ok(())
            } else {
                Err(format!("{v} → {d}, rel {rel}"))
            }
        });
    }

    #[test]
    fn nan_handling() {
        assert!(e4m3_to_f32(f32_to_e4m3(f32::NAN)).is_nan());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    // ---- property suite (util/prop.rs): E4M3 ---------------------------

    #[test]
    fn e4m3_roundtrip_is_identity_on_every_representable_code() {
        // decode → encode must reproduce every non-NaN code exactly,
        // including -0.0 (0x80) and the ±448 endpoints.
        for code in 0..=0xffu16 {
            let code = code as u8;
            if code & 0x7f == 0x7f {
                continue; // NaN patterns
            }
            let v = e4m3_to_f32(code);
            assert_eq!(f32_to_e4m3(v), code, "code {code:#04x} (value {v})");
        }
    }

    #[test]
    fn e4m3_encoding_is_monotone_property() {
        Prop::new(256).check("e4m3_monotone", |rng, _| {
            let a = rng.range_f32(-500.0, 500.0);
            let b = rng.range_f32(-500.0, 500.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let (dl, dh) = (e4m3_to_f32(f32_to_e4m3(lo)), e4m3_to_f32(f32_to_e4m3(hi)));
            if dl <= dh {
                Ok(())
            } else {
                Err(format!("{lo} → {dl} but {hi} → {dh}"))
            }
        });
    }

    #[test]
    fn e4m3_ties_round_to_even_codes() {
        // the exact midpoint of every adjacent pair must take the even code
        // (both signs; midpoints of adjacent e4m3 values are f32-exact)
        for lo in 0..126u8 {
            let (a, b) = (e4m3_to_f32(lo), e4m3_to_f32(lo + 1));
            let mid = (a + b) / 2.0;
            let want = if lo & 1 == 0 { lo } else { lo + 1 };
            assert_eq!(f32_to_e4m3(mid), want, "midpoint of {a} and {b}");
            assert_eq!(f32_to_e4m3(-mid), 0x80 | want, "negative midpoint");
        }
    }

    #[test]
    fn e4m3_saturates_at_448_property() {
        assert_eq!(f32_to_e4m3(448.0), 0x7e);
        assert_eq!(f32_to_e4m3(f32::INFINITY), 0x7e);
        assert_eq!(f32_to_e4m3(f32::NEG_INFINITY), 0xfe);
        Prop::new(128).check("e4m3_saturation", |rng, _| {
            let v = rng.range_f32(448.0, 1e9);
            let enc = f32_to_e4m3(v);
            let dec = e4m3_to_f32(enc);
            if enc == 0x7e && dec == 448.0 && f32_to_e4m3(-v) == 0xfe {
                Ok(())
            } else {
                Err(format!("{v} → code {enc:#04x}, value {dec}"))
            }
        });
    }

    #[test]
    fn e4m3_lut_matches_decoder_on_every_code() {
        let lut = e4m3_lut();
        for code in 0..=0xffu16 {
            let code = code as u8;
            let direct = e4m3_to_f32(code);
            if direct.is_nan() {
                assert!(lut[code as usize].is_nan(), "code {code:#04x}");
            } else {
                assert_eq!(lut[code as usize].to_bits(), direct.to_bits(), "code {code:#04x}");
            }
        }
    }

    #[test]
    fn e4m3_nan_maps_to_0x7f() {
        assert_eq!(f32_to_e4m3(f32::NAN), 0x7f);
        assert_eq!(f32_to_e4m3(-f32::NAN), 0x7f);
    }

    // ---- property suite: FP16 ------------------------------------------

    #[test]
    fn f16_roundtrip_is_identity_on_every_representable_code() {
        // decode → encode over the whole 16-bit space (minus NaNs),
        // covering subnormals, ±0, ±inf and both exponent extremes.
        for h in 0..=0xffffu32 {
            let h = h as u16;
            if h & 0x7fff > 0x7c00 {
                continue; // NaN patterns
            }
            let v = f16_to_f32(h);
            assert_eq!(f32_to_f16(v), h, "bits {h:#06x} (value {v})");
        }
    }

    #[test]
    fn f16_encoding_is_monotone_property() {
        Prop::new(256).check("f16_monotone", |rng, _| {
            let a = rng.range_f32(-70000.0, 70000.0);
            let b = rng.range_f32(-70000.0, 70000.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let (dl, dh) = (f16_to_f32(f32_to_f16(lo)), f16_to_f32(f32_to_f16(hi)));
            if dl <= dh {
                Ok(())
            } else {
                Err(format!("{lo} → {dl} but {hi} → {dh}"))
            }
        });
    }

    #[test]
    fn f16_ties_round_to_even_codes() {
        // midpoints of adjacent finite halves (normal and subnormal, both
        // exponent-boundary and interior) must take the even code
        for h in [0x0000u16, 0x0001, 0x03fe, 0x03ff, 0x0400, 0x3bff, 0x3c00, 0x7bfe] {
            let (a, b) = (f16_to_f32(h), f16_to_f32(h + 1));
            let mid = (a + b) / 2.0;
            let want = if h & 1 == 0 { h } else { h + 1 };
            assert_eq!(f32_to_f16(mid), want, "midpoint of {a} and {b}");
            assert_eq!(f32_to_f16(-mid), 0x8000 | want, "negative midpoint");
        }
    }

    #[test]
    fn f16_saturates_to_infinity_beyond_max_finite() {
        assert_eq!(f32_to_f16(65504.0), 0x7bff);
        assert_eq!(f32_to_f16(70000.0), 0x7c00);
        assert_eq!(f32_to_f16(-70000.0), 0xfc00);
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xfc00), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_nan_maps_to_quiet_nan_bits() {
        assert_eq!(f32_to_f16(f32::NAN), 0x7e00);
        assert!(f16_to_f32(0x7e00).is_nan());
    }
}
