//! FP8 (E4M3) and FP16 (IEEE binary16) conversion.
//!
//! Lexico stores CSR coefficients in FP8 E4M3 (paper §3.4): 1 sign, 4
//! exponent (bias 7), 3 mantissa bits; no infinities, S.1111.111 = NaN,
//! max finite = 448. The ablations (Tables 4/5/9/10) use FP16 values
//! instead; both are implemented and selectable per cache.

/// Sorted table of the 127 non-negative finite E4M3 values (codes 0..=0x7e).
/// E4M3 decoding is monotone in the code, so code k is at table index k.
fn e4m3_table() -> &'static [f32; 127] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[f32; 127]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0f32; 127];
        for (code, slot) in t.iter_mut().enumerate() {
            *slot = e4m3_to_f32(code as u8);
        }
        t
    })
}

/// Encode f32 → E4M3 byte: nearest representable value, ties to the even
/// code, saturating at ±448 (the E4M3 max-finite; S.1111.111 is NaN).
pub fn f32_to_e4m3(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7f;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    let t = e4m3_table();
    if a >= t[126] {
        return sign | 0x7e;
    }
    // binary search for the first table value > a, then pick the nearest of
    // the two neighbours.
    let hi = t.partition_point(|&v| v <= a);
    let code = if hi == 0 {
        0
    } else {
        let lo = hi - 1;
        let dl = a - t[lo];
        let dh = t[hi] - a;
        if dl < dh || (dl == dh && lo & 1 == 0) {
            lo
        } else {
            hi
        }
    };
    sign | code as u8
}

/// Decode E4M3 byte → f32.
pub fn e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xf) as i32;
    let m = (b & 7) as f32;
    if e == 15 && b & 7 == 7 {
        return f32::NAN;
    }
    if e == 0 {
        sign * m * 2f32.powi(-9) // subnormal
    } else {
        sign * (1.0 + m / 8.0) * 2f32.powi(e - 7)
    }
}

/// Encode f32 → IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16(x: f32) -> u16 {
    if x.is_nan() {
        return 0x7e00;
    }
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32 - 127 + 15;
    let mant = bits & 0x7fffff;
    if (bits & 0x7fffffff) == 0 {
        return sign;
    }
    if exp >= 31 {
        return sign | 0x7c00; // inf / overflow
    }
    if exp <= 0 {
        // subnormal half
        if exp < -10 {
            return sign;
        }
        let m = mant | 0x800000;
        let shift = (14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let rem = m & ((1 << shift) - 1);
        let mut v = (m >> shift) as u16;
        if rem > half || (rem == half && v & 1 == 1) {
            v += 1;
        }
        return sign | v;
    }
    let rem = mant & 0x1fff;
    let mut m10 = (mant >> 13) as u16;
    if rem > 0x1000 || (rem == 0x1000 && m10 & 1 == 1) {
        m10 += 1;
        if m10 == 0x400 {
            m10 = 0;
            exp += 1;
            if exp >= 31 {
                return sign | 0x7c00;
            }
        }
    }
    sign | ((exp as u16) << 10) | m10
}

/// Decode binary16 bits → f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let e = ((h >> 10) & 0x1f) as i32;
    let m = (h & 0x3ff) as f32;
    match e {
        0 => sign * m * 2f32.powi(-24),
        31 => {
            if m == 0.0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            }
        }
        _ => sign * (1.0 + m / 1024.0) * 2f32.powi(e - 15),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;

    #[test]
    fn e4m3_exact_values() {
        // Exactly representable values round-trip losslessly.
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 1.5, 2.0, 448.0, -448.0, 0.001953125] {
            let d = e4m3_to_f32(f32_to_e4m3(v));
            assert_eq!(d, v, "value {v} → {d}");
        }
    }

    #[test]
    fn e4m3_relative_error_bound() {
        // For normal-range values, e4m3 relative error ≤ 2^-4 = 6.25%.
        Prop::new(128).check("e4m3_rel_err", |rng, _| {
            let v = rng.range_f32(-400.0, 400.0);
            if v.abs() < 0.02 {
                return Ok(());
            }
            let d = e4m3_to_f32(f32_to_e4m3(v));
            let rel = (d - v).abs() / v.abs();
            if rel <= 0.0625 + 1e-6 {
                Ok(())
            } else {
                Err(format!("{v} → {d}, rel {rel}"))
            }
        });
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3_to_f32(f32_to_e4m3(1e9)), 448.0);
        assert_eq!(e4m3_to_f32(f32_to_e4m3(-1e9)), -448.0);
    }

    #[test]
    fn e4m3_monotone() {
        // Encoding must be monotone in the value.
        let mut prev = e4m3_to_f32(f32_to_e4m3(-500.0));
        let mut x = -500.0f32;
        while x < 500.0 {
            let d = e4m3_to_f32(f32_to_e4m3(x));
            assert!(d >= prev - 1e-6, "non-monotone at {x}: {prev} > {d}");
            prev = d;
            x += 0.37;
        }
    }

    #[test]
    fn f16_roundtrip_exact() {
        for &v in &[0.0f32, 1.0, -2.5, 0.125, 65504.0, -65504.0] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v);
        }
    }

    #[test]
    fn f16_relative_error_bound() {
        Prop::new(128).check("f16_rel_err", |rng, _| {
            let v = rng.range_f32(-1000.0, 1000.0);
            if v.abs() < 1e-3 {
                return Ok(());
            }
            let d = f16_to_f32(f32_to_f16(v));
            let rel = (d - v).abs() / v.abs();
            if rel <= 1.0 / 2048.0 + 1e-7 {
                Ok(())
            } else {
                Err(format!("{v} → {d}, rel {rel}"))
            }
        });
    }

    #[test]
    fn nan_handling() {
        assert!(e4m3_to_f32(f32_to_e4m3(f32::NAN)).is_nan());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }
}
