//! Minimal JSON reader/writer (the offline image has no serde facade).
//!
//! The parser covers the full JSON grammar we produce/consume here:
//! `artifacts/manifest.json` (objects, arrays, strings, ints, floats, bools,
//! null) and the `reports/*.json` experiment outputs, including `\uXXXX`
//! escapes with UTF-16 surrogate pairs (`"\uD83D\uDE00"` parses to U+1F600);
//! lone surrogates decode to U+FFFD, matching the standard lenient
//! behaviour of `JSON.parse`/serde.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (adequate for all our payloads).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    /// Signed integer view (request `priority` fields); truncates any
    /// fractional part the way `as_usize` does.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    /// Unsigned integer view (`deadline_ms`, retry hints); negative
    /// numbers saturate to 0 rather than wrapping.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| if x <= 0.0 { 0 } else { x as u64 })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    let v = self.value()?;
                    m.insert(k, v);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4(self.i + 1)?;
                            self.i += 4;
                            if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: pairs with an immediately
                                // following \uDC00..\uDFFF to form one
                                // non-BMP code point (e.g. 😀 = D83D DE00)
                                let lo = if self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                {
                                    self.hex4(self.i + 3).ok()
                                } else {
                                    None
                                };
                                match lo {
                                    Some(lo) if (0xDC00..0xE000).contains(&lo) => {
                                        self.i += 6; // consume the low escape
                                        let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                        s.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                                    }
                                    // unpaired high surrogate: replacement
                                    // char; whatever follows parses on its
                                    // own
                                    _ => s.push('\u{fffd}'),
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                s.push('\u{fffd}'); // unpaired low surrogate
                            } else {
                                s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            }
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits starting at byte `at` (does not advance `self.i`).
    fn hex4(&self, at: usize) -> Result<u32, String> {
        let hex = std::str::from_utf8(self.b.get(at..at + 4).ok_or("bad \\u")?)
            .map_err(|_| "bad \\u")?;
        u32::from_str_radix(hex, 16).map_err(|_| "bad \\u".to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("b").get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("b").get("d"), &Json::Bool(true));
        assert_eq!(v.get("b").get("d").as_bool(), Some(true));
        assert_eq!(v.get("b").get("e").as_bool(), None);
        assert_eq!(v.get("a").idx(0).as_bool(), None, "numbers are not booleans");
    }

    #[test]
    fn integer_views_truncate_and_saturate() {
        let v = Json::parse(r#"{"p": -2, "d": 1500, "f": 2.9, "s": "7"}"#).unwrap();
        assert_eq!(v.get("p").as_i64(), Some(-2));
        assert_eq!(v.get("d").as_u64(), Some(1500));
        assert_eq!(v.get("f").as_i64(), Some(2), "fractional parts truncate");
        assert_eq!(v.get("p").as_u64(), Some(0), "negatives saturate to zero");
        assert_eq!(v.get("s").as_i64(), None, "strings are not numbers");
        assert_eq!(v.get("missing").as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str(), Some("Ab"));
    }

    #[test]
    fn surrogate_pairs_decode_to_one_code_point() {
        // U+1F600 GRINNING FACE, escaped as the UTF-16 pair \uD83D\uDE00 --
        // the BMP-only regression: the pair must become one char, not two
        // mangled replacement chars.
        let v = Json::parse(r#""\uD83D\uDE00""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        // pair embedded in surrounding text
        let v = Json::parse(r#""hi \uD83D\uDE00!""#).unwrap();
        assert_eq!(v.as_str(), Some("hi \u{1F600}!"));
        // U+1F9EA TEST TUBE = \uD83E\uDDEA
        let v = Json::parse(r#""\uD83E\uDDEA""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F9EA}"));
    }

    #[test]
    fn lone_surrogates_become_replacement_chars() {
        let v = Json::parse(r#""x\uD83Dy""#).unwrap();
        assert_eq!(v.as_str(), Some("x\u{fffd}y"));
        let v = Json::parse(r#""x\uDE00y""#).unwrap();
        assert_eq!(v.as_str(), Some("x\u{fffd}y"));
        // high surrogate followed by a non-surrogate escape: both survive
        let v = Json::parse(r#""\uD83DA""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{fffd}A"));
    }

    #[test]
    fn non_bmp_round_trips_through_write_and_parse() {
        let v = Json::Str("prompt 😀🧪".into());
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.as_str(), Some("prompt 😀🧪"));
        // raw (unescaped) UTF-8 in the source also parses
        let v = Json::parse("\"😀\"").unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }
}
