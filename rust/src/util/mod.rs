//! Cross-cutting utilities: PRNG, JSON, property testing, bench statistics.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
