//! SplitMix64 — the deterministic PRNG used across the repo.
//!
//! Bit-identical to `python/compile/data.py::SplitMix64`; the Python side
//! seeds training data, the Rust side seeds evaluation data, and keeping the
//! algorithm shared (but the *streams* disjoint) makes every experiment
//! reproducible end-to-end. A cross-language vector test lives in
//! `tests/cross_contract.rs`.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        self.next_u64() as f64 / 2f64.powi(64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// In-place Fisher–Yates shuffle (matches the Python generator's order).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn golden_value_matches_python() {
        // data.py::SplitMix64(0).next_u64() — cross-language parity anchor.
        assert_eq!(Rng::new(0).next_u64(), 16294208416658607535);
    }

    #[test]
    fn known_vectors() {
        // First three outputs for seed 1234 — mirrored in the Python tests
        // so both languages agree on the generator.
        let mut r = Rng::new(1234);
        let v: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
        let mut r2 = Rng::new(1234);
        assert_eq!(v, (0..3).map(|_| r2.next_u64()).collect::<Vec<_>>());
        // below() stays in range and hits both halves eventually
        let mut r = Rng::new(7);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..200 {
            let x = r.below(10);
            assert!(x < 10);
            lo |= x < 5;
            hi |= x >= 5;
        }
        assert!(lo && hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
