//! In-repo property-testing harness (the offline image has no proptest).
//!
//! Semantics: run a property over `cases` randomly generated inputs; on the
//! first failure, report the failing seed so the case replays exactly
//! (generation is a pure function of the per-case [`Rng`]). A lightweight
//! shrink pass retries the property with progressively smaller `size`
//! parameters to present a small counterexample when the generator honours
//! `size`.

use super::rng::Rng;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: 0xC0FFEE, max_size: 32 }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Check `f(rng, size)`, where `f` returns `Err(msg)` on violation.
    /// `size` ramps from 1 to `max_size` across the cases, so early cases
    /// are small; on failure a shrink pass retries smaller sizes first.
    pub fn check<F>(&self, name: &str, mut f: F)
    where
        F: FnMut(&mut Rng, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let size = 1 + (case * self.max_size) / self.cases.max(1);
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = f(&mut rng, size) {
                // shrink: same seed, smaller sizes
                for small in 1..size {
                    let mut r2 = Rng::new(case_seed);
                    if let Err(msg2) = f(&mut r2, small) {
                        panic!(
                            "property '{name}' failed (seed={case_seed:#x}, size={small}, shrunk from {size}): {msg2}"
                        );
                    }
                }
                panic!("property '{name}' failed (seed={case_seed:#x}, size={size}): {msg}");
            }
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol + 1e-5 * y.abs() {
            return Err(format!("{what}: idx {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial() {
        Prop::new(16).check("trivial", |rng, size| {
            let v = rng.below(size.max(1) * 10);
            if v < size * 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        Prop::new(16).check("fails", |_rng, size| {
            if size < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
