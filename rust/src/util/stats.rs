//! Timing/statistics helpers for the hand-rolled bench harness
//! (no criterion in the offline image).

use std::time::Instant;

/// Summary statistics over a set of samples (nanoseconds or any unit).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let pct = |p: f64| v[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: v[0],
        p50: pct(0.5),
        p95: pct(0.95),
        p99: pct(0.99),
        max: v[n - 1],
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations; returns
/// per-iteration wall time in milliseconds.
pub fn bench_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize(&samples)
}

/// Pretty one-line report used by the `benches/` binaries.
pub fn report(name: &str, s: &Summary) {
    println!(
        "{name:<44} mean {m:>9.4} ms  p50 {p50:>9.4}  p95 {p95:>9.4}  (n={n})",
        m = s.mean,
        p50 = s.p50,
        p95 = s.p95,
        n = s.n
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn p99_sits_between_p95_and_max() {
        let v: Vec<f64> = (1..=1000).map(|x| x as f64).collect();
        let s = summarize(&v);
        assert_eq!(s.p50, 501.0); // round-half-up index: v[round(0.5·999)]
        assert_eq!(s.p95, 950.0);
        assert_eq!(s.p99, 990.0);
        assert_eq!(s.max, 1000.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench_ms(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
