//! Deterministic multi-core execution layer: a hand-rolled, dependency-free
//! worker pool that every hot layer (GEMMs, batched OMP, per-session
//! attention, the batcher round) shards onto.
//!
//! **Design.** [`ExecPool::new(threads)`](ExecPool::new) spawns `threads−1`
//! persistent workers; the calling thread is always the `threads`-th worker,
//! so a 1-thread pool runs everything inline with zero overhead and zero
//! behavioural difference. [`ExecPool::parallel_for`] is a *scoped*
//! parallel-for: it publishes a lifetime-erased reference to the closure,
//! lets workers claim shard indices from an atomic counter, participates in
//! the claiming itself, and returns only after every shard completed — so
//! the closure may freely borrow the caller's stack. Nested `parallel_for`
//! (a sharded session calling into the sharded OMP encoder) is safe: the
//! inner caller drains its own shard queue before blocking, so progress
//! never depends on a worker being free.
//!
//! **Determinism contract.** The pool schedules *work*, never *values*:
//! every parallel kernel built on it partitions disjoint output elements
//! across shards and computes each element with the exact floating-point
//! operation sequence of its sequential twin. No partial sums are ever
//! combined across shards, so results are bitwise identical at every thread
//! count — the batch-parity and golden-transcript suites pass unchanged at
//! `T ∈ {1, 2, 4, …}`. See DESIGN.md §7.
//!
//! The process-wide default pool ([`default_pool`]) is sized from
//! `LEXICO_THREADS`, falling back to the machine's available parallelism;
//! [`configure_default`] (the `--threads` CLI flag) overrides it before
//! first use.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// One published batch of shards: a lifetime-erased task plus the claim /
/// completion state. Workers hold it behind an `Arc`, so a worker waking
/// late (after the job drained) finds `next ≥ n_shards` and goes back to
/// sleep without ever touching the erased pointer.
struct Job {
    /// Erased `&dyn Fn(usize)` — only dereferenced between a successful
    /// shard claim and the matching `pending` decrement, both of which
    /// happen while the owning `parallel_for` call is still blocked.
    task: *const (dyn Fn(usize) + Sync),
    n_shards: usize,
    /// next shard index to claim
    next: AtomicUsize,
    /// shards claimed-or-unclaimed that have not finished yet
    pending: AtomicUsize,
    /// set when any shard panicked; the publisher re-raises after the join
    panicked: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `task` is only dereferenced while the publishing `parallel_for`
// frame is alive (it waits for `pending == 0` before returning); all other
// fields are Sync primitives.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct State {
    epoch: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
}

/// Erase the borrow lifetime from a task so worker threads can hold it.
///
/// # Safety
/// The returned pointer must only be dereferenced while the originating
/// `parallel_for` call is still on the stack — the call joins all shards
/// before returning, so the borrow outlives every dereference.
#[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
unsafe fn erase_task<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> *const (dyn Fn(usize) + Sync) {
    std::mem::transmute(f)
}

/// Claim and run shards of `job` until its claim counter is exhausted,
/// signalling completion when this thread finishes the last shard.
///
/// A shard that panics is caught here: the panic must not skip the
/// `pending` decrement (the publisher would block forever) and must not
/// unwind the publisher's own frame past the unpublish (a stale worker
/// could then dereference the dangling task pointer). Instead the job is
/// flagged and the publisher re-raises the panic after all shards joined.
fn run_shards(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_shards {
            break;
        }
        // SAFETY: a successful claim implies pending > 0, i.e. the
        // publishing parallel_for is still blocked and the borrow is live.
        let task = unsafe { &*job.task };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        // AcqRel: this thread's shard writes are released to whoever sees
        // the final decrement, and the final decrementer acquires every
        // earlier worker's writes through the RMW chain.
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = job.done.lock().unwrap();
            *done = true;
            job.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(j) = st.job.clone() {
                        break j;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        run_shards(&job);
    }
}

/// A pool of persistent worker threads executing scoped parallel-for jobs
/// over disjoint output shards. See the module docs for the determinism
/// contract. Cheap to share behind an [`Arc`]; `Drop` joins the workers.
pub struct ExecPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ExecPool {
    /// A pool with `threads` total lanes of parallelism (the caller counts
    /// as one lane, so `threads − 1` worker threads are spawned; `threads`
    /// is clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lexico-exec-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn exec worker")
            })
            .collect();
        ExecPool { shared, handles, threads }
    }

    /// Pool sized from `LEXICO_THREADS`, falling back to the machine's
    /// available parallelism (then 1).
    pub fn from_env() -> Self {
        let threads = std::env::var("LEXICO_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        ExecPool::new(threads)
    }

    /// Total lanes of parallelism (worker threads + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(shard)` for every shard in `0..n_shards`, distributing shards
    /// across the pool, and return once all shards completed. `f` may
    /// borrow the caller's stack. Shards must write disjoint outputs; the
    /// pool guarantees each index runs exactly once but promises nothing
    /// about which thread runs it or in what order.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n_shards: usize, f: F) {
        if n_shards == 0 {
            return;
        }
        if self.handles.is_empty() || n_shards == 1 {
            for i in 0..n_shards {
                f(i);
            }
            return;
        }
        let task_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: this frame blocks until every shard completed (below).
        let task = unsafe { erase_task(task_ref) };
        let job = Arc::new(Job {
            task,
            n_shards,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_shards),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(job.clone());
            self.shared.work_cv.notify_all();
        }
        // The caller is a full participant: it drains the claim queue
        // before it ever blocks, so nested parallel_for cannot deadlock.
        run_shards(&job);
        {
            let mut done = job.done.lock().unwrap();
            while !*done {
                done = job.done_cv.wait(done).unwrap();
            }
        }
        // Unpublish (only if the slot still holds *this* job — a concurrent
        // caller may already have replaced it) so the erased pointer never
        // outlives this call.
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.job.as_ref().is_some_and(|j| Arc::ptr_eq(j, &job)) {
                st.job = None;
            }
        }
        // Re-raise shard panics in the publisher, matching the sequential
        // path's behaviour — only after the join + unpublish, so no worker
        // can be left holding live work or a dangling pointer.
        if job.panicked.load(Ordering::Relaxed) {
            panic!("ExecPool: a parallel_for shard panicked (see output above)");
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Default pool
// ---------------------------------------------------------------------------

static DEFAULT: OnceLock<Arc<ExecPool>> = OnceLock::new();

/// The process-wide default pool (created on first use from
/// `LEXICO_THREADS` / available parallelism). `Engine::new` and fresh
/// `BatchOmpWorkspace` instances run on this pool unless given another one
/// explicitly.
pub fn default_pool() -> Arc<ExecPool> {
    DEFAULT.get_or_init(|| Arc::new(ExecPool::from_env())).clone()
}

/// Set the default pool size (the `--threads N` CLI flag). Returns `false`
/// if the default pool was already created — callers should configure
/// before touching any engine or cache.
pub fn configure_default(threads: usize) -> bool {
    DEFAULT.set(Arc::new(ExecPool::new(threads))).is_ok()
}

/// Parse `--threads N` / `--threads=N` out of a raw argv slice — the shared
/// front-end for bench binaries and examples (the `lexico` CLI proper
/// validates through its own flag parser). Returns `Err` on a present but
/// malformed value so callers can report it instead of silently running on
/// the default pool.
pub fn threads_from_args(argv: &[String]) -> Result<Option<usize>, String> {
    let raw = argv
        .iter()
        .position(|a| a == "--threads")
        .map(|i| argv.get(i + 1).cloned().unwrap_or_default())
        .or_else(|| argv.iter().find_map(|a| a.strip_prefix("--threads=").map(String::from)));
    match raw {
        None => Ok(None),
        Some(v) => match v.parse::<usize>() {
            Ok(t) if t >= 1 => Ok(Some(t)),
            _ => Err(format!("--threads must be a positive integer, got '{v}'")),
        },
    }
}

// ---------------------------------------------------------------------------
// SendPtr
// ---------------------------------------------------------------------------

/// A raw pointer that asserts cross-thread use is safe because every shard
/// of a `parallel_for` touches a *disjoint* region behind it. This is the
/// one escape hatch the parallel kernels use to hand each shard its slice
/// of a shared output buffer.
///
/// # Safety
/// The creator must guarantee that (a) concurrent shards never access
/// overlapping elements through the pointer and (b) the pointee outlives
/// the `parallel_for` call — both hold trivially for the
/// output-partitioning kernels in this crate.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    pub fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_shard_runs_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = ExecPool::new(threads);
            for n in [0usize, 1, 2, 3, 17, 64, 257] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                pool.parallel_for(n, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "shard {i} at T={threads} n={n}");
                }
            }
        }
    }

    #[test]
    fn shard_writes_are_visible_to_the_caller() {
        let pool = ExecPool::new(4);
        let mut out = vec![0u64; 1000];
        let ptr = SendPtr::new(out.as_mut_ptr());
        pool.parallel_for(1000, move |i| {
            // SAFETY: each shard writes exactly element i.
            unsafe { *ptr.get().add(i) = (i as u64) * 3 + 1 };
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * 3 + 1);
        }
    }

    #[test]
    fn pool_is_reusable_across_many_rounds() {
        let pool = ExecPool::new(3);
        let total = AtomicU64::new(0);
        for round in 0..200u64 {
            pool.parallel_for(8, |i| {
                total.fetch_add(round + i as u64, Ordering::Relaxed);
            });
        }
        // Σ_round Σ_i (round + i) = 200·(0+..+7) + 8·(0+..+199)
        let expect = 200 * 28 + 8 * (199 * 200 / 2);
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn nested_parallel_for_completes() {
        let pool = ExecPool::new(4);
        let grid = vec![AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)];
        let cells = AtomicUsize::new(0);
        pool.parallel_for(3, |outer| {
            grid[outer].fetch_add(1, Ordering::Relaxed);
            pool.parallel_for(5, |_inner| {
                cells.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(cells.load(Ordering::Relaxed), 15);
        for g in &grid {
            assert_eq!(g.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn borrowed_state_is_safe_to_capture() {
        // The scoped guarantee: the closure borrows a stack-local Vec.
        let pool = ExecPool::new(2);
        let data: Vec<usize> = (0..100).collect();
        let sum = AtomicUsize::new(0);
        pool.parallel_for(10, |s| {
            let part: usize = data[s * 10..(s + 1) * 10].iter().sum();
            sum.fetch_add(part, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn single_thread_pool_runs_inline_in_order() {
        let pool = ExecPool::new(1);
        assert_eq!(pool.threads(), 1);
        let order = Mutex::new(Vec::new());
        pool.parallel_for(6, |i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn shard_panic_propagates_to_the_publisher_and_pool_survives() {
        let pool = ExecPool::new(3);
        let ran = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(8, |i| {
                ran.fetch_add(1, Ordering::Relaxed);
                if i == 3 {
                    panic!("boom in shard 3");
                }
            });
        }));
        assert!(result.is_err(), "shard panic must re-raise in the publisher");
        assert_eq!(ran.load(Ordering::Relaxed), 8, "panic must not strand other shards");
        // the pool keeps working afterwards
        let ok = AtomicUsize::new(0);
        pool.parallel_for(5, |_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn threads_from_args_parses_both_flag_forms_and_rejects_garbage() {
        let argv = |s: &[&str]| -> Vec<String> { s.iter().map(|a| a.to_string()).collect() };
        assert_eq!(threads_from_args(&argv(&["bench"])), Ok(None));
        assert_eq!(threads_from_args(&argv(&["bench", "--threads", "4"])), Ok(Some(4)));
        assert_eq!(threads_from_args(&argv(&["bench", "--threads=2"])), Ok(Some(2)));
        assert!(threads_from_args(&argv(&["bench", "--threads", "four"])).is_err());
        assert!(threads_from_args(&argv(&["bench", "--threads", "0"])).is_err());
        assert!(threads_from_args(&argv(&["bench", "--threads"])).is_err());
    }

    #[test]
    fn from_env_and_default_pool_exist() {
        // No assertions about the exact count (the env is shared), just
        // that construction succeeds and the default is stable.
        let p = ExecPool::from_env();
        assert!(p.threads() >= 1);
        let a = default_pool();
        let b = default_pool();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }
}
