//! `lexico` — the L3 coordinator binary.
//!
//! Subcommands (hand-rolled CLI; the offline image has no clap):
//!   serve       run the serving coordinator (TCP JSON-lines)
//!   eval        evaluate one cache method on one task
//!   repro       regenerate a paper table/figure (or `all`)
//!   pjrt        generate through the PJRT artifacts + cross-check native
//!   train-dict  native dictionary training demo
//!   inspect     print model / dictionary / artifact info

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use lexico::repro::{self, ReproOpts};
use lexico::server::batcher::{self, BatcherConfig};
use lexico::server::metrics::Metrics;
use lexico::tasks::Task;
use lexico::{artifacts_dir, eval, model::Engine, model::Weights};

struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.insert(name.to_string(), "1".to_string());
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Args { positional, flags }
}

impl Args {
    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "\
lexico — Lexico KV-cache compression (ICML 2025) reproduction

USAGE:
  lexico serve  [--addr 127.0.0.1:7077] [--model M] [--method SPEC]
                [--budget-mb 64] [--max-sessions 32] [--threads N]
                [--prefill-chunk 256] [--spill-dir DIR]
                [--resident-budget MB] [--max-queue 1024]
                [--max-decode-batch 0] [--ttft-slo MS] [--tpot-slo MS]
                [--tenant-quota SPEC] [--max-conns 256]
                [--coef-mode fp8|fp16|sign] [--dict-refresh N]
  lexico eval   [--model M] [--task arith] [--method SPEC] [--n 50]
                [--seed 0] [--dict-n 1024] [--threads N]
  lexico repro  <fig1|fig3|fig5|fig6|fig7|table1..table7|all> [--fast]
                [--threads N]
  lexico pjrt   [--prompt TEXT] [--max-new 16]
  lexico train-dict [--model M] [--atoms 256] [--s 8] [--epochs 6]
  lexico inspect [--model M]

--threads N sizes the worker pool every hot path runs on (default:
LEXICO_THREADS, then the machine's available parallelism). Results are
bitwise identical at every thread count.

--fast-math (any subcommand) opts into the fused-kernel tier: dot/axpy
dispatch to FMA variants (fast-scalar | fma | avx512-fma | neon-fma).
Equivalent to LEXICO_FAST_MATH=1. Fast-tier results are bitwise
reproducible within the tier but only tolerance-equal to the default
canonical tier (max |Δlogit| pinned by goldens); leave it off when
comparing transcripts against canonical runs. LEXICO_SIMD=<name> pins a
specific kernel in whichever tier is active
(scalar|sse2|avx2|neon, or a fast-tier name under --fast-math).

--gram-omp (any subcommand) opts into the precomputed-Gram Batch-OMP
encode tier: each dictionary's Gram matrix G = D·Dᵀ is realized once
(4·N² bytes, reported as the `gram` gauge) and every compression runs
coefficient-space pursuit — one GEMM for the whole batch's initial
projections, O(N·s) per iteration instead of O(N·m), no residual
vectors. Equivalent to LEXICO_GRAM_OMP=1. Gram-tier results are bitwise
reproducible at every thread count but only tolerance-equal to the
default canonical pursuit (same supports on well-separated
dictionaries); leave it off when comparing transcripts against
canonical runs. Adaptive-dictionary methods always use the canonical
path (atom mutation would stale the Gram cache).

--coef-mode MODE (any subcommand) retargets every lexico cache that left
its coefficient mode at the default: fp8 (1 byte/coef, the default),
fp16 (2 bytes/coef, the paper's setting), or sign — coefficients
collapse to ±α with one packed sign bit per atom and a single f16 row
scale α, ~1–2 bits/coef stored. Equivalent to LEXICO_COEF_MODE=MODE.
Method specs carrying an explicit ,fp16 or ,sign flag keep their pinned
mode. Each mode's decode is bitwise deterministic at every thread count.

--dict-refresh N (serve) folds each adaptive session's overlay atoms
into its universal dictionaries every N scheduling rounds (0 = never,
the default; LEXICO_DICT_REFRESH sets the same default). Decode output
is bitwise unchanged — folded atoms keep their coefficients — while the
overlay's growth headroom re-arms and the dictionary generation
rotates, so a Gram cache realized afterwards sees the folded atoms.

--prefill-chunk N bounds the prompt tokens a prefilling session consumes
per scheduling round (0 = monolithic). Chunking keeps one long admission
from stalling active sessions' decode cadence; token streams are bitwise
identical at every chunk size. Send {"stream": true} with a request to
receive one {"id","token","i"} JSON line per generated token ahead of the
final response line.

SLO-aware admission: requests may carry \"tenant\", \"priority\" (higher
admits first; FIFO within a class) and \"deadline_ms\" (0 = none;
past-deadline jobs retire with a deadline_expired error, freeing their
budget the same round). --tenant-quota \"free=seats:2,kv_mb:4;*=seats:8\"
caps per-tenant seats/KV bytes (\"*\" = every other tenant). --max-queue
bounds the admission queue: overflow sheds the lowest-priority, newest
queued request with {\"error\":\"overloaded\",\"retry_after_ms\":N}.
--ttft-slo / --tpot-slo (ms) steer the per-round prefill chunk budget and
decode batch composition under load; --max-decode-batch hard-caps the
decode batch (0 = all; pacing only — token streams never change).
--max-conns caps concurrent connections; excess accepts get
{\"error\":\"busy\"} with a retry hint.

--spill-dir DIR enables tiered KV residency: cold sessions' sealed pages
page out to an append-only file under DIR and fault back on demand,
bitwise-identically. Requests carrying \"session\": \"name\" hibernate on
completion instead of retiring; {\"cmd\": \"resume\", \"session\": \"name\"}
continues them — across server restarts, since hibernation snapshots to
DIR. --resident-budget MB caps resident KV bytes below --budget-mb
(default: equal), forcing cold sessions to disk under pressure.
(LEXICO_SPILL_DIR / LEXICO_RESIDENT_BUDGET set the same defaults.)

Method specs: full | lexico:s=8,nb=32[,delta=..][,fp16|,sign][,adaptive=N:d]
  | kivi:bits=2,g=16,nb=16 | pertoken:bits=4,g=16 | zipcache:hi=4,lo=2
  | snapkv:cap=64,win=8 | pyramidkv:cap=64,win=8
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    // opt into the fast-math kernel tier before the first dot/axpy call
    // freezes dispatch (simd::active is a process-wide OnceLock)
    if args.has("fast-math") {
        std::env::set_var("LEXICO_FAST_MATH", "1");
    }
    // opt into the precomputed-Gram OMP tier before any cache snapshots
    // the request flag at construction
    if args.has("gram-omp") {
        std::env::set_var("LEXICO_GRAM_OMP", "1");
    }
    // route the coefficient-mode override through the runtime config's one
    // resolution point (CacheRuntime::from_env) so every subcommand —
    // serve, eval, repro — builds caches under the same mode
    if let Some(mode) = args.flags.get("coef-mode") {
        if lexico::sparse::CoefMode::parse(mode).is_none() {
            bail!("--coef-mode must be fp8, fp16 or sign (got '{mode}')");
        }
        std::env::set_var("LEXICO_COEF_MODE", mode);
    }
    // size the exec pool before any engine or cache exists
    if let Some(t) = args.flags.get("threads") {
        let t: usize = t.parse().context("--threads must be a positive integer")?;
        if t == 0 {
            bail!("--threads must be ≥ 1");
        }
        if !lexico::exec::configure_default(t) {
            eprintln!("warning: exec pool already initialized; --threads {t} ignored");
        }
    }
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "repro" => cmd_repro(&args),
        "pjrt" => cmd_pjrt(&args),
        "train-dict" => cmd_train_dict(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn load_engine(size: &str) -> Result<Engine> {
    let path = artifacts_dir().join(format!("model_{size}.bin"));
    let w = Weights::load(&path)
        .with_context(|| format!("{} (run `make artifacts` first)", path.display()))?;
    Ok(Engine::new(w))
}

fn load_dicts(size: &str, n: usize) -> Result<Arc<lexico::dict::DictionarySet>> {
    Ok(Arc::new(lexico::dict::DictionarySet::load(
        artifacts_dir().join(format!("dict_{size}_N{n}.bin")),
    )?))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let size = args.get("model", "M");
    let engine = Arc::new(load_engine(&size)?);
    let dicts = load_dicts(&size, 1024).ok();
    let mut cfg = BatcherConfig {
        default_method: args.get("method", "lexico:s=8,nb=32"),
        kv_budget_bytes: args.get("budget-mb", "64").parse::<f64>()? * 1024.0 * 1024.0,
        max_sessions: args.get("max-sessions", "32").parse()?,
        prefix_entries: args.get("prefix-entries", "8").parse()?,
        prefix_min_tokens: args.get("prefix-min-tokens", "8").parse()?,
        max_fanout: args.get("max-fanout", "8").parse()?,
        prefill_chunk: args.get("prefill-chunk", "256").parse()?,
        max_queue: args.get("max-queue", "1024").parse()?,
        max_decode_batch: args.get("max-decode-batch", "0").parse()?,
        slo: lexico::server::sched::SloTargets {
            ttft_ms: args.get("ttft-slo", "0").parse()?,
            tpot_ms: args.get("tpot-slo", "0").parse()?,
        },
        tenant_quotas: lexico::server::sched::TenantQuotas::parse(&args.get("tenant-quota", ""))
            .map_err(|e| anyhow::anyhow!("--tenant-quota: {e}"))?,
        // spill_dir / resident_budget_bytes: env-derived defaults
        ..Default::default()
    };
    if let Some(dir) = args.flags.get("spill-dir") {
        // explicit flag: use the directory exactly as given (resumable
        // across restarts), unlike the env default's per-process subdir
        cfg.spill_dir = Some(std::path::PathBuf::from(dir));
    }
    if let Some(mb) = args.flags.get("resident-budget") {
        cfg.resident_budget_bytes =
            mb.parse::<f64>().context("--resident-budget takes MB")? * 1024.0 * 1024.0;
    }
    if let Some(n) = args.flags.get("dict-refresh") {
        cfg.dict_refresh = n.parse().context("--dict-refresh takes a round count")?;
    }
    // redundant with the LEXICO_COEF_MODE env main() set, but keeps the
    // batcher's config self-describing for programmatic embedders
    cfg.coef_mode = args.flags.get("coef-mode").and_then(|m| lexico::sparse::CoefMode::parse(m));
    let addr = args.get("addr", "127.0.0.1:7077");
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (jtx, jrx) = std::sync::mpsc::channel();
    let m2 = metrics.clone();
    let eng2 = engine.clone();
    let cfg2 = cfg.clone();
    let batcher = std::thread::spawn(move || batcher::run(eng2, dicts, cfg2, jrx, m2));
    println!(
        "lexico serving model {size} on {addr} (default method: {}, budget {} MB, {} threads)",
        cfg.default_method,
        cfg.kv_budget_bytes / 1048576.0,
        engine.pool().threads()
    );
    let opts =
        lexico::server::http::ServeOpts { max_conns: args.get("max-conns", "256").parse()? };
    lexico::server::http::serve_opts(&addr, opts, jtx, metrics.clone(), |a| {
        println!("listening on {a}");
    })?;
    drop(batcher);
    println!("{}", lexico::server::lock_tolerant(&metrics).report());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let size = args.get("model", "M");
    let engine = load_engine(&size)?;
    let task = Task::from_name(&args.get("task", "arith"))
        .context("unknown task (arith|arith-hard|needle|copy|sort|lm)")?;
    let method = args.get("method", "lexico:s=8,nb=32");
    let n: usize = args.get("n", "50").parse()?;
    let seed: u64 = args.get("seed", "0").parse()?;
    let dict_n: usize = args.get("dict-n", "1024").parse()?;
    let dicts = load_dicts(&size, dict_n).ok();
    let r = eval::evaluate(&engine, dicts, &method, &eval::EvalConfig::new(task, n, seed))?;
    println!("{:<28} {:>7} {:>10} {:>9}", "method", "task", "KV size", "score");
    println!("{}", eval::format_row(&r));
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let exhibit = args
        .positional
        .first()
        .context("usage: lexico repro <exhibit|all> [--fast]")?;
    let opts = ReproOpts { fast: args.has("fast"), ..Default::default() };
    repro::run(exhibit, &opts)
}

fn cmd_pjrt(args: &Args) -> Result<()> {
    use lexico::tasks;
    let dir = artifacts_dir();
    let engine = lexico::runtime::PjrtEngine::load(&dir, &dir.join("model_M.bin"))?;
    println!("PJRT engine up: {} graphs compiled", 2 + engine.omp.is_some() as usize
        + engine.lexico_decode.is_some() as usize);
    let prompt_text = args.get("prompt", "a=3;b=a+4;b?");
    let max_new: usize = args.get("max-new", "8").parse()?;
    let mut prompt = vec![tasks::BOS];
    prompt.extend(tasks::encode_lossy(&prompt_text));
    let out = engine.generate(&prompt, max_new, Some(tasks::newline_id()))?;
    let pl = engine.prefill_logits(&prompt)?;
    println!("pjrt   : {:?} -> {:?}", prompt_text, tasks::decode(&out));
    // cross-check against the native engine
    let native = load_engine("M")?;
    let mut cache = lexico::cache::full::FullCache::new(native.shape());
    let out2 = native.generate(&prompt, max_new, Some(tasks::newline_id()), &mut cache);
    println!("native : {:?} -> {:?}", prompt_text, tasks::decode(&out2));
    let mut cache2 = lexico::cache::full::FullCache::new(native.shape());
    let nl2 = native.prefill(&prompt, &mut cache2);
    let maxd = pl.iter().zip(&nl2).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    println!("prefill logits: max |PJRT − native| = {maxd:.2e}");
    if out == out2 {
        println!("MATCH: PJRT and native greedy decoding agree");
    } else if maxd < 1e-3 {
        println!("logits agree to {maxd:.1e}; token streams diverged on a near-tie argmax");
    } else {
        println!("WARNING: engines disagree numerically");
    }
    Ok(())
}

fn cmd_train_dict(args: &Args) -> Result<()> {
    let size = args.get("model", "M");
    let engine = load_engine(&size)?;
    let atoms: usize = args.get("atoms", "256").parse()?;
    let s: usize = args.get("s", "8").parse()?;
    let epochs: usize = args.get("epochs", "6").parse()?;
    println!("collecting KV vectors from model {size}…");
    let (ks, _vs) = lexico::repro::exhibits::collect_kv_for_training(&engine, 0xDEED, 2000);
    let m = engine.shape().head_dim;
    let flat: Vec<f32> = ks.iter().flatten().copied().collect();
    let opts = lexico::dict::train::TrainOpts {
        n_atoms: atoms, sparsity: s, epochs, batch: 128, lr: 1e-3, seed: 7,
    };
    println!("training dictionary N={atoms} s={s} on {} vectors…", ks.len());
    let (d, losses) = lexico::dict::train::train_dictionary(&flat, m, &opts);
    for (i, l) in losses.iter().enumerate() {
        println!("  epoch {:>2}: loss {l:.5}", i + 1);
    }
    // compare against a random dictionary
    let rand = lexico::dict::Dictionary::random(m, atoms, 42);
    let (mut e_t, mut e_r) = (0.0f64, 0.0f64);
    for x in ks.iter().take(300) {
        let ct = lexico::omp::omp_encode_alloc(&d.atoms, d.n, d.m, x, s, 0.0);
        let cr = lexico::omp::omp_encode_alloc(&rand.atoms, rand.n, rand.m, x, s, 0.0);
        e_t += lexico::omp::rel_error(&d.atoms, m, x, &ct) as f64;
        e_r += lexico::omp::rel_error(&rand.atoms, m, x, &cr) as f64;
    }
    println!(
        "mean rel. error: trained {:.4} vs random {:.4}",
        e_t / 300.0,
        e_r / 300.0
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let size = args.get("model", "M");
    let engine = load_engine(&size)?;
    let c = engine.weights.cfg;
    let n_params: usize = engine.weights.by_name.values()
        .map(|(s, _)| s.iter().product::<usize>())
        .sum();
    println!("model {size}: {n_params} params");
    println!("  layers={} d_model={} heads={}/{} head_dim={} ff={} vocab={} max_seq={}",
             c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.head_dim, c.d_ff,
             c.vocab, c.max_seq);
    for n in [256usize, 1024] {
        if let Ok(d) = lexico::dict::DictionarySet::load(
            artifacts_dir().join(format!("dict_{size}_N{n}.bin"))) {
            println!("  dict N={n}: {} layers × (K,V), {} KB fp16 each",
                     d.keys.len(), d.keys[0].bytes_fp16() / 1024);
        }
    }
    for s in [1usize, 2, 4, 6, 8] {
        let fp8 = lexico::sparse::memory::csr_ratio(s, c.head_dim, lexico::sparse::CoefMode::Fp8);
        let sign =
            lexico::sparse::memory::csr_ratio(s, c.head_dim, lexico::sparse::CoefMode::Sign);
        println!(
            "  KV ratio at s={s}: {:.1}% fp8 / {:.1}% sign (no buffer)",
            100.0 * fp8,
            100.0 * sign
        );
    }
    Ok(())
}
