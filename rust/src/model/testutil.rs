//! Test fixtures shared by unit tests, integration tests and benches.

use crate::model::weights::{LayerWeights, ModelConfig, Weights};
use crate::util::rng::Rng;

/// A tiny random-weight model (2L, d=16, 2 heads / 1 kv head, m=8) with the
/// real tokenizer's vocab. Deterministic in `seed`; used wherever a test
/// needs a functioning engine without the trained artifacts.
pub fn tiny_weights(seed: u64) -> Weights {
    tiny_weights_cfg(seed, ModelConfig {
        n_layers: 2, d_model: 16, n_heads: 2, n_kv_heads: 1,
        head_dim: 8, d_ff: 32, vocab: crate::tasks::vocab_size(), max_seq: 128,
    })
}

/// A second, deeper test model (4L, d=32, 4 heads / 2 kv heads, m=8) — the
/// "M"-shaped fixture the golden-transcript suite pins alongside the 2L
/// one, so regressions that only bite GQA grouping or deeper stacks show.
pub fn tiny_weights_deep(seed: u64) -> Weights {
    tiny_weights_cfg(seed, ModelConfig {
        n_layers: 4, d_model: 32, n_heads: 4, n_kv_heads: 2,
        head_dim: 8, d_ff: 64, vocab: crate::tasks::vocab_size(), max_seq: 128,
    })
}

/// Random weights for an arbitrary config (deterministic in `seed`).
pub fn tiny_weights_cfg(seed: u64, cfg: ModelConfig) -> Weights {
    let mut rng = Rng::new(seed);
    let mut mk = |n: usize, fan_in: usize| -> Vec<f32> {
        let s = 1.0 / (fan_in as f32).sqrt();
        (0..n).map(|_| rng.normal() * s).collect()
    };
    let layers = (0..cfg.n_layers)
        .map(|_| LayerWeights {
            ln1: vec![1.0; cfg.d_model],
            wq: mk(cfg.d_model * cfg.q_dim(), cfg.d_model),
            wk: mk(cfg.d_model * cfg.kv_dim(), cfg.d_model),
            wv: mk(cfg.d_model * cfg.kv_dim(), cfg.d_model),
            wo: mk(cfg.q_dim() * cfg.d_model, cfg.q_dim()),
            ln2: vec![1.0; cfg.d_model],
            w1: mk(cfg.d_model * cfg.d_ff, cfg.d_model),
            w3: mk(cfg.d_model * cfg.d_ff, cfg.d_model),
            w2: mk(cfg.d_ff * cfg.d_model, cfg.d_ff),
        })
        .collect();
    Weights {
        cfg,
        embed: mk(cfg.vocab * cfg.d_model, cfg.d_model),
        layers,
        lnf: vec![1.0; cfg.d_model],
        by_name: Default::default(),
    }
}
